"""RPU ISA phases (paper §VI "RPU ISA and Compiler").

The RPU exposes CISC-style long-running instructions (a whole VMM, an SDPA
pass, a collective) whose dataflow is hardened in hardware; the compiler
statically orders them into synchronized memory/compute/network streams.
We model each instruction as a ``Phase`` with its per-CU resource demands;
the event-driven engine (``sim.engine``) executes the streams with the
decoupled-pipeline semantics of §V.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Phase:
    """One CISC instruction in the per-layer stream (per-CU quantities)."""

    name: str
    mem_bytes: float = 0.0       # HBM -> memory-buffer traffic (weights, KV$)
    flops: float = 0.0           # TMAC + HP-VOP work
    net_bytes: float = 0.0       # ring traffic for this phase's collective
    net_hops: int = 0            # ring hops for the collective
    overlap_net: bool = False    # True: broadcast pipelined into the VMM
                                 # (paper §IV: compute starts on the local
                                 # fragment; the collective only bounds the
                                 # phase END).  False: collective gates the
                                 # phase START (SDPA gathers/reductions).
    kind: str = "vmm"            # vmm | sdpa | moe | vop | collective


@dataclasses.dataclass
class LayerProgram:
    """Compiled instruction stream for one transformer layer (or stack)."""

    name: str
    phases: list
    repeat: int = 1

    def total(self, attr: str) -> float:
        return self.repeat * sum(getattr(p, attr) for p in self.phases)


@dataclasses.dataclass
class Program:
    """A full compiled model step (one decode token or one batch step)."""

    name: str
    layers: list                  # list[LayerProgram]
    batch: int = 1
    seq_len: int = 0
    n_cus: int = 1

    def flat_phases(self) -> list:
        out = []
        for lp in self.layers:
            for _ in range(lp.repeat):
                out.extend(lp.phases)
        return out

    def total_mem_bytes(self) -> float:
        return sum(lp.total("mem_bytes") for lp in self.layers)

    def total_flops(self) -> float:
        return sum(lp.total("flops") for lp in self.layers)

    def total_net_bytes(self) -> float:
        return sum(lp.total("net_bytes") for lp in self.layers)
