"""HuBERT-XLarge — encoder-only audio transformer; the conv feature
frontend is a stub (input_specs provides precomputed frame embeddings).
[arXiv:2106.07447]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504, vocab_pad_multiple=512,           # cluster targets
    causal=False,             # bidirectional; no decode step
    frontend="audio",
)
