"""Trip-count-aware HLO cost walker: exactness vs fully-unrolled lowerings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_cost import HloModule, analyze_hlo_text


def _walk(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(c.as_text())


def test_scan_flops_match_unrolled_exactly():
    def body(x, w):
        return x @ w, None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        return jax.lax.scan(body, x, ws, unroll=True)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    cs = _walk(scanned, x, ws)
    cu = _walk(unrolled, x, ws)
    expect = 2 * 64 * 128 * 128 * 12
    assert cs.flops == pytest.approx(expect, rel=1e-6)
    assert cu.flops == pytest.approx(expect, rel=1e-6)
    assert cs.unknown_trip_loops == 0
    # bytes agree within fusion-boundary noise
    assert cs.bytes == pytest.approx(cu.bytes, rel=0.35)


def test_nested_scan_trip_multiplication():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            return jax.lax.scan(inner, c, None, length=5)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    cost = _walk(f, x, ws)
    assert cost.flops == pytest.approx(2 * 32 * 64 * 64 * 35, rel=1e-6)


def test_xla_cost_analysis_undercounts_scans():
    """Document WHY the walker exists: XLA counts loop bodies once."""
    def body(x, w):
        return x @ w, None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert float(ca.get("flops", 0)) < 2 * 64 * 128 * 128 * 12 * 0.5


def test_scan_weight_slices_not_overcounted():
    """Bytes: scanning over stacked weights must stream each layer ONCE,
    not (the full stack x trip count)."""
    L, K, N = 16, 64, 64

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    x = jax.ShapeDtypeStruct((8, K), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, K, N), jnp.float32)
    cost = _walk(f, x, ws)
    stack_bytes = L * K * N * 4
    # each layer's slice is streamed a handful of times (slice r/w + dot
    # read, the op-level no-fusion accounting XLA's cost model also uses)
    # — crucially FAR below the L x blowup of counting the whole stack
    # per iteration (16x here).
    assert cost.bytes < stack_bytes * 6
    assert cost.bytes > stack_bytes * 0.9


def test_dot_general_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 48, 16), jnp.float32)
    cost = _walk(f, a, b)
    assert cost.flops == pytest.approx(2 * 4 * 32 * 48 * 16, rel=1e-6)


def test_collectives_inside_scan_are_multiplied():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device")
    from jax.sharding import PartitionSpec as P
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("x",))

    def f(xs):
        def step(c, x):
            return c + jax.lax.psum(x, "x"), None
        return jax.lax.scan(step, jnp.zeros_like(xs[0]), xs)[0]

    from repro.parallel.compat import shard_map
    g = jax.jit(shard_map(f, mesh=mesh, in_specs=P(None, "x"),
                          out_specs=P("x")))
    xs = jax.ShapeDtypeStruct((10, 8 * n), jnp.float32)
    cost = analyze_hlo_text(g.lower(xs).compile().as_text())
    ar = cost.coll_count.get("all-reduce", 0)
    assert ar >= 10        # one per scan step, trip-multiplied


def test_parser_handles_tuple_headers():
    text = """
HloModule test

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(9)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[4]) -> f32[4] {
  ROOT %a = f32[4] parameter(0)
}
"""
    mod = HloModule(text)
    assert "cond" in mod.comps
    assert mod._trip_count("cond") == 9
