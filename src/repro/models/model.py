"""Model assembly: config -> executable model (init / forward / prefill /
decode_step) for all assigned architecture families.

A model is a sequence of **segments**; each segment is a homogeneous run of
layers executed with ``jax.lax.scan`` over stacked parameters (O(1) HLO in
depth).  A segment step may contain several block kinds (e.g. Llama4's
alternating dense/MoE pair), so heterogeneous-period stacks still scan.
Layers that differ in attention window (Hymba's global/SWA mix) are split
into separate segments so the window — and hence the KV-cache geometry —
stays static per segment.

Block kinds:
  attn_dense   GQA attention + SwiGLU MLP            (qwen*, phi3, danube, hubert, internvl2 backbone)
  attn_moe     GQA attention + MoE                    (llama4-maverick)
  mla_dense    MLA attention + SwiGLU MLP             (deepseek first layer)
  mla_moe      MLA attention + MoE(+shared)           (deepseek)
  ssm          Mamba2 SSD mixer (no MLP)              (mamba2)
  hybrid       attention ∥ SSM heads, then MLP        (hymba)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib, ssm as ssm_lib
from repro.models.attention_backends import backend_for_kind, layout_for_kind
from repro.models.common import (
    ModelConfig, count_params, dense_init, embed_init, rmsnorm, split_keys,
)
from repro.parallel.hints import shard_hint, tp_psum


@dataclasses.dataclass(frozen=True)
class Segment:
    kinds: tuple[str, ...]
    reps: int
    window: int | None = None     # attention window; None = full attention


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------


def build_plan(cfg: ModelConfig) -> list[Segment]:
    if cfg.family == "ssm":
        return [Segment(("ssm",), cfg.n_layers)]
    if cfg.family == "hybrid":
        # per-layer window: global attention at layers 0, every
        # ``global_attn_every``, and the last layer; SWA elsewhere.
        wins = []
        for i in range(cfg.n_layers):
            is_global = (cfg.global_attn_every and
                         (i % cfg.global_attn_every == 0 or i == cfg.n_layers - 1))
            wins.append(None if is_global else cfg.sliding_window)
        segs: list[Segment] = []
        for w in wins:
            if segs and segs[-1].window == w:
                segs[-1] = dataclasses.replace(segs[-1], reps=segs[-1].reps + 1)
            else:
                segs.append(Segment(("hybrid",), 1, w))
        return segs
    w = cfg.sliding_window
    if cfg.mla:
        segs = []
        nd = cfg.first_dense_layers
        if nd:
            segs.append(Segment(("mla_dense",), nd, w))
        segs.append(Segment(("mla_moe",), cfg.n_layers - nd, w))
        return segs
    if cfg.moe:
        if cfg.moe_layer_period == 1:
            segs = []
            nd = cfg.first_dense_layers
            if nd:
                segs.append(Segment(("attn_dense",), nd, w))
            segs.append(Segment(("attn_moe",), cfg.n_layers - nd, w))
            return segs
        assert cfg.n_layers % cfg.moe_layer_period == 0
        kinds = tuple(["attn_dense"] * (cfg.moe_layer_period - 1) + ["attn_moe"])
        return [Segment(kinds, cfg.n_layers // cfg.moe_layer_period, w)]
    return [Segment(("attn_dense",), cfg.n_layers, w)]


# ---------------------------------------------------------------------------
# Block dispatch
# ---------------------------------------------------------------------------


def _init_block(kind: str, key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, 4)
    d = cfg.d_model
    ln = lambda: jnp.ones((d,), jnp.float32)
    be = backend_for_kind(kind)
    if kind in ("attn_dense", "mla_dense"):
        d_ff = cfg.d_ff if kind == "mla_dense" else None
        return {"ln1": ln(), "attn": be.init(ks[0], cfg),
                "ln2": ln(), "mlp": layers.init_mlp(ks[1], cfg, d_ff)}
    if kind in ("attn_moe", "mla_moe"):
        return {"ln1": ln(), "attn": be.init(ks[0], cfg),
                "ln2": ln(), "moe": moe_lib.init_moe(ks[1], cfg)}
    if kind == "ssm":
        return {"ln1": ln(), "ssm": ssm_lib.init_ssm(ks[0], cfg)}
    if kind == "hybrid":
        return {"ln1": ln(), "attn": be.init(ks[0], cfg),
                "ssm": ssm_lib.init_ssm(ks[1], cfg),
                "attn_out_norm": ln(), "ssm_out_norm": ln(),
                "ln2": ln(), "mlp": layers.init_mlp(ks[2], cfg)}
    raise ValueError(kind)


def _init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                      window: int | None, dtype=None):
    dtype = dtype or jnp.bfloat16
    be = backend_for_kind(kind)
    if kind == "ssm":
        return ssm_lib.init_ssm_state(cfg, batch)
    if kind == "hybrid":
        return {"attn": be.init_cache(cfg, batch, max_len, window,
                                      dtype=dtype),
                "ssm": ssm_lib.init_ssm_state(cfg, batch)}
    return be.init_cache(cfg, batch, max_len, window, dtype=dtype)


def _ffn(kind: str, p: dict, x, cfg: ModelConfig, moe_impl: str):
    if kind.endswith("_moe") or kind == "attn_moe":
        # inside a manual TP serve region MoE weights are replicated —
        # every expert matmul (incl. shared experts) is already complete,
        # so the whole subtree traces with the Megatron marks off
        from repro.parallel.hints import no_manual_tp
        with no_manual_tp():
            return moe_lib.moe_forward(x, p["moe"], cfg, impl=moe_impl)
    return layers.mlp_forward(p["mlp"], x)


def _block_forward(kind: str, p: dict, x, cfg: ModelConfig, window,
                   moe_impl: str):
    be = backend_for_kind(kind)
    if kind == "ssm":
        out, _ = ssm_lib.ssm_forward(rmsnorm(x, p["ln1"], cfg.norm_eps), p["ssm"], cfg)
        return x + out
    if kind == "hybrid":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a = be.forward(p["attn"], h, cfg, window=window)
        s, _ = ssm_lib.ssm_forward(h, p["ssm"], cfg)
        mix = 0.5 * (rmsnorm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rmsnorm(s, p["ssm_out_norm"], cfg.norm_eps))
        x = x + mix
        x = x + layers.mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a = be.forward(p["attn"], h, cfg, window=window)
    x = x + a
    x = x + _ffn(kind, p, rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, moe_impl)
    return shard_hint(x, "act_bsd")


def _block_prefill(kind: str, p: dict, x, cfg: ModelConfig, window, cache,
                   moe_impl: str):
    be = backend_for_kind(kind)
    if kind == "ssm":
        out, st = ssm_lib.ssm_forward(rmsnorm(x, p["ln1"], cfg.norm_eps),
                                      p["ssm"], cfg, None)
        return x + out, st
    if kind == "hybrid":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, ac = be.prefill(p["attn"], h, cfg, cache["attn"], window=window)
        s, sc = ssm_lib.ssm_forward(h, p["ssm"], cfg, None)
        mix = 0.5 * (rmsnorm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rmsnorm(s, p["ssm_out_norm"], cfg.norm_eps))
        x = x + mix
        x = x + layers.mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x, {"attn": ac, "ssm": sc}
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, c = be.prefill(p["attn"], h, cfg, cache, window=window)
    x = x + a
    x = x + _ffn(kind, p, rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, moe_impl)
    return x, c


def _init_block_page_pool(kind: str, cfg: ModelConfig, num_pages: int,
                          page_size: int, dtype=None):
    dtype = dtype or jnp.bfloat16
    be = backend_for_kind(kind)
    if be is None:
        # pure-state kinds (ssm) write no token-indexed pages: an empty
        # pool keeps the pytree structure parallel so the scanned segment
        # protocol (and the engine's page walkers) need no special case
        return {}
    if not be.supports_paged:
        raise NotImplementedError(
            f"continuous batching: no paged cache for block kind {kind!r}")
    pool = be.init_page_pool(cfg, num_pages, page_size, dtype=dtype)
    # quantized pools may carry extra metadata leaves (k_scale/v_scale)
    # beyond the declared token-axis leaves
    assert set(be.paged_leaf_keys) <= set(pool), \
        (f"backend {be.name!r} pool layout {sorted(pool)} missing declared "
         f"paged_leaf_keys {sorted(be.paged_leaf_keys)}")
    return pool


def _gather_state_rows(state, slot_idx, start):
    """Pick per-slot state rows for a prefill chunk's bucket rows.

    Rows whose chunk starts at position 0 read a ZERO state in-graph:
    admission and preemption-restart both begin at ``start == 0``, so the
    host never has to reset state-pool rows between tenants — the zeroing
    is part of the traced step, like the scratch-page redirect for pages."""
    def pick(a):
        rows = a[slot_idx]
        fresh = (start == 0).reshape((-1,) + (1,) * (rows.ndim - 1))
        return jnp.where(fresh, jnp.zeros_like(rows), rows)
    return jax.tree.map(pick, state)


def _scatter_state_rows(state, rows, slot_idx, valid):
    """Write updated rows back into the slot-indexed pool; bucket padding
    rows (``valid == 0``) are dropped via an out-of-bounds index."""
    def put(a, r):
        safe = jnp.where(valid > 0, slot_idx, a.shape[0])
        return a.at[safe].set(r.astype(a.dtype), mode="drop")
    return jax.tree.map(put, state, rows)


def _commit_state_rows(state, new, ok):
    """Decode-step commit: only rows actually decoding this step replace
    their state (other slots may be mid-prefill in the same iteration)."""
    def put(a, n):
        m = ok.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, n.astype(a.dtype), a)
    return jax.tree.map(put, state, new)


def _block_decode_paged(kind: str, p: dict, x, cfg: ModelConfig, window,
                        pool, page_table, pos, moe_impl: str,
                        state=None, state_ok=None):
    """Paged analogue of ``_block_decode``: per-slot ragged positions and
    K/V streamed through the page table.  x: (B, D).

    Stateful kinds (ssm, the SSM half of hybrid) run the exact
    single-token recurrence over their slot-indexed ``state`` rows and
    commit only rows flagged by ``state_ok`` (slots actually decoding).
    Returns ``(x, new_pool, new_state)`` — stateless kinds pass their
    (possibly empty) state through untouched.

    The ``tp_psum`` marks close the Megatron column->row pairs when this
    traces inside the sharded serve path's manual region (one reduction
    per attention block, one per dense MLP; MoE experts run replicated
    there, so their output is already complete).  Off-mesh they are
    identity."""
    be = backend_for_kind(kind)
    if kind == "ssm":
        out, st = ssm_lib.ssm_decode_step(rmsnorm(x, p["ln1"], cfg.norm_eps),
                                          p["ssm"], cfg, state)
        return x + out, pool, _commit_state_rows(state, st, state_ok)
    if kind == "hybrid":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, c = be.decode_paged(p["attn"], h, cfg, pool, page_table, pos,
                               window=window)
        s, st = ssm_lib.ssm_decode_step(h, p["ssm"], cfg, state)
        mix = 0.5 * (rmsnorm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rmsnorm(s, p["ssm_out_norm"], cfg.norm_eps))
        x = x + mix
        x = x + layers.mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x, c, _commit_state_rows(state, st, state_ok)
    if be is None or be.decode_paged is None:
        raise NotImplementedError(kind)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, c = be.decode_paged(p["attn"], h, cfg, pool, page_table, pos,
                           window=window)
    x = x + tp_psum(a).astype(x.dtype)
    f = _ffn(kind, p, rmsnorm(x[:, None, :], p["ln2"], cfg.norm_eps), cfg,
             moe_impl)[:, 0]
    x = x + (f if kind.endswith("_moe") else tp_psum(f).astype(x.dtype))
    return x, c, state


def _block_prefill_chunk_paged(kind: str, p: dict, x, cfg: ModelConfig,
                               window, pool, page_table, start, valid,
                               moe_impl: str, state=None, slot_idx=None):
    """Paged chunked-prefill analogue of ``_block_prefill``.  x: (B, C, D);
    start/valid: (B,) per-slot chunk offset and real-token count.

    Stateful kinds gather their ``slot_idx`` state rows (zeroed at
    ``start == 0``), run the chunked SSD with ``valid`` masking so the
    carried state lands exactly at the valid boundary, and scatter the
    rows back.  Returns ``(x, new_pool, new_state)``."""
    be = backend_for_kind(kind)
    if kind == "ssm":
        rows = _gather_state_rows(state, slot_idx, start)
        out, st = ssm_lib.ssm_forward(rmsnorm(x, p["ln1"], cfg.norm_eps),
                                      p["ssm"], cfg, rows, valid=valid)
        return x + out, pool, _scatter_state_rows(state, st, slot_idx, valid)
    if kind == "hybrid":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, c = be.prefill_chunk_paged(p["attn"], h, cfg, pool, page_table,
                                      start, valid, window=window)
        rows = _gather_state_rows(state, slot_idx, start)
        s, st = ssm_lib.ssm_forward(h, p["ssm"], cfg, rows, valid=valid)
        mix = 0.5 * (rmsnorm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rmsnorm(s, p["ssm_out_norm"], cfg.norm_eps))
        x = x + mix
        x = x + layers.mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x, c, _scatter_state_rows(state, st, slot_idx, valid)
    if be is None or be.prefill_chunk_paged is None:
        raise NotImplementedError(kind)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, c = be.prefill_chunk_paged(p["attn"], h, cfg, pool, page_table, start,
                                  valid, window=window)
    x = x + tp_psum(a).astype(x.dtype)
    f = _ffn(kind, p, rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, moe_impl)
    x = x + (f if kind.endswith("_moe") else tp_psum(f).astype(x.dtype))
    return x, c, state


def _block_decode_multi_paged(kind: str, p: dict, x, cfg: ModelConfig,
                              window, pool, page_table, start, valid,
                              moe_impl: str):
    """Multi-token paged decode (speculative verify): x: (B, C, D) chosen
    tokens at per-slot offsets ``start`` with ``valid`` real rows.  Same
    block shape as ``_block_prefill_chunk_paged`` but dispatched through
    the backend's ``decode_multi_paged`` entry so new cache families can
    split the two paths (e.g. SSM states need an explicit multi-step
    scan here but a one-shot conv prefill there)."""
    be = backend_for_kind(kind)
    if be is None or be.decode_multi_paged is None or kind == "hybrid":
        raise NotImplementedError(
            f"multi-token decode (speculative verify) over block kind "
            f"{kind!r}: state pools advance one token per step — the "
            f"engine gates speculation off for stateful layouts")
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, c = be.decode_multi_paged(p["attn"], h, cfg, pool, page_table, start,
                                 valid, window=window)
    x = x + tp_psum(a).astype(x.dtype)
    f = _ffn(kind, p, rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, moe_impl)
    x = x + (f if kind.endswith("_moe") else tp_psum(f).astype(x.dtype))
    return x, c


def _block_decode(kind: str, p: dict, x, cfg: ModelConfig, window, cache,
                  cur_pos, moe_impl: str):
    """x: (B, D) single-token representations."""
    be = backend_for_kind(kind)
    if kind == "ssm":
        out, st = ssm_lib.ssm_decode_step(rmsnorm(x, p["ln1"], cfg.norm_eps),
                                          p["ssm"], cfg, cache)
        return x + out, st
    if kind == "hybrid":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, ac = be.decode(p["attn"], h, cfg, cache["attn"], cur_pos,
                          window=window)
        s, sc = ssm_lib.ssm_decode_step(h, p["ssm"], cfg, cache["ssm"])
        mix = 0.5 * (rmsnorm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rmsnorm(s, p["ssm_out_norm"], cfg.norm_eps))
        x = x + mix
        x = x + layers.mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x, {"attn": ac, "ssm": sc}
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, c = be.decode(p["attn"], h, cfg, cache, cur_pos, window=window)
    x = x + a
    x = x + _ffn(kind, p, rmsnorm(x[:, None, :], p["ln2"], cfg.norm_eps), cfg,
                 moe_impl)[:, 0]
    return x, c


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Executable model for one ``ModelConfig``.

    Stateless: all state lives in explicit ``params`` / ``cache`` pytrees.
    """

    def __init__(self, cfg: ModelConfig, moe_impl: str = "auto"):
        self.cfg = cfg
        self.plan = build_plan(cfg)
        self.moe_impl = moe_impl
        # stateful serving: any kind carrying per-slot recurrent state
        self._needs_state = any(layout_for_kind(k).state
                                for seg in self.plan for k in seg.kinds)
        assert sum(len(s.kinds) * s.reps for s in self.plan) == cfg.n_layers
        for seg in self.plan:               # windowed segments need a
            for kind in seg.kinds:          # sliding-capable dense backend
                be = backend_for_kind(kind)
                if seg.window is not None and be is not None:
                    assert "sliding" in be.mask_families, \
                        (f"backend {be.name!r} has no sliding mask for "
                         f"windowed segment kind {kind!r}")

    # ----- init -----
    def init(self, key) -> dict:
        cfg = self.cfg
        keys = split_keys(key, len(self.plan) + 3)
        stacks = []
        for seg, k in zip(self.plan, keys[:-3]):
            kinds_params = []
            for ki, kind in enumerate(seg.kinds):
                kk = jax.random.fold_in(k, ki)
                if seg.reps == 1:
                    kinds_params.append(_init_block(kind, kk, cfg))
                else:
                    kinds_params.append(jax.vmap(
                        lambda kkk: _init_block(kind, kkk, cfg))(
                            jax.random.split(kk, seg.reps)))
            stacks.append(tuple(kinds_params))
        params: dict[str, Any] = {"stacks": stacks,
                                  "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
        if cfg.frontend == "audio":
            params["in_proj"] = dense_init(keys[-3], cfg.d_model, cfg.d_model)
            params["head"] = dense_init(keys[-2], cfg.d_model, cfg.padded_vocab)
        else:
            params["embed"] = embed_init(keys[-3], cfg.padded_vocab, cfg.d_model)
            if not cfg.tie_embeddings:
                params["head"] = dense_init(keys[-2], cfg.d_model, cfg.padded_vocab)
        return params

    # ----- shared pieces -----
    def _embed_inputs(self, params: dict, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = batch["features"].astype(jnp.bfloat16) @ params["in_proj"]
        elif cfg.frontend == "vision":
            tok = params["embed"][batch["tokens"]]
            x = jnp.concatenate([batch["image_embeds"].astype(tok.dtype), tok],
                                axis=1)
        else:
            x = params["embed"][batch["tokens"]]
        return shard_hint(x, "act_bsd")

    def _head(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["head"]
        if cfg.padded_vocab != cfg.vocab_size:   # mask pad columns to -inf
            pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
        return shard_hint(logits, "logits")

    # ----- forward (training / no-cache prefill) -----
    def forward(self, params: dict, batch: dict, *, remat: bool = False) -> jnp.ndarray:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)

        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]

            def seg_step(xc, ps, seg=seg):
                for kind, p in zip(seg.kinds, ps):
                    xc = _block_forward(kind, p, xc, cfg, seg.window,
                                        self.moe_impl)
                return xc

            if remat:
                # Save ONLY the scan carry (layer boundary); recompute all
                # within-layer activations on the backward pass.  At 4k x 256
                # x 40L saving dot outputs too would need >100 GiB/device.
                seg_step = jax.checkpoint(seg_step)

            if seg.reps == 1:
                x = seg_step(x, stack)
            else:
                x, _ = jax.lax.scan(lambda c, ps: (seg_step(c, ps), None),
                                    x, stack)
        return self._head(params, x)

    # ----- loss -----
    @staticmethod
    def _xent(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
        """Mean cross-entropy without materializing (B,S,V) log-probs.

        ``logsumexp`` and ``take_along_axis`` reduce the vocab axis in f32
        on the fly, so the only (B,S,V) buffer is the bf16 logits (which
        shard over TP via the "logits" rule) — essential for 200k-vocab
        training cells.
        """
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        tgt = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - tgt)

    def loss(self, params: dict, batch: dict, *, remat: bool = False) -> jnp.ndarray:
        cfg = self.cfg
        logits = self.forward(params, batch, remat=remat)
        if cfg.frontend == "audio":
            return self._xent(logits, batch["labels"])
        tokens = batch["tokens"]
        if cfg.frontend == "vision":
            ni = batch["image_embeds"].shape[1]
            logits = logits[:, ni:, :]
        return self._xent(logits[:, :-1], tokens[:, 1:])

    # ----- cache -----
    def init_cache(self, batch: int, max_len: int, dtype=None) -> list:
        cfg = self.cfg
        caches = []
        for seg in self.plan:
            kinds_caches = []
            for kind in seg.kinds:
                single = _init_block_cache(kind, cfg, batch, max_len,
                                           seg.window, dtype)
                if seg.reps == 1:
                    kinds_caches.append(single)
                else:
                    kinds_caches.append(jax.tree.map(
                        lambda a: jnp.tile(a[None], (seg.reps,) + (1,) * a.ndim),
                        single))
            caches.append(tuple(kinds_caches))
        return caches

    # ----- paged cache (continuous-batching serve) -----
    def init_paged_cache(self, num_pages: int, page_size: int,
                         dtype=None, *, ring_pages: int | None = None) -> list:
        """Physical page pools, one per layer, in the same nested structure
        as ``init_cache`` (list over segments, tuple over kinds, stacked
        along a leading reps axis for scanned segments).  All full-KV
        layers share one logical page-id space — the allocator in
        ``runtime.kv_cache`` is model-agnostic.

        ``ring_pages``: pool size for sliding-window segments, which live
        in their own (smaller) page-id space managed by
        ``runtime.state_cache.RingPageSpace`` — O(window) pages per slot
        instead of O(context).  When None (legacy callers), windowed
        segments share the full space and simply never reclaim."""
        cfg = self.cfg
        pools = []
        for seg in self.plan:
            if seg.window is not None and any(
                    (be := backend_for_kind(k)) is None
                    or "sliding" not in be.paged_mask_families
                    for k in seg.kinds):
                raise NotImplementedError(
                    "continuous batching over sliding-window segments needs "
                    "a sliding-capable paged backend")
            size = (ring_pages if (seg.window is not None
                                   and ring_pages is not None) else num_pages)
            kinds_pools = []
            for kind in seg.kinds:
                single = _init_block_page_pool(kind, cfg, size,
                                               page_size, dtype)
                if seg.reps == 1:
                    kinds_pools.append(single)
                else:
                    kinds_pools.append(jax.tree.map(
                        lambda a: jnp.tile(a[None], (seg.reps,) + (1,) * a.ndim),
                        single))
            pools.append(tuple(kinds_pools))
        return pools

    def init_state_pools(self, num_slots: int) -> list:
        """Per-slot recurrent state pools (SSM conv tail + SSD state), in
        the same nested structure as ``init_paged_cache``; stateless kinds
        get empty subtrees so the scanned-segment protocol is uniform."""
        cfg = self.cfg
        states = []
        for seg in self.plan:
            kinds_states = []
            for kind in seg.kinds:
                lay = layout_for_kind(kind)
                single = (lay.init_state_pool(cfg, num_slots)
                          if lay.state else {})
                if seg.reps == 1:
                    kinds_states.append(single)
                else:
                    kinds_states.append(jax.tree.map(
                        lambda a: jnp.tile(a[None], (seg.reps,) + (1,) * a.ndim),
                        single))
            states.append(tuple(kinds_states))
        return states

    def prefill_chunk_paged(self, params: dict, tokens: jnp.ndarray,
                            pools: list, page_table: jnp.ndarray,
                            start: jnp.ndarray, valid: jnp.ndarray, *,
                            states: list | None = None,
                            ring_table: jnp.ndarray | None = None,
                            slot_idx: jnp.ndarray | None = None):
        """One fixed-size prefill chunk over a slot batch, straight into the
        page pools.

        tokens: (B, C) int32 chunk tokens (rows padded past ``valid``);
        start: (B,) int32 absolute position of tokens[:, 0]; valid: (B,)
        int32 number of real tokens in each row (0 for padding rows, whose
        page-table rows must point at the scratch page).  Each chunk
        attends over the pages already written for its slot — earlier
        chunks, or prefix-cache pages shared from another request — so long
        prompts prefill incrementally, interleaved with decode iterations.

        Stateful models additionally thread ``states`` (slot-indexed
        pools from ``init_state_pools``) with ``slot_idx`` (B,) mapping
        bucket rows to slots, and ``ring_table`` for sliding-window
        segments; the return gains a third element, the updated states.

        Returns per-row logits at the row's last valid position (the
        first-token logits once a request's final chunk lands) and the
        updated pools."""
        x, new_pools, new_states = self._prefill_chunk_body(
            params, tokens, pools, page_table, start, valid,
            states=states, ring_table=ring_table, slot_idx=slot_idx)
        b, c = tokens.shape
        last = jnp.clip(valid - 1, 0, c - 1)
        x_last = x[jnp.arange(b), last]
        logits = self._head(params, x_last[:, None, :])[:, 0]
        if states is None:
            return logits, new_pools
        return logits, new_pools, new_states

    def prefill_chunk_scored_paged(self, params: dict, tokens: jnp.ndarray,
                                   pools: list, page_table: jnp.ndarray,
                                   start: jnp.ndarray, valid: jnp.ndarray, *,
                                   states: list | None = None,
                                   ring_table: jnp.ndarray | None = None,
                                   slot_idx: jnp.ndarray | None = None):
        """Chunked paged prefill that also SCORES the chunk (prompt
        logprobs): returns (last_logits (B, V), full_logits (B, C, V),
        pools[, states]).  ``last_logits`` comes through exactly the same
        last-position head shape as ``prefill_chunk_paged``, so a scored
        admission samples the identical first token; ``full_logits`` feed
        raw prompt-token scoring, where rounding parity doesn't matter."""
        x, new_pools, new_states = self._prefill_chunk_body(
            params, tokens, pools, page_table, start, valid,
            states=states, ring_table=ring_table, slot_idx=slot_idx)
        b, c = tokens.shape
        last = jnp.clip(valid - 1, 0, c - 1)
        x_last = x[jnp.arange(b), last]
        last_logits = self._head(params, x_last[:, None, :])[:, 0]
        if states is None:
            return last_logits, self._head(params, x), new_pools
        return last_logits, self._head(params, x), new_pools, new_states

    def _prefill_chunk_body(self, params, tokens, pools, page_table, start,
                            valid, states=None, ring_table=None,
                            slot_idx=None):
        cfg = self.cfg
        assert cfg.frontend is None, "chunked paged prefill serves tokens only"
        if states is None and self._needs_state:
            raise NotImplementedError(
                f"{cfg.name}: ssm/hybrid serving needs per-slot state pools "
                f"— pass states=init_state_pools(num_slots) (the continuous "
                f"engine threads them automatically)")
        x = params["embed"][tokens]                        # (B, C, D)
        x = shard_hint(x, "act_bsd")
        new_pools = []
        new_states = [] if states is not None else None
        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]
            # sliding-window segments index their own (ring) page space
            tbl = (ring_table if (seg.window is not None
                                  and ring_table is not None) else page_table)

            def seg_step(xc, layer, seg=seg, tbl=tbl):
                if states is None:
                    ps, cs = layer
                    ss = ({},) * len(seg.kinds)
                else:
                    ps, cs, ss = layer
                new_cs, new_ss = [], []
                for kind, p, c, s in zip(seg.kinds, ps, cs, ss):
                    xc, nc, ns = _block_prefill_chunk_paged(
                        kind, p, xc, cfg, seg.window, c, tbl, start,
                        valid, self.moe_impl, state=s, slot_idx=slot_idx)
                    new_cs.append(nc)
                    new_ss.append(ns)
                if states is None:
                    return xc, tuple(new_cs)
                return xc, (tuple(new_cs), tuple(new_ss))

            layer = ((stack, pools[si]) if states is None
                     else (stack, pools[si], states[si]))
            if seg.reps == 1:
                x, ys = seg_step(x, layer)
            else:
                x, ys = jax.lax.scan(seg_step, x, layer)
            if states is None:
                new_pools.append(ys)
            else:
                new_pools.append(ys[0])
                new_states.append(ys[1])
        return x, new_pools, new_states

    def decode_step_paged(self, params: dict, tokens: jnp.ndarray,
                          pools: list, page_table: jnp.ndarray,
                          pos: jnp.ndarray, valid: jnp.ndarray | None = None,
                          *, states: list | None = None,
                          ring_table: jnp.ndarray | None = None,
                          state_ok: jnp.ndarray | None = None):
        """One continuous-batching decode step over the slot batch.

        tokens: (B,) int32 (one per slot); pos: (B,) int32 per-slot ragged
        positions; page_table: (B, n_blocks) int32.  Inactive slots point
        at the scratch page and are masked out by the caller.

        Stateful models thread ``states`` (slot-indexed pools, B ==
        num_slots rows aligned with the decode batch), ``ring_table``
        (the sliding-window segments' own page space), and ``state_ok``
        (B,) bool marking slots actually decoding (their state rows
        commit; all other rows keep their value).  The return gains a
        third element, the updated states.

        Multi-token form (speculative verify / prompt scoring): tokens
        (B, C) int32 of C *already-chosen* tokens per slot starting at
        per-slot position ``pos`` with ``valid`` (B,) real rows (the rest
        scatter to the scratch page) — returns (B, C, V) logits, one
        next-token distribution per fed position, through the backends'
        ``decode_multi_paged`` ragged-q_offset path (unsupported for
        stateful layouts — speculation is gated off there)."""
        cfg = self.cfg
        assert cfg.frontend != "audio", "encoder-only models have no decode step"
        if tokens.ndim == 2:
            if states is not None:
                raise NotImplementedError(
                    "multi-token decode over state pools (speculative "
                    "verify) is unsupported — the engine gates it off")
            return self._decode_multi_paged(params, tokens, pools, page_table,
                                            pos, valid)
        if states is None and self._needs_state:
            raise NotImplementedError(
                f"{cfg.name}: ssm/hybrid serving needs per-slot state pools "
                f"— pass states=init_state_pools(num_slots) (the continuous "
                f"engine threads them automatically)")
        x = params["embed"][tokens]
        x = shard_hint(x, "act_bd")
        new_pools = []
        new_states = [] if states is not None else None
        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]
            tbl = (ring_table if (seg.window is not None
                                  and ring_table is not None) else page_table)

            def seg_step(xc, layer, seg=seg, tbl=tbl):
                if states is None:
                    ps, cs = layer
                    ss = ({},) * len(seg.kinds)
                else:
                    ps, cs, ss = layer
                new_cs, new_ss = [], []
                for kind, p, c, s in zip(seg.kinds, ps, cs, ss):
                    xc, nc, ns = _block_decode_paged(
                        kind, p, xc, cfg, seg.window, c, tbl, pos,
                        self.moe_impl, state=s, state_ok=state_ok)
                    new_cs.append(nc)
                    new_ss.append(ns)
                if states is None:
                    return xc, tuple(new_cs)
                return xc, (tuple(new_cs), tuple(new_ss))

            layer = ((stack, pools[si]) if states is None
                     else (stack, pools[si], states[si]))
            if seg.reps == 1:
                x, ys = seg_step(x, layer)
            else:
                x, ys = jax.lax.scan(seg_step, x, layer)
            if states is None:
                new_pools.append(ys)
            else:
                new_pools.append(ys[0])
                new_states.append(ys[1])
        logits = self._head(params, x[:, None, :])[:, 0]
        if states is None:
            return logits, new_pools
        return logits, new_pools, new_states

    def _decode_multi_paged(self, params: dict, tokens: jnp.ndarray,
                            pools: list, page_table: jnp.ndarray,
                            pos: jnp.ndarray, valid: jnp.ndarray | None
                            ) -> tuple[jnp.ndarray, list]:
        """(B, C) tokens at per-slot offsets -> (B, C, V) logits; the head
        keeps EVERY position (the verify step scores all gamma+1 of them),
        unlike chunked prefill's last-valid-only head.

        On CPU the window is flattened into B*C VIRTUAL SLOTS and run
        through the single-token decode program itself: each window token
        becomes its own decode row with its own position and a copy of its
        slot's page-table row, so every position's logits — and every KV
        write — come out of literally the same compiled computation as
        the non-speculative decode step, bit for bit (the greedy
        byte-identity contract; a chunk-shaped (B, C, D) trace diverges at
        bf16 ulp inside the scanned segments because XLA fuses the 3-D
        carry differently).  Later window positions ARE already scattered
        when an earlier query reads the pool, but the causal ``idx <=
        pos`` mask assigns them exp(NEG_INF) == exact zero weight, which
        is indistinguishable from their never having been written.  On
        accelerators the chunk-shaped ``decode_multi_paged`` dispatch
        runs instead: pages stream once per slot (not once per window
        token), and the byte-contract doesn't span kernels there anyway.
        """
        from repro.kernels import on_cpu

        b, c = tokens.shape
        if valid is None:
            valid = jnp.full((b,), c, jnp.int32)
        if on_cpu():
            ok = (jnp.arange(c)[None, :] < valid[:, None]).reshape(b * c)
            vpt = jnp.where(ok[:, None],
                            jnp.repeat(page_table, c, axis=0), 0)
            vpos = (jnp.repeat(pos, c)
                    + jnp.tile(jnp.arange(c, dtype=pos.dtype), b))
            vpos = jnp.where(ok, vpos, 0)
            logits, new_pools = self.decode_step_paged(
                params, tokens.reshape(b * c), pools, vpt, vpos)
            return logits.reshape(b, c, -1), new_pools
        x = params["embed"][tokens]                        # (B, C, D)
        x = shard_hint(x, "act_bsd")
        new_pools = []
        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]

            def seg_step(xc, layer, seg=seg):
                ps, cs = layer
                new_cs = []
                for kind, p, cch in zip(seg.kinds, ps, cs):
                    xc, nc = _block_decode_multi_paged(
                        kind, p, xc, self.cfg, seg.window, cch, page_table,
                        pos, valid, self.moe_impl)
                    new_cs.append(nc)
                return xc, tuple(new_cs)

            if seg.reps == 1:
                x, nc = seg_step(x, (stack, pools[si]))
            else:
                x, nc = jax.lax.scan(seg_step, x, (stack, pools[si]))
            new_pools.append(nc)
        logits = self._head(params, x)                     # (B, C, V)
        return logits, new_pools

    # ----- prefill -----
    def prefill(self, params: dict, batch: dict, cache: list):
        """Run the full prompt, fill the cache; returns (last_logits, cache)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        new_caches = []
        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]

            def seg_step(xc, layer, seg=seg):
                ps, cs = layer
                new_cs = []
                for kind, p, c in zip(seg.kinds, ps, cs):
                    xc, nc = _block_prefill(kind, p, xc, cfg, seg.window, c,
                                            self.moe_impl)
                    new_cs.append(nc)
                return xc, tuple(new_cs)

            if seg.reps == 1:
                x, nc = seg_step(x, (stack, cache[si]))
            else:
                x, nc = jax.lax.scan(seg_step, x, (stack, cache[si]))
            new_caches.append(nc)
        logits = self._head(params, x[:, -1:, :])[:, 0]
        return logits, new_caches

    # ----- decode -----
    def decode_step(self, params: dict, tokens: jnp.ndarray, cache: list,
                    cur_pos) -> tuple[jnp.ndarray, list]:
        """One decode step.  tokens: (B,) int32; cur_pos: scalar position."""
        cfg = self.cfg
        assert cfg.frontend != "audio", "encoder-only models have no decode step"
        x = params["embed"][tokens]
        x = shard_hint(x, "act_bd")
        new_caches = []
        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]

            def seg_step(xc, layer, seg=seg):
                ps, cs = layer
                new_cs = []
                for kind, p, c in zip(seg.kinds, ps, cs):
                    xc, nc = _block_decode(kind, p, xc, cfg, seg.window, c,
                                           cur_pos, self.moe_impl)
                    new_cs.append(nc)
                return xc, tuple(new_cs)

            if seg.reps == 1:
                x, nc = seg_step(x, (stack, cache[si]))
            else:
                x, nc = jax.lax.scan(seg_step, x, (stack, cache[si]))
            new_caches.append(nc)
        logits = self._head(params, x[:, None, :])[:, 0]
        return logits, new_caches

    def param_count(self, params) -> int:
        return count_params(params)


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)
