"""Fault-tolerant training loop.

Production contract (the part of "runs on 1000 nodes" that lives above the
compiler): periodic atomic checkpoints, loss-spike/NaN detection with
rollback-and-skip, straggler-tolerant data fetch (see ``data.pipeline``),
and elastic restart (restore onto a different mesh via
``checkpoint.restore_latest(shardings=...)``).

Failure injection (``failure_fn``) lets tests exercise the recovery paths
deterministically.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.data.pipeline import SyntheticTokenPipeline
from repro.train import checkpoint as ckpt_lib
from repro.train.train_step import TrainState

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = False
    nan_rollback: bool = True
    max_rollbacks: int = 3
    log_every: int = 10


@dataclasses.dataclass
class LoopResult:
    state: Any
    losses: list
    rollbacks: int
    resumed_from: int
    straggler_fallbacks: int


def run_training(
    train_step: Callable,
    state: TrainState,
    pipeline: SyntheticTokenPipeline,
    loop_cfg: LoopConfig,
    *,
    shardings=None,
    failure_fn: Callable[[int], bool] | None = None,
) -> LoopResult:
    """Run (or resume) training to ``total_steps``.

    ``failure_fn(step) -> True`` injects a simulated node failure: the loop
    responds exactly as to a real one — restore last checkpoint, continue.
    """
    jitted = jax.jit(train_step, donate_argnums=(0,))

    # ---- resume if a committed checkpoint exists (elastic restore)
    restored, from_step = ckpt_lib.restore_latest(
        loop_cfg.ckpt_dir, state, shardings=shardings)
    if restored is not None:
        state = restored
        log.info("resumed from step %d", from_step)
    start = int(state.step)

    losses: list[float] = []
    rollbacks = 0
    pending_save = None
    step = start
    while step < loop_cfg.total_steps:
        if failure_fn is not None and failure_fn(step):
            # simulated node failure: abandon in-flight state, restore.
            log.warning("injected failure at step %d; restoring", step)
            restored, from_step = ckpt_lib.restore_latest(
                loop_cfg.ckpt_dir, state, shardings=shardings)
            if restored is None:
                raise RuntimeError("failure before first checkpoint")
            state = restored
            step = int(state.step)
            rollbacks += 1
            if rollbacks > loop_cfg.max_rollbacks:
                raise RuntimeError("rollback budget exhausted")
            continue

        batch = pipeline.get_batch(step)
        batch = jax.tree.map(jnp.asarray, batch)
        new_state, metrics = jitted(state, batch)
        loss = float(metrics["loss"])

        if loop_cfg.nan_rollback and not (loss == loss and abs(loss) < 1e9):
            log.warning("non-finite loss %.3g at step %d; rolling back", loss, step)
            restored, from_step = ckpt_lib.restore_latest(
                loop_cfg.ckpt_dir, state, shardings=shardings)
            if restored is None:
                raise RuntimeError("NaN before first checkpoint")
            state = restored
            step = int(state.step)
            rollbacks += 1
            if rollbacks > loop_cfg.max_rollbacks:
                raise RuntimeError("rollback budget exhausted")
            continue

        state = new_state
        losses.append(loss)
        step += 1

        if step % loop_cfg.ckpt_every == 0 or step == loop_cfg.total_steps:
            if isinstance(pending_save, __import__("threading").Thread):
                pending_save.join()
            pending_save = ckpt_lib.save_checkpoint(
                loop_cfg.ckpt_dir, step, state, async_save=loop_cfg.async_ckpt)
        if step % loop_cfg.log_every == 0:
            log.info("step %d loss %.4f", step, loss)

    if isinstance(pending_save, __import__("threading").Thread):
        pending_save.join()
    return LoopResult(state=state, losses=losses, rollbacks=rollbacks,
                      resumed_from=from_step,
                      straggler_fallbacks=pipeline.stats.straggler_fallbacks)
