"""Serving launcher: one ``LLMEngine`` front-end, three backends.

``--backend static`` runs the whole decode as ONE jitted ``lax.scan`` (no
per-token host dispatch) — the JAX analogue of the RPU's host-free
execution model.  ``--backend continuous`` (also ``--continuous``) runs
iteration-level batching over the block-paged KV cache: requests arrive as
a Poisson process (``--arrival-rate`` req/s) and are admitted into freed
decode slots without recompiling.  ``--backend speculative`` (also
``--speculative``) runs draft/target speculative decoding (paper Fig 14)
with a reduced draft model.

Per-request generation is a ``SamplingParams``: ``--temperature``,
``--top-k``, ``--top-p``, ``--min-p``, ``--stop-token`` (repeatable), and
``--seed`` apply to every request; ``--sampling-mix`` serves a
heterogeneous mix instead (comma-separated ``temp:top_p[:top_k]`` specs
cycled across requests — all of them share the ONE compiled decode step,
since per-slot sampling params are data, not shapes).

Continuous admission runs **chunked prefill** (``--prefill-chunk`` tokens
per iteration per request) interleaved with decode, and shares prompt
prefixes through the page pool's prefix index (``--num-prompts`` distinct
prompts over ``--num-requests`` requests exercises the sharing;
``--no-prefix-cache`` disables it).  ``--spec-draft reduced --gamma 4``
turns on scheduler-integrated speculative decoding inside the continuous
engine: each occupied slot drafts gamma tokens with a reduced model over
its own paged KV pool, the target verifies them in one multi-token decode
step, and the end-of-run summary reports windows / accepted-per-window /
wasted draft tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
      --batch 4 --prompt-len 64 --max-new 32 [--backend speculative]
  PYTHONPATH=src python -m repro.launch.serve --continuous \
      --num-requests 16 --arrival-rate 50 --batch 4 --num-prompts 4 \
      --sampling-mix 0.0:1.0,0.8:0.9:40,1.0:0.95
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch.mesh import make_small_mesh, parse_mesh
from repro.models.model import build_model
from repro.parallel.hints import sharding_rules
from repro.parallel.plan import make_plan
from repro.quant import formats
from repro.runtime.deployment import DeploymentSpec
from repro.runtime.llm import LLMEngine
from repro.runtime.sampling import SamplingParams

# "fp8" / "int8" are the quantized page pools from repro.quant.kv: codes in
# the narrow dtype + per-token-per-KV-head f32 scales riding in the pool.
CACHE_DTYPES = {"bf16": jnp.bfloat16, "f16": jnp.float16, "f32": jnp.float32,
                "fp8": "fp8", "int8": "int8"}


def parse_mix(spec: str, base: SamplingParams) -> list[SamplingParams]:
    """``temp:top_p[:top_k]`` specs, comma-separated, cycled per request."""
    out = []
    for part in spec.split(","):
        fields = part.split(":")
        if not 2 <= len(fields) <= 3:
            raise ValueError(f"bad --sampling-mix entry {part!r} "
                             "(want temp:top_p[:top_k])")
        out.append(SamplingParams(
            temperature=float(fields[0]), top_p=float(fields[1]),
            top_k=int(fields[2]) if len(fields) == 3 else 0,
            min_p=base.min_p, seed=base.seed,
            stop_token_ids=base.stop_token_ids))
    return out


def main(argv=None) -> int:
    import sys
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--fleet" in argv:
        # fleet-level serving (router + simulator + autoscaler) has its
        # own argument surface — delegate everything else to it
        argv.remove("--fleet")
        from repro.launch.fleet import main as fleet_main
        return fleet_main(argv)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--backend", default=None,
                    choices=["static", "continuous", "speculative"])
    ap.add_argument("--continuous", action="store_true",
                    help="alias for --backend continuous")
    ap.add_argument("--speculative", action="store_true",
                    help="alias for --backend speculative")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    # -- per-request sampling -------------------------------------------
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--min-p", type=float, default=0.0)
    ap.add_argument("--stop-token", type=int, action="append", default=[],
                    help="finish a request when this token id is emitted "
                         "(repeatable)")
    ap.add_argument("--sampling-mix", default=None,
                    help="comma-separated temp:top_p[:top_k] specs cycled "
                         "across requests (heterogeneous per-slot mix "
                         "through one compiled decode step)")
    # -- continuous-batching knobs --------------------------------------
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson request arrival rate in req/s "
                         "(0 = all requests arrive at t=0)")
    ap.add_argument("--num-requests", type=int, default=0,
                    help="total requests for continuous (default 3x batch)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size in tokens for continuous")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="prefill chunk size in tokens for continuous")
    ap.add_argument("--num-prompts", type=int, default=0,
                    help="distinct prompts for continuous (0 = all "
                         "distinct; lower values share prefixes)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false", default=True,
                    help="disable prompt-prefix page sharing")
    ap.add_argument("--spec-draft", default=None,
                    choices=["self", "reduced"],
                    help="scheduler-integrated speculative decoding for the "
                         "continuous backend: 'reduced' drafts with an "
                         "n_layers/4 copy of the target, 'self' with the "
                         "target itself (acceptance ~1; a plumbing check)")
    ap.add_argument("--gamma", type=int, default=4,
                    help="draft lookahead per speculative window")
    ap.add_argument("--disaggregate", default=None, metavar="P:D",
                    help="phase-split continuous serving behind the KV-page "
                         "handoff: with --mesh, prefill runs on the first P "
                         "and decode on the next D device slices of the "
                         "model axis (P+D <= its size); without --mesh the "
                         "two phase engines share the host device")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard the continuous serve path over a "
                         "(data=D, model=M) mesh: KV page pools split "
                         "per KV head over the model axis (e.g. --mesh 2x4 "
                         "with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    ap.add_argument("--tp-reduce", default="auto",
                    choices=["auto", "gather", "psum"],
                    help="how each Megatron column pair closes on the mesh: "
                         "gather = bit-exact all-gather composition (CPU "
                         "default), psum = one f32 psum per attention/MLP "
                         "block (accelerator default)")
    # -- hardware-aware deployment (DeploymentSpec) ----------------------
    ap.add_argument("--sku", default=None,
                    help="deployment hardware point: rpu-cu | tpu-v5e | "
                         "h100 | h200.  Giving --sku/--hbmco/--weight-"
                         "format switches the engine to the DeploymentSpec "
                         "path: KV pool pages and decode slots are derived "
                         "from the per-device memory budget and the "
                         "bandwidth roofline instead of --batch")
    ap.add_argument("--hbmco", default=None,
                    help="HBM-CO memory stack: hbm3e-like | hbmco-768MB | "
                         "co-r<R>c<C>b<B>m<MB> (paper Fig-5 design-space "
                         "naming)")
    ap.add_argument("--weight-format", default=None,
                    choices=sorted(formats.FORMATS),
                    help="block-quantized weight format for the capacity "
                         "budget (the RPU streams compressed weights, §V)")
    ap.add_argument("--cache-dtype", default=None,
                    choices=sorted(CACHE_DTYPES),
                    help="KV page-pool dtype (default: engine default); "
                         "fp8/int8 store quantized codes + per-token scales "
                         "in the pool (continuous backend only)")
    ap.add_argument("--max-slots", type=int, default=32,
                    help="cap on the spec-derived decode slot count")
    ap.add_argument("--seed", type=int, default=0,
                    help="model-init seed AND per-request sampling seed")
    args = ap.parse_args(argv)
    backend = args.backend or ("continuous" if args.continuous else
                               "speculative" if args.speculative else
                               "static")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if not cfg.has_decode:
        print(f"{cfg.name} is encoder-only: no decode step")
        return 1
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    serve_mesh = parse_mesh(args.mesh) if args.mesh else None
    if serve_mesh is not None and backend != "continuous":
        print("--mesh shards the continuous backend; "
              f"ignoring it for backend={backend}")
        serve_mesh = None
    disagg = None
    if args.disaggregate:
        if backend != "continuous":
            print("--disaggregate splits the continuous backend; "
                  f"ignoring it for backend={backend}")
        else:
            try:
                p_dev, d_dev = (int(x) for x in args.disaggregate.split(":"))
            except ValueError:
                print(f"--disaggregate wants 'P:D', got "
                      f"{args.disaggregate!r}")
                return 1
            disagg = (p_dev, d_dev)
    pmesh = dmesh = serve_mesh
    if disagg is not None and serve_mesh is not None:
        from repro.parallel.plan import split_mesh
        pmesh, dmesh = split_mesh(serve_mesh, disagg[0], disagg[1])
    mesh = make_small_mesh()
    plan = make_plan(cfg, mesh, global_batch=args.batch, shape_kind="decode")
    max_len = args.prompt_len + args.max_new + 1
    if args.spec_draft is not None and backend == "continuous":
        # verify windows may overshoot by up to gamma draft positions
        # before rollback, so slots need that much page headroom
        max_len += args.gamma

    cache_dtype = CACHE_DTYPES.get(args.cache_dtype)
    spec = None
    if args.sku or args.hbmco or args.weight_format:
        spec = DeploymentSpec(
            sku=args.sku or "rpu-cu", hbmco=args.hbmco,
            mesh=serve_mesh, tp_reduce=args.tp_reduce,
            weight_format=args.weight_format, cache_dtype=cache_dtype,
            max_len=max_len, page_size=args.page_size,
            prefill_chunk=args.prefill_chunk, max_slots=args.max_slots)

    base = SamplingParams(
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        min_p=args.min_p, seed=args.seed,
        stop_token_ids=tuple(args.stop_token))

    spec_cfg = None
    if args.spec_draft is not None:
        if backend != "continuous":
            print(f"--spec-draft configures the continuous backend; "
                  f"ignoring it for backend={backend}")
        else:
            import dataclasses

            from repro.runtime.speculative import SpeculativeConfig
            if args.spec_draft == "reduced":
                draft_cfg = dataclasses.replace(
                    cfg, name=cfg.name + "-draft",
                    n_layers=max(2, cfg.n_layers // 4))
                draft = build_model(draft_cfg)
                spec_cfg = SpeculativeConfig(
                    draft_model=draft,
                    draft_params=draft.init(jax.random.fold_in(key, 3)),
                    gamma=args.gamma)
            else:                        # "self": target drafts for itself
                spec_cfg = SpeculativeConfig(gamma=args.gamma)

    with mesh, sharding_rules(plan.rules()):
        if backend == "continuous":
            n_req = args.num_requests or 3 * args.batch
            rng = np.random.default_rng(args.seed)
            gaps = (rng.exponential(1.0 / args.arrival_rate, n_req)
                    if args.arrival_rate > 0 else np.zeros(n_req))
            arrivals = np.cumsum(gaps)
            n_distinct = args.num_prompts or n_req
            pool_prompts = np.asarray(jax.random.randint(
                jax.random.fold_in(key, 4), (n_distinct, args.prompt_len), 0,
                cfg.vocab_size))
            picks = np.random.default_rng(args.seed + 1).integers(
                0, n_distinct, n_req)
            mix = parse_mix(args.sampling_mix, base) if args.sampling_mix \
                else [base]
            sps = [mix[i % len(mix)] for i in range(n_req)]
            dkw = dict(disaggregate=disagg is not None,
                       prefill_mesh=pmesh, decode_mesh=dmesh)
            if spec is not None:
                # hardware-derived pool/slots — no manual num_pages knob
                llm = LLMEngine(model, params, backend="continuous",
                                spec=spec, speculative=spec_cfg,
                                enable_prefix_cache=args.prefix_cache,
                                **dkw)
                print(llm.deployment.describe())
                if disagg is not None:
                    print(llm._eng.prefill.deployment.describe())
                slots = llm._eng.num_slots
            else:
                slots = args.batch
                llm = LLMEngine(
                    model, params, backend="continuous", max_len=max_len,
                    num_slots=slots, page_size=args.page_size,
                    num_pages=1 + slots * -(-max_len // args.page_size) * 2,
                    prefill_chunk=args.prefill_chunk,
                    cache_dtype=cache_dtype,
                    enable_prefix_cache=args.prefix_cache, mesh=serve_mesh,
                    tp_reduce=args.tp_reduce, speculative=spec_cfg, **dkw)
            t0 = time.time()
            outs = llm.generate([pool_prompts[picks[i]] for i in range(n_req)],
                                sps, max_new_tokens=args.max_new,
                                arrival_times=arrivals)
            dt = time.time() - t0
            stats = llm.last_stats
            n_tok = sum(len(o.token_ids) for o in outs)
            print(f"arch={cfg.name} continuous slots={slots} "
                  f"requests={n_req} rate={args.arrival_rate}/s "
                  f"steps={stats.steps} occupancy={stats.occupancy:.2f} "
                  f"preemptions={stats.preemptions}")
            if serve_mesh is not None:
                sp = llm.serve_plan
                print(f"mesh: data={serve_mesh.shape['data']} x "
                      f"model={serve_mesh.shape['model']} "
                      f"(reduce={sp.reduce}) — "
                      f"{llm.kv_token_bytes_per_device()} KV bytes/token "
                      f"per device, "
                      f"{sp.psum_bytes_per_step(model, slots)}"
                      f" collective bytes/step per device")
            if args.sampling_mix:
                print(f"sampling mix: {args.sampling_mix} "
                      f"(one decode-step signature, per-slot data)")
            print(f"tokens={n_tok} wall={dt:.2f}s "
                  f"({n_tok / dt:.1f} tok/s incl. compile)")
            print(f"prefill: {stats.chunks} chunks, "
                  f"{stats.prefill_tokens}/{stats.prompt_tokens} prompt "
                  f"tokens computed, prefix hit rate "
                  f"{stats.prefix_hit_rate:.2f}, cow={stats.cow_events}")
            if spec_cfg is not None:
                print(f"speculative: gamma={args.gamma} "
                      f"draft={args.spec_draft} "
                      f"windows={stats.spec_windows} "
                      f"accepted/window={stats.accepted_per_window:.2f} "
                      f"drafted={stats.spec_drafted} "
                      f"wasted={stats.spec_wasted}")
            if disagg is not None:
                print(f"handoff: {stats.handoffs} chains, "
                      f"{stats.handoff_pages} pages, "
                      f"{stats.handoff_bytes} bytes, "
                      f"{stats.handoff_shared_tokens} prefix-shared tokens")
            q = stats.ttft_quantiles()
            if q is not None:
                print(f"ttft p50={q[0] * 1e3:.1f}ms p99={q[1] * 1e3:.1f}ms")
            reasons = {}
            for o in outs:
                reasons[o.finish_reason] = reasons.get(o.finish_reason, 0) + 1
            if spec_cfg is not None:
                per_req = " ".join(
                    f"r{rid}:p{st['preemptions']}/c{st['chunks']}"
                    f"/w{st['spec_windows']}/a{st['spec_accepted']}"
                    for rid, st in sorted(stats.per_request.items()))
            else:
                per_req = " ".join(
                    f"r{rid}:p{st['preemptions']}/c{st['chunks']}"
                    for rid, st in sorted(stats.per_request.items()))
            print(f"finish reasons: {reasons}")
            print(f"per-request preemptions/chunks: {per_req}")
            print("sample:", outs[0].token_ids[:16])
            return 0

        prompts = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size))
        if cfg.frontend == "vision" and backend == "static":
            # vision frontends serve batch dicts (tokens + image embeds)
            # through ServeEngine directly; LLMEngine fronts token-only
            # requests
            from repro.runtime.engine import ServeEngine
            batch = {"tokens": jnp.asarray(prompts),
                     "image_embeds": jax.random.normal(
                         jax.random.fold_in(key, 2),
                         (args.batch, 8, cfg.d_model), jnp.bfloat16)}
            eng = ServeEngine(model, params, max_len=max_len + 8)
            t0 = time.time()
            out = eng.generate(batch, max_new_tokens=args.max_new,
                               sampling_params=base)
            dt = time.time() - t0
            toks = np.asarray(out.tokens)
            n_tok = toks.size
            print(f"arch={cfg.name} backend=static(vision) "
                  f"batch={args.batch} new_tokens={toks.shape[1]} "
                  f"wall={dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile)")
            print("sample:", toks[0, :16].tolist())
            return 0
        if backend == "speculative":
            import dataclasses
            draft_cfg = dataclasses.replace(
                cfg, name=cfg.name + "-draft",
                n_layers=max(2, cfg.n_layers // 4))
            draft = build_model(draft_cfg)
            draft_params = draft.init(jax.random.fold_in(key, 3))
            llm = LLMEngine(model, params, backend="speculative",
                            max_len=max_len, draft_model=draft,
                            draft_params=draft_params, gamma=4)
            t0 = time.time()
            outs = llm.generate(prompts[:1], base, max_new_tokens=args.max_new)
            dt = time.time() - t0
            m = outs[0].metrics
            print(f"speculative: accepted/window="
                  f"{m['accepted_per_window']:.2f} over {m['windows']} windows")
        else:
            llm = LLMEngine(model, params, backend="static", max_len=max_len,
                            spec=spec, cache_dtype=cache_dtype)
            if spec is not None:
                print(llm._eng.deployment.describe())
            t0 = time.time()
            outs = llm.generate(prompts, base, max_new_tokens=args.max_new)
            dt = time.time() - t0

    n_tok = sum(len(o.token_ids) for o in outs)
    print(f"arch={cfg.name} backend={backend} batch={len(outs)} "
          f"new_tokens={len(outs[0].token_ids)} "
          f"wall={dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile)")
    print("sample:", outs[0].token_ids[:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
