"""Per-architecture smoke tests (reduced configs, per the assignment) +
prefill/decode equivalence for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, PAPER_ARCHS, get_config,
                           list_configs, reduced_config)
from repro.models.footprint import compute_footprint
from repro.models.model import build_model
from tests.conftest import make_batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.slow
def test_smoke_forward_and_train_step(arch, key):
    """One forward + one train step on a reduced same-family config;
    asserts output shapes and finiteness (the assignment's smoke test)."""
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, key)
    logits = model.forward(params, batch)
    s_expect = 32 + (8 if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, s_expect, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_matches_forward(arch, key):
    """Teacher-forced prefill+decode logits == full-forward logits."""
    cfg = reduced_config(get_config(arch))
    if not cfg.has_decode:
        pytest.skip("encoder-only")
    model = build_model(cfg)
    params = model.init(key)
    B, S, G = 2, 16, 4
    ni = 8 if cfg.frontend == "vision" else 0
    toks = jax.random.randint(key, (B, S + G), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if ni:
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 7), (B, ni, cfg.d_model), jnp.bfloat16)
    cache = model.init_cache(B, ni + S + G)
    logits, cache = model.prefill(params, batch, cache)
    got = [logits]
    for i in range(G):
        logits, cache = model.decode_step(params, toks[:, S + i], cache,
                                          jnp.int32(ni + S + i))
        got.append(logits)
    full_b = dict(batch)
    full_b["tokens"] = toks
    full = model.forward(params, full_b)
    want = full[:, ni + S - 1:ni + S + G].astype(np.float32)
    got = jnp.stack(got, 1).astype(np.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.15, rtol=0.05)


def test_all_configs_loadable():
    for name in list_configs():
        cfg = get_config(name)
        assert cfg.n_layers > 0 and cfg.d_model > 0


@pytest.mark.parametrize("arch,expected_b", [
    ("qwen2.5-14b", 14.8), ("qwen3-14b", 14.8), ("phi3-mini-3.8b", 3.8),
    ("h2o-danube-1.8b", 1.8), ("mamba2-370m", 0.37), ("hymba-1.5b", 1.5),
    ("deepseek-v2-lite-16b", 15.7), ("llama4-maverick-400b-a17b", 400.0),
    ("internvl2-26b", 20.0), ("hubert-xlarge", 1.26),
])
def test_param_counts_match_published(arch, expected_b):
    """Total params from the exact configs land near the published sizes.
    (internvl2: LM backbone only — the ViT frontend is a stub per the
    assignment; hubert: the assigned dims with this framework's gated MLP
    give 1.26B vs the original ~0.96B non-gated encoder.)"""
    fp = compute_footprint(get_config(arch))
    got_b = fp.total_params / 1e9
    assert got_b == pytest.approx(expected_b, rel=0.30), got_b


def test_sliding_window_bounds_attention(key):
    """SWA: moving a token far outside the window must not change logits."""
    cfg = reduced_config(get_config("h2o-danube-1.8b"))
    assert cfg.sliding_window == 8
    model = build_model(cfg)
    params = model.init(key)
    S = 32
    t1 = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab_size)
    l1 = model.forward(params, {"tokens": t1})
    l2 = model.forward(params, {"tokens": t2})
    # last position attends only to the trailing window: unaffected
    np.testing.assert_allclose(
        np.asarray(l1[0, -1].astype(np.float32)),
        np.asarray(l2[0, -1].astype(np.float32)), atol=1e-3)
    # within-window positions DO change
    assert float(jnp.max(jnp.abs((l1[0, 1] - l2[0, 1]).astype(np.float32)))) > 1e-3


def test_vocab_padding_masks_logits(key):
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-14b")),
                              vocab_size=250, vocab_pad_multiple=128)
    assert cfg.padded_vocab == 256
    model = build_model(cfg)
    params = model.init(key)
    logits = model.forward(params, {"tokens": jnp.zeros((1, 8), jnp.int32)})
    assert logits.shape[-1] == 256
    assert bool(jnp.all(logits[..., 250:] <= -1e29))
    loss = model.loss(params, {"tokens": jnp.zeros((1, 8), jnp.int32)})
    assert np.isfinite(float(loss))


def test_moe_capacity_matches_dense_when_ample(key):
    """With generous capacity, the production MoE path == dense reference."""
    from repro.models import moe as moe_lib
    cfg = reduced_config(get_config("llama4-maverick-400b-a17b"))
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    dense = moe_lib.moe_dense(x, p, cfg)
    cap = moe_lib.moe_capacity(x, p, cfg, capacity_factor=float(cfg.n_experts))
    np.testing.assert_allclose(np.asarray(cap.astype(np.float32)),
                               np.asarray(dense.astype(np.float32)),
                               atol=0.08, rtol=0.05)


def test_mamba2_chunked_equals_decode_chain(key):
    """SSD chunked prefill state == sequential decode recurrence state."""
    from repro.models import ssm as ssm_lib
    cfg = reduced_config(get_config("mamba2-370m"))
    p = ssm_lib.init_ssm(key, cfg)
    B, S = 1, 24
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model),
                          jnp.bfloat16) * 0.3
    y_seq, st_seq = ssm_lib.ssm_forward(x, p, cfg, None)
    st = ssm_lib.init_ssm_state(cfg, B)
    ys = []
    for t in range(S):
        y, st = ssm_lib.ssm_decode_step(x[:, t], p, cfg, st)
        ys.append(y)
    y_dec = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_dec.astype(np.float32)),
                               np.asarray(y_seq.astype(np.float32)),
                               atol=0.08, rtol=0.1)
    np.testing.assert_allclose(np.asarray(st["ssm"]), np.asarray(st_seq["ssm"]),
                               atol=0.05, rtol=0.1)
