"""The assigned-architecture roofline table: reads the dry-run JSONs in
experiments/dryrun/ and renders the per-(arch x shape x mesh) three-term
roofline with dominant-bottleneck calls (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import EXP_DIR, Row

DRYRUN_DIR = EXP_DIR / "dryrun"


def load_cells() -> list[dict]:
    cells = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def render_markdown(cells: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful flops | plan |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for c in cells:
        if c.get("status") != "ok":
            continue
        plan = c.get("plan", {})
        pl = f"dp={'+'.join(plan.get('dp', []) or ['-'])}"
        if plan.get("fsdp"):
            pl += " fsdp"
        if plan.get("seq_parallel"):
            pl += " sp"
        if plan.get("cache_seq"):
            cs = plan["cache_seq"]
            pl += f" kv/{'+'.join(cs) if isinstance(cs, list) else cs}"
        u = c.get("useful_flops_ratio")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.3g} | {c['memory_s']:.3g} "
            f"| {c['collective_s']:.3g} | {c['dominant']} "
            f"| {u:.3f} | {pl} |" if u is not None else "")
    return hdr + "\n".join(l for l in lines if l)


def run() -> list[Row]:
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    rows = [Row("ours:roofline", "dry-run cells recorded", len(ok), None, "",
                f"of {len(cells)} json files")]
    if not ok:
        return rows
    by_dom: dict[str, int] = {}
    for c in ok:
        by_dom[c["dominant"]] = by_dom.get(c["dominant"], 0) + 1
    rows.append(Row("ours:roofline", "dominant-term distribution",
                    str(by_dom)))
    worst = min(ok, key=lambda c: (c.get("useful_flops_ratio") or 1.0))
    rows.append(Row("ours:roofline", "worst useful-flops cell",
                    f"{worst['arch']} x {worst['shape']}",
                    None, "", f"ratio {worst.get('useful_flops_ratio'):.3f}"))
    most_coll = max(ok, key=lambda c: c["collective_s"] / max(c["bound_s"], 1e-12))
    rows.append(Row("ours:roofline", "most collective-bound cell",
                    f"{most_coll['arch']} x {most_coll['shape']}",
                    None, "",
                    f"coll {most_coll['collective_s']*1e3:.1f}ms of bound "
                    f"{most_coll['bound_s']*1e3:.1f}ms"))
    return rows


if __name__ == "__main__":
    print(render_markdown(load_cells()))
