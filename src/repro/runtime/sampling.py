"""Token sampling for the serve path (fp32 HP-VOPs analogue)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(key, logits: jnp.ndarray, temperature: float = 1.0,
           top_k: int = 0) -> jnp.ndarray:
    """Temperature / top-k sampling.  logits: (..., V) -> (...) int32."""
    if temperature <= 0.0:
        return greedy(logits)
    lg = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jnp.sort(lg, axis=-1)[..., -top_k][..., None]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def probs(logits: jnp.ndarray, temperature: float = 1.0) -> jnp.ndarray:
    return jax.nn.softmax(logits.astype(jnp.float32) / max(temperature, 1e-6),
                          axis=-1)
