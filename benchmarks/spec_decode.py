"""Paper Fig 14: speculative decoding — analytic RPU point + a MEASURED
draft/target comparison on the real continuous engine.

``run()`` (used by ``benchmarks.run``) keeps the paper-anchored analytic
rows: Llama3-70B target / Llama3-8B draft on the RPU-200CU roofline
(8-token lookahead, 4.6 accepted/window, 1.8x).

``main()`` measures the scheduler-integrated speculative mode end to end
on XLA:CPU (f32): the SAME Poisson-free greedy trace served by the
continuous engine with and without a draft.  The draft is the target's
own first ``--draft-layers`` layers (sliced stacked weights, shared
embed/head); the target's deeper blocks are damped (out-projections
scaled by ``--damp``) so the draft agrees with the target often enough
to measure a real speedup — the same high-acceptance regime the paper's
Fig 14 assumes, scaled to a toy model.  Greedy speculation is lossless,
so the benchmark also ASSERTS byte-identical outputs between the two
engines; ``--assert-speedup`` additionally gates on >= 1.3x useful
tokens/s (the slow CI tier runs this).

Measured accepted-per-window is reported against the DeploymentSpec
window model evaluated AT the measured per-token acceptance rate
(``alpha(1-alpha^g)/(1-alpha)`` — i.i.d. acceptance assumption), so the
JSON artifact carries modeled-vs-measured for both throughput and
acceptance.

  PYTHONPATH=src python -m benchmarks.spec_decode [--gamma 4] \
      [--requests 12] [--assert-speedup]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, dump
from repro.configs import get_config
from repro.models.common import ModelConfig
from repro.models.model import build_model
from repro.runtime.deployment import DeploymentSpec
from repro.runtime.llm import LLMEngine
from repro.runtime.sampling import SamplingParams
from repro.runtime.speculative import SpeculativeConfig
from repro.sim.scaling import rpu_point

PUBLISHED_TOKENS_PER_S = {
    "NVIDIA H200": 134, "SambaNova": 457, "Groq LPU": 1678,
    "Cerebras WSE-3": 2148, "RPU (paper)": 4423,
}


def run() -> list[Row]:
    """Analytic Fig 14 rows on the RPU roofline (paper's window stats)."""
    cfg70 = get_config("llama3-70b")
    cfg8 = get_config("llama3-8b")
    # RPU-200CU base decode latency for the 70B target + 8B draft steps.
    p70 = rpu_point(cfg70, 200, batch=1, seq_len=8192)
    p8 = rpu_point(cfg8, 200, batch=1, seq_len=8192)
    gamma, accepted = 8, 4.6                      # paper's window stats
    # one window: gamma draft steps + 1 target verification pass (the
    # verification VMM streams the same weights once — like one target step)
    window_s = gamma * p8.ms_per_token * 1e-3 + p70.ms_per_token * 1e-3
    toks_per_s = accepted / window_s
    base_tps = 1e3 / p70.ms_per_token
    rows = [
        Row("Fig14", "RPU-200CU 70B base decode", base_tps, None, " tok/s"),
        Row("Fig14", "RPU-200CU speculative throughput", toks_per_s, 4423,
            " tok/s", f"{gamma}-lookahead, {accepted} accepted"),
        Row("Fig14", "speculative speedup", toks_per_s / base_tps, 1.8, "x"),
    ]
    for sys_name, tps in PUBLISHED_TOKENS_PER_S.items():
        rows.append(Row("Fig14", f"published: {sys_name}", tps, None,
                        " tok/s"))
    rows.append(Row("Fig14", "RPU(ours)/best-competitor",
                    toks_per_s / 2148, 4423 / 2148, "x", "vs Cerebras WSE-3"))
    return rows


# ---------------------------------------------------------------------------
# Measured: the real continuous engine, spec vs non-spec, same trace
# ---------------------------------------------------------------------------

PROMPT_LEN = 16
PAGE = 40             # 2 blocks/request at max_len 64


def bench_config(n_layers: int) -> ModelConfig:
    return ModelConfig(
        name="bench-spec", family="dense", n_layers=n_layers, d_model=384,
        n_heads=8, n_kv_heads=4, head_dim=48, d_ff=1024, vocab_size=2048)


def _damp_deep_blocks(params, keep: int, eps: float):
    """Scale the residual out-projections of blocks >= ``keep`` by
    ``eps``: the deep layers barely move the hidden state, so the
    truncated draft's argmax tracks the target's."""
    def go(d):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                out[k] = go(v)
            elif k in ("wo", "w_down"):
                out[k] = v.at[keep:].multiply(eps)
            else:
                out[k] = v
        return out
    params = dict(params)
    params["stacks"] = [tuple(go(blk) for blk in stack)
                        for stack in params["stacks"]]
    return params


def build_pair(n_layers: int, draft_layers: int, damp: float, seed: int):
    """Target + draft sharing weights: the draft IS the target's first
    ``draft_layers`` layers (stacked-leaf slices) with the same
    embed/head, so draft cost ~ draft_layers/n_layers of a target step."""
    cfg = bench_config(n_layers)
    model = build_model(cfg)
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        model.init(jax.random.PRNGKey(seed)))
    params = _damp_deep_blocks(params, draft_layers, damp)
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft",
                               n_layers=draft_layers)
    draft = build_model(dcfg)
    dparams = dict(params)
    # one-layer stacks are UNSTACKED (no lax.scan leading axis)
    take = (lambda a: a[0]) if draft_layers == 1 \
        else (lambda a: a[:draft_layers])
    dparams["stacks"] = jax.tree.map(take, params["stacks"])
    return model, params, draft, dparams


def run_measured(gamma: int, slots: int, n_req: int, max_new: int,
                 n_layers: int, draft_layers: int, damp: float,
                 seed: int, reps: int = 2) -> tuple[list[Row], float]:
    model, params, draft, dparams = build_pair(n_layers, draft_layers,
                                               damp, seed)
    # + gamma: verify windows scatter KV past the last emitted token
    max_len = PROMPT_LEN + max_new + gamma
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, model.cfg.vocab_size,
                           (n_req, PROMPT_LEN)).astype(np.int32)

    def make(spec_cfg):
        return LLMEngine(
            model, params, backend="continuous", num_slots=slots,
            page_size=PAGE, num_pages=1 + 2 * slots * -(-max_len // PAGE),
            max_len=max_len, cache_dtype=jnp.float32,
            prefill_chunk=PROMPT_LEN, speculative=spec_cfg)

    base = make(None)
    spec = make(SpeculativeConfig(draft_model=draft, draft_params=dparams,
                                  gamma=gamma))
    for llm in (base, spec):
        b = 1                 # compile every pow-2 admission bucket
        while b <= slots:
            llm.generate([prompts[0]] * b, max_new_tokens=2)
            b *= 2

    def serve(llm):
        outs = llm.generate(list(prompts), max_new_tokens=max_new)
        return llm.last_stats, [tuple(o.token_ids) for o in outs]

    # best-of-N: wall-clock on a shared machine, keep the least-interfered
    (bstats, bres) = min((serve(base) for _ in range(reps)),
                         key=lambda r: r[0].wall)
    (sstats, sres) = min((serve(spec) for _ in range(reps)),
                         key=lambda r: r[0].wall)
    assert bres == sres, \
        "greedy speculation must be byte-identical to the plain engine"

    base_tps = bstats.total_tokens / bstats.wall
    spec_tps = sstats.total_tokens / sstats.wall
    speedup = spec_tps / base_tps
    alpha = sstats.spec_accepted / max(sstats.spec_drafted, 1)
    # the DeploymentSpec window model AT the measured acceptance rate
    dep = DeploymentSpec(sku="rpu-cu", max_len=max_len, page_size=PAGE,
                         max_slots=slots).resolve(
        model, draft=draft, draft_params=dparams, gamma=gamma,
        spec_accept_rate=alpha)
    plain_dep = DeploymentSpec(sku="rpu-cu", max_len=max_len,
                               page_size=PAGE, max_slots=slots).resolve(model)
    modeled_speedup = (dep.spec_tokens_per_s_ceiling
                       / plain_dep.tokens_per_s_ceiling)
    rows = [
        Row("ours:spec", f"non-spec slots={slots} useful tok/s", base_tps,
            None, "", f"wall {bstats.wall:.2f}s, {bstats.steps} steps"),
        Row("ours:spec", f"speculative gamma={gamma} useful tok/s", spec_tps,
            None, "",
            f"wall {sstats.wall:.2f}s, {sstats.spec_windows} windows, "
            f"draft {draft_layers}/{n_layers} layers"),
        Row("ours:spec", "measured speedup", speedup, None, "x",
            f"{n_req} greedy requests, byte-identical outputs"),
        Row("ours:spec", "accepted/window (measured)",
            sstats.accepted_per_window, None, "",
            f"of gamma={gamma} drafted; {sstats.spec_wasted} draft "
            f"tokens wasted"),
        Row("ours:spec", "accepted/window (modeled)",
            dep.spec_expected_accepted, None, "",
            f"alpha(1-alpha^g)/(1-alpha) at measured alpha={alpha:.3f}"),
        Row("ours:spec", "per-token acceptance rate", alpha, None, "",
            "accepted draft proposals / drafted"),
        Row("ours:spec", "modeled window speedup (RPU roofline)",
            modeled_speedup, None, "x",
            f"{dep.spec_window_seconds * 1e6:.1f}us window vs "
            f"{plain_dep.step_seconds * 1e6:.1f}us step on "
            "target hardware (not the CPU host)"),
    ]
    return rows, speedup


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--draft-layers", type=int, default=2)
    ap.add_argument("--damp", type=float, default=0.005,
                    help="scale on deep-block out-projections (lower = "
                         "higher draft/target agreement)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--assert-speedup", type=float, nargs="?",
                    const=1.3, default=None,
                    help="fail unless measured speedup >= this (CI gate; "
                         "default 1.3 when given without a value)")
    args = ap.parse_args(argv)
    t0 = time.time()
    rows, speedup = run_measured(
        args.gamma, args.slots, args.requests, args.max_new, args.layers,
        args.draft_layers, args.damp, args.seed, args.reps)
    rows += run()                      # analytic paper anchor in the same JSON
    for r in rows:
        print(r.render())
    dump(rows, "spec_decode")
    print(f"[{time.time() - t0:.1f}s] speedup {speedup:.2f}x "
          f"-> experiments/bench_spec_decode.json")
    if args.assert_speedup is not None:
        assert speedup >= args.assert_speedup, \
            f"speculative speedup {speedup:.2f}x < {args.assert_speedup}x"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
