"""Quantized serve execution: param-tree quantization + matmul dispatch.

``DeploymentSpec.weight_format`` stops being a pricing fiction here: the
serve engines call ``quantize_params`` at construction, replacing every
eligible projection weight (attn/MLP, dense blocks only) with its packed
block-quantized form from ``quant/formats.py``, and the model code routes
the affected matmuls through ``qdot`` — the Pallas MXFP4 VMM kernel for
``mxfp4`` (jnp oracle on CPU), dequantize-then-matmul for every other
format.  Packed leaves carry their per-layer logical ``(K, N)`` shape as
pytree aux data, so ``lax.scan`` over stacked layer weights slices the
code/scale children and each sliced element stays self-consistent.

``serve_weight_bytes`` is the budget side of the same coin: it prices a
param tree with the *exact* packed bytes ``quantize_params`` would
allocate (quantizable leaves) plus native bytes for everything else, so
``DeploymentSpec.resolve`` reports the bytes actually resident.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mxfp4_vmm.ops import mxfp4_matmul
from repro.quant import formats

# projection leaves the serve path streams through the software stream
# decoder; everything else (norms, biases, embeddings, router/expert and
# SSM weights) keeps its native dtype
QUANT_KEYS = frozenset({"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"})
# replicated / non-dense subtrees never quantize (MoE experts contract
# via einsum; SSM state kernels are not K-major streams)
SKIP_SUBTREES = frozenset({"moe", "ssm"})


def _path_dict_keys(path) -> list:
    return [e.key for e in path if isinstance(e, jax.tree_util.DictKey)]


def quantizable_leaf(path, leaf, fmt: str) -> bool:
    """True when ``quantize_params`` packs this leaf under ``fmt``."""
    names = _path_dict_keys(path)
    if not names or names[-1] not in QUANT_KEYS:
        return False
    if any(n in SKIP_SUBTREES for n in names):
        return False
    if getattr(leaf, "ndim", 0) < 2:
        return False
    return leaf.shape[-2] % formats.format_spec(fmt).block == 0


def _quantize_leaf(w: jnp.ndarray, fmt: str):
    """Pack one (possibly layer-stacked) weight; aux shape is the
    per-layer (K, N) so scanned slices stay self-consistent."""
    p = formats.quantize(w, fmt)
    children, _ = p.tree_flatten()
    return type(p).tree_unflatten(tuple(w.shape[-2:]), children)


def quantize_params(params, fmt: str):
    """Quantize every eligible projection leaf of a model param tree to
    ``fmt``; all other leaves pass through unchanged."""
    fmt = formats.canonical_format(fmt)

    def fn(path, leaf):
        if quantizable_leaf(path, leaf, fmt):
            return _quantize_leaf(leaf, fmt)
        return leaf

    return jax.tree_util.tree_map_with_path(fn, params)


def serve_weight_bytes(params, fmt: str | None) -> int:
    """Total bytes the serve params occupy under ``fmt`` (None = native):
    exact packed bytes for quantizable leaves, native ``nbytes`` for the
    rest — the number ``quantize_params`` actually allocates."""
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        if fmt is not None and quantizable_leaf(path, leaf, fmt):
            total += formats.packed_nbytes(leaf.shape, fmt)
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


def is_packed(w) -> bool:
    return isinstance(w, formats.PACKED_TYPES)


def qdot(x: jnp.ndarray, w) -> jnp.ndarray:
    """``x @ w`` where ``w`` may be a packed quantized tensor.

    MXFP4 routes through the ``kernels/mxfp4_vmm`` op (Pallas kernel on
    accelerators, jnp dequant oracle on CPU); other packed formats take
    the dequantize-then-matmul oracle; plain arrays are a native matmul.
    """
    if isinstance(w, formats.PackedMXFP4):
        return mxfp4_matmul(x, w, out_dtype=x.dtype)
    if isinstance(w, formats.PACKED_TYPES):
        return x @ formats.dequantize_any(w, x.dtype)
    return x @ w
