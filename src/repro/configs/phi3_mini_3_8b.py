"""Phi-3-mini-3.8B — RoPE + SwiGLU; kv=32 (full MHA).  [arXiv:2404.14219]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064, vocab_pad_multiple=512,
    rope_theta=10000.0,
)
