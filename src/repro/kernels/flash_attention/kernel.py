"""Flash-attention (forward) Pallas TPU kernel — the fix for the dominant
memory-roofline term of the train/prefill cells (EXPERIMENTS.md §Perf
"beyond-paper"): pure-XLA blocked attention materializes every
(q_block, kv_block) score/probability tile to HBM (~70% of qwen3
train_4k's device traffic); this kernel keeps them in VMEM so the HBM
stream is exactly q + k + v + o.

Mapping onto the RPU story: this is the same insight as the paper's
decoupled memory pipeline + on-chip buffer — keep the phase-local
working set on-chip and stream only the irreducible operands.

Grid: (batch x kv-head groups, q blocks, kv blocks); the kv dimension is
innermost so the (bq, bk) score tile and the output accumulator stay
resident while KV streams.  Causal masking skips fully-masked kv blocks
via ``pl.when``.  fp32 online-softmax state, bf16 streams.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               block_q: int, block_k: int, scale: float, causal: bool,
               n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)

    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0].astype(jnp.float32)                  # (bk, dv)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "interpret"))
def flash_attention(
    q: jnp.ndarray,      # (BH, Sq, D)  — batch x heads flattened
    k: jnp.ndarray,      # (BH, Skv, D)
    v: jnp.ndarray,      # (BH, Skv, Dv)
    *,
    block_q: int = 512,
    block_k: int = 512,
    causal: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused attention forward; returns (BH, Sq, Dv)."""
    bh, sq, d = q.shape
    skv, dv = k.shape[1], v.shape[2]
    assert k.shape == (bh, skv, d) and v.shape == (bh, skv, dv)
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, "pad sequences to block multiples"
    n_q, n_k = sq // bq, skv // bk
    scale = 1.0 / math.sqrt(d)

    grid = (bh, n_q, n_k)
    return pl.pallas_call(
        functools.partial(_fa_kernel, block_q=bq, block_k=bk, scale=scale,
                          causal=causal, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m  (online-softmax max)
            pltpu.VMEM((bq, 1), jnp.float32),    # l  (normalizer)
            pltpu.VMEM((bq, dv), jnp.float32),   # acc (output accumulator)
        ],
        interpret=interpret,
    )(q, k, v)
