"""Stateful cache layouts (``runtime.state_cache``): ring-page
reclamation and SSM/hybrid state pools behind the attention-backend
registry.

Covers the PR-10 acceptance surface: layout classification, RingPageSpace
allocator/refcount invariants through reclamation + release, O(window)
per-slot residency during decode, byte-identity continuous == static for
the SSM and hybrid families under forced preemption-restart and slot
permutation, prefix-cache scoping (no hits from ring or state, hybrid
attention pages still share), and DeploymentSpec residency accounting
that matches the engine's actual pool allocations byte for byte.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models.model import Model, build_model, build_plan
from repro.runtime.deployment import DeploymentError, DeploymentSpec
from repro.runtime.engine import ContinuousServeEngine, ServeEngine
from repro.runtime.kv_cache import SCRATCH_PAGE, PagedKVCache
from repro.runtime.scheduler import Request
from repro.runtime.state_cache import (
    RingPageSpace, model_cache_layout, ring_blocks_cap, ring_pages_needed,
    state_bytes_per_slot,
)


def _hybrid_cfg():
    """A reduced hymba that actually exercises all three residency
    classes: 2-layer reduced configs make every layer global (layer 0 and
    the last layer are always global), so stretch to 3 layers with the
    middle one windowed."""
    return dataclasses.replace(reduced_config(get_config("hymba-1.5b")),
                               n_layers=3, global_attn_every=3)


# ---------------------------------------------------------------------------
# Layout classification
# ---------------------------------------------------------------------------


def test_model_cache_layout_classification():
    ssm = model_cache_layout(build_plan(reduced_config(
        get_config("mamba2-370m"))))
    assert (ssm.has_full, ssm.has_ring, ssm.has_state) == (False, False, True)
    assert ssm.stateful and ssm.ring_window is None

    ring = model_cache_layout(build_plan(reduced_config(
        get_config("h2o-danube-1.8b"))))
    assert (ring.has_full, ring.has_ring, ring.has_state) == (False, True,
                                                              False)
    assert ring.stateful and ring.ring_window == 8

    hyb = model_cache_layout(build_plan(_hybrid_cfg()))
    assert (hyb.has_full, hyb.has_ring, hyb.has_state) == (True, True, True)
    assert hyb.ring_window == 8 and hyb.ring_layers() == 1

    dense = model_cache_layout(build_plan(reduced_config(
        get_config("qwen3-14b"))))
    assert not dense.stateful and dense.has_full


def test_ring_caps():
    assert ring_blocks_cap(8, 4) == 3                  # ceil(8/4)+1
    assert ring_blocks_cap(9, 4) == 4
    # transient bound: +prefill_chunk positions before reclamation runs
    assert ring_pages_needed(num_slots=2, window=8, page_size=4,
                             max_blocks=100, prefill_chunk=4) == 1 + 2 * 4
    # never more than max_blocks per slot
    assert ring_pages_needed(num_slots=2, window=8, page_size=4,
                             max_blocks=3, prefill_chunk=64) == 1 + 2 * 3


# ---------------------------------------------------------------------------
# RingPageSpace invariants
# ---------------------------------------------------------------------------


def test_ring_space_reclaim_release_invariants():
    ring = RingPageSpace(num_slots=3, num_pages=1 + 3 * 4, page_size=4,
                         max_blocks=16, window=8)
    alloc = ring.allocator
    rng = np.random.default_rng(0)
    pos = [0, 0, 0]
    for step in range(300):
        slot = int(rng.integers(0, 3))
        op = rng.integers(0, 10)
        if op < 7:                                     # advance one token
            if ring.ensure(slot, pos[slot]):
                ring.reclaim(slot, pos[slot] + 1)
                pos[slot] += 1
            else:                                      # pool pressure:
                ring.release(slot)                     # preempt-restart
                pos[slot] = 0
        elif op < 9:                                   # mid-stream reclaim
            ring.reclaim(slot, pos[slot])
        else:                                          # finish
            ring.release(slot)
            pos[slot] = 0
        ring.check()
        assert alloc.num_free + alloc.num_live == alloc.num_pages - 1
        for s in range(3):
            # steady-state bound: reclaim runs after every advance
            assert ring.live_blocks(s) <= ring.decode_cap
    # reclaimed blocks read as scratch, live ones never do
    for s in range(3):
        ring.release(s)
        assert all(int(p) == SCRATCH_PAGE for p in ring.table()[s])
    assert alloc.num_live == 0


def test_ring_ensure_all_or_nothing():
    ring = RingPageSpace(num_slots=2, num_pages=4, page_size=4,
                         max_blocks=8, window=8)
    assert ring.ensure(0, 11)                          # 3 blocks
    assert not ring.ensure(1, 7)                       # needs 2, has 0 free
    assert ring.live_blocks(1) == 0                    # nothing leaked
    ring.check()
    ring.release(0)
    assert ring.ensure(1, 7)


def test_prefix_cache_requires_full_space():
    ring = RingPageSpace(num_slots=2, num_pages=8, page_size=4,
                         max_blocks=4, window=8)
    with pytest.raises(ValueError, match="prefix"):
        PagedKVCache(num_slots=2, num_pages=8, page_size=4, max_blocks=4,
                     enable_prefix_cache=True, has_full=False, ring=ring)


def test_state_bytes_per_slot_exact():
    for mk in ("mamba2-370m", "hymba-1.5b"):
        cfg = reduced_config(get_config(mk))
        model = Model(cfg)
        states = model.init_state_pools(num_slots=3)
        nbytes = sum(a.nbytes for a in jax.tree.leaves(states))
        assert state_bytes_per_slot(cfg) * 3 == nbytes
    assert state_bytes_per_slot(reduced_config(get_config("qwen3-14b"))) == 0


# ---------------------------------------------------------------------------
# End-to-end byte-identity: continuous == static for stateful families
# ---------------------------------------------------------------------------


def _static_refs(model, prompts, lens, max_len):
    eng = ServeEngine(model, params=model._params, max_len=max_len,
                      donate_cache=False)
    return {i: np.asarray(eng.generate(
        {"tokens": jnp.asarray(prompts[i])[None]},
        max_new_tokens=lens[i]).tokens[0]) for i in range(len(prompts))}


@pytest.fixture(scope="module")
def mamba():
    cfg = reduced_config(get_config("mamba2-370m"))
    model = build_model(cfg)
    model._params = model.init(jax.random.PRNGKey(0))
    return cfg, model


@pytest.fixture(scope="module")
def hybrid():
    cfg = _hybrid_cfg()
    model = build_model(cfg)
    model._params = model.init(jax.random.PRNGKey(0))
    return cfg, model


def test_mamba2_continuous_matches_static_forced_preemption(mamba):
    """Pure-state serving: 3 requests over 2 slots (slot permutation on
    requeue) with an explicit mid-decode preemption — the restart replays
    the prompt + emitted tokens through chunked SSD prefill and must
    still emit the static engine's greedy stream byte for byte.  (SSM
    slots hold no pages, so pool pressure cannot preempt them; the test
    preempts through the scheduler, as an operator eviction would.)"""
    cfg, model = mamba
    G = [8, 6, 7]
    rng = np.random.default_rng(0)
    # chunk-aligned prompt lengths: SSD chunk boundaries must land on
    # ssm_chunk multiples for bitwise prefill/decode-chain equality
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (16, 32, 16)]
    refs = _static_refs(model, prompts, G, max_len=48)

    ceng = ContinuousServeEngine(model, model._params, num_slots=2,
                                 page_size=4, num_pages=13, max_len=48,
                                 prefill_chunk=cfg.ssm_chunk)
    for i in range(3):
        ceng.add_request(Request(rid=i, prompt=prompts[i],
                                 max_new_tokens=G[i],
                                 arrival_time=0.01 * i))
    outs, preempted = {}, False
    steps = 0
    while ceng.has_unfinished():
        for o in ceng.step():
            if o.finished:
                outs[o.rid] = o.token_ids
        steps += 1
        if steps == 4 and not preempted:
            decoding = ceng._sched.decoding()
            assert decoding, "no decoding request to preempt"
            ceng._sched.preempt(decoding[-1])
            preempted = True
        assert steps < 500
    assert preempted
    assert sum(r.preemptions for r in ceng._requests) > 0
    for i in range(3):
        np.testing.assert_array_equal(refs[i], outs[i])


def test_hybrid_continuous_matches_static_preemption_defrag(hybrid):
    """Full + ring + state in one slot: ragged lengths under a tight full
    pool (evictions move all three residencies together) + periodic
    defrag (which must leave ring pages untouched) still reproduce the
    static engine's greedy stream, and both allocators' invariants hold
    afterwards."""
    cfg, model = hybrid
    R = 5
    lens = [6, 9, 5, 8, 7]
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (16, 32, 16, 32, 16)]
    refs = _static_refs(model, prompts, lens, max_len=48)

    ceng = ContinuousServeEngine(model, model._params, num_slots=2,
                                 page_size=4, num_pages=14, max_len=44,
                                 prefill_chunk=cfg.ssm_chunk)
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=lens[i],
                    arrival_time=0.002 * i) for i in range(R)]
    stats = ceng.run(reqs, defrag_every=3)
    for i in range(R):
        np.testing.assert_array_equal(refs[i], stats.results[i])
    assert stats.preemptions > 0                       # pressure was real
    ceng.cache.allocator.check()
    a = ceng.cache.allocator
    assert a.num_free + a.num_live == a.num_pages - 1
    ceng.cache.ring.check()
    ra = ceng.cache.ring.allocator
    assert ra.num_free + ra.num_live == ra.num_pages - 1


def test_windowed_residency_bounded_per_step():
    """The capacity half of sliding-window serving: during decode a
    slot's live ring blocks never exceed ceil(window/page) + 1, however
    long the stream runs (the full-KV baseline holds ceil(pos/page))."""
    cfg = reduced_config(get_config("h2o-danube-1.8b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    G = 40                                             # >> window (8)
    prompt = np.arange(1, 13, dtype=np.int32) % cfg.vocab_size
    ceng = ContinuousServeEngine(model, params, num_slots=2, page_size=4,
                                 num_pages=17, max_len=64, prefill_chunk=5)
    ceng.add_request(Request(rid=0, prompt=prompt, max_new_tokens=G))
    cap = ring_blocks_cap(cfg.sliding_window, 4)
    assert cap == 3
    seen_decode_steps = 0
    while ceng.has_unfinished():
        ceng.step()
        ring = ceng.cache.ring
        ring.check()
        decoding = ceng._sched.decoding()
        for r in decoding:
            assert ring.live_blocks(r.slot) <= cap, \
                (r.pos, ring.live_blocks(r.slot))
        seen_decode_steps += bool(decoding)
    # the final decode step finishes the request before the check above
    # sees it, so the count under-reads by a step or two
    assert seen_decode_steps >= G - 3


# ---------------------------------------------------------------------------
# Prefix-cache scoping
# ---------------------------------------------------------------------------


def test_prefix_cache_disabled_for_pure_ring_and_state():
    """Reclaimed ring blocks and never-written SSM 'blocks' must not be
    handed out as prefix hits: models with no full-KV space serve with
    the prefix index force-disabled."""
    for mk in ("h2o-danube-1.8b", "mamba2-370m"):
        cfg = reduced_config(get_config(mk))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ceng = ContinuousServeEngine(model, params, num_slots=2, page_size=4,
                                     num_pages=9, max_len=32,
                                     enable_prefix_cache=True)
        assert ceng.enable_prefix_cache is False
        prompt = np.arange(1, 13, dtype=np.int32) % cfg.vocab_size
        reqs = [Request(rid=i, prompt=prompt.copy(), max_new_tokens=4,
                        arrival_time=0.05 * i) for i in range(3)]
        stats = ceng.run(reqs)
        assert stats.prefix_hit_tokens == 0
        assert len({tuple(stats.results[i]) for i in range(3)}) == 1


def test_prefix_cache_hybrid_shares_pages_but_recomputes(hybrid):
    """Hybrid prompts still share full-space attention pages for CAPACITY
    (the index hands out matched pages), but admission reports 0 shared
    tokens so the whole prompt replays — rebuilding SSM state and ring
    pages — and outputs stay byte-identical to static."""
    cfg, model = hybrid
    prompt = (np.arange(1, 33, dtype=np.int32) * 7) % cfg.vocab_size
    refs = _static_refs(model, [prompt] * 3, [5, 5, 5], max_len=48)
    ceng = ContinuousServeEngine(model, model._params, num_slots=2,
                                 page_size=4, num_pages=40, max_len=48,
                                 prefill_chunk=cfg.ssm_chunk,
                                 enable_prefix_cache=True)
    assert ceng.enable_prefix_cache is True
    reqs = [Request(rid=i, prompt=prompt.copy(), max_new_tokens=5,
                    arrival_time=0.05 * i) for i in range(3)]
    stats = ceng.run(reqs)
    for i in range(3):
        np.testing.assert_array_equal(refs[i], stats.results[i])
    assert ceng.cache.hit_tokens > 0                   # pages were shared
    # ...but no prompt compute was skipped (state must be rebuilt)
    assert stats.prefill_tokens == stats.prompt_tokens
    assert all(r["shared_tokens"] == 0 for r in stats.per_request.values())


# ---------------------------------------------------------------------------
# DeploymentSpec residency accounting
# ---------------------------------------------------------------------------


def _pool_nbytes(tree):
    return sum(a.nbytes for a in jax.tree.leaves(tree))


@pytest.mark.parametrize("mk", ["mamba2-370m", "hymba-1.5b",
                                "h2o-danube-1.8b"])
def test_resolve_prices_exactly_what_the_pools_allocate(mk):
    """Acceptance: ``resolve`` reports exactly the bytes the engine's
    pools allocate — full pages + ring pages (scratch rows excluded, per
    the existing convention) + state pools."""
    cfg = reduced_config(get_config(mk))
    if mk == "hymba-1.5b":
        cfg = _hybrid_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = DeploymentSpec(sku="rpu-cu", max_len=64, page_size=4,
                          max_slots=4, prefill_chunk=16)
    r = spec.resolve(model, params=params)
    eng = ContinuousServeEngine(model, params, spec=spec)
    eng.reset()
    lay = model_cache_layout(model.plan)
    assert eng.ring_pages == r.num_ring_pages
    assert (r.ring_window == lay.ring_window)
    total = _pool_nbytes(eng._pools)
    if eng._states is not None:
        state_total = _pool_nbytes(eng._states)
        assert state_total == r.num_slots * r.state_bytes_per_slot
        total += state_total
    else:
        assert r.state_bytes_per_slot == 0
    full_tok = r.kv_token_bytes - r.ring_token_bytes
    scratch = full_tok * r.page_size \
        + (r.ring_token_bytes * r.page_size if r.num_ring_pages else 0)
    assert r.pool_bytes_per_device == total - scratch
    d = r.as_dict()
    assert d["num_ring_pages"] == r.num_ring_pages
    assert "stateful" in r.describe()


def test_resolve_rejects_unsupported_stateful_combinations():
    hy = Model(_hybrid_cfg())
    dense = Model(reduced_config(get_config("qwen3-14b")))
    spec = DeploymentSpec(sku="rpu-cu", max_len=64, page_size=4)
    with pytest.raises(DeploymentError, match="speculative.*hymba"):
        spec.resolve(hy, draft=dense)
    with pytest.raises(DeploymentError, match="phase.*hymba"):
        spec.resolve(hy, phase="prefill")
    with pytest.raises(DeploymentError, match="cache_dtype.*hymba"):
        DeploymentSpec(sku="rpu-cu", max_len=64, page_size=4,
                       cache_dtype="fp8").resolve(hy)
    # quantized RING pages (no state) are fine — only state pools reject
    danube = Model(reduced_config(get_config("h2o-danube-1.8b")))
    r = DeploymentSpec(sku="rpu-cu", max_len=64, page_size=4,
                       cache_dtype="fp8").resolve(danube)
    assert r.num_ring_pages > 0


def test_benchmark_smoke_ring_gate():
    """Fast tier of ``benchmarks/state_cache``: the measured ring
    residency gate (bounded pages/slot vs the no-reclamation baseline)
    runs clean at reduced scale."""
    from benchmarks.state_cache import ring_residency_rows
    rows = ring_residency_rows(max_new=24)
    peak, baseline = rows[0].value, rows[1].value
    assert peak < baseline
