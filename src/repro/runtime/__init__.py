"""Serving runtime: engines, paged KV cache, scheduler, sampling,
speculative decoding, and the hardware-aware ``DeploymentSpec``."""
from repro.runtime.deployment import (
    DeploymentError, DeploymentSpec, DeviceBudget, ResolvedDeployment,
)
from repro.runtime.engine import (
    ContinuousServeEngine, ContinuousStats, GenerationResult, RequestOutput,
    ServeEngine, prefill_step_fn, serve_step_fn,
)
from repro.runtime.kv_cache import PageAllocator, PagedKVCache, SCRATCH_PAGE
from repro.runtime.llm import LLMEngine
from repro.runtime.sampling import (
    MAX_LOGIT_BIAS, MAX_TOP_K, SamplingParams, SlotSampling, dist, draw,
    greedy, probs, sample, sample_slots, stack_extras, stack_params,
    token_key,
)
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.speculative import (
    SpecStats, make_speculative_window, speculative_generate,
)
