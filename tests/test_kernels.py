"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ops import gqa_decode_attention
from repro.kernels.mxfp4_vmm.kernel import mxfp4_vmm
from repro.kernels.mxfp4_vmm.ops import mxfp4_matmul
from repro.kernels.mxfp4_vmm.ref import mxfp4_vmm_ref
from repro.models.common import decode_attention_ref
from repro.quant import formats


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-6)


# ---------------------------------------------------------------------------
# MXFP4 VMM (Stream Decoder + TMAC stripe dataflow)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,k,n,bk,bn", [
    (1, 128, 256, 64, 128),        # single-token VMM (the paper's case)
    (4, 512, 512, 512, 256),
    (8, 1024, 384, 256, 128),
    (16, 256, 1024, 128, 512),
    (3, 160, 128, 32, 64),         # odd batch, minimal K tile
])
def test_mxfp4_vmm_shapes(b, k, n, bk, bn):
    key = jax.random.PRNGKey(b * 1000 + k + n)
    x = jax.random.normal(key, (b, k), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    qw = formats.quantize_mxfp4(w)
    out = mxfp4_vmm(x, qw.codes, qw.scales, block_n=bn, block_k=bk,
                    interpret=True)
    ref = mxfp4_vmm_ref(x, qw.codes, qw.scales)
    assert _rel_err(out, ref) < 0.02    # bf16 tile rounding only


def test_mxfp4_matmul_wrapper_fallback():
    """Non-tileable shapes fall back to the oracle transparently."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 96), jnp.bfloat16)   # 96 % 64 != 0 tiles
    w = jax.random.normal(key, (96, 100), jnp.float32)
    qw = formats.quantize_mxfp4(w)
    out = mxfp4_matmul(x, qw)
    ref = mxfp4_vmm_ref(x, qw.codes, qw.scales)
    assert _rel_err(out, ref.astype(out.dtype)) < 0.02


def test_mxfp4_vmm_matches_float_matmul_loosely():
    """End-to-end quantization error vs the unquantized matmul is bounded
    (MXFP4 ~ 4.25 b/elem: expect a few percent on gaussian data)."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (4, 1024), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 1), (1024, 256), jnp.float32)
    qw = formats.quantize_mxfp4(w)
    out = mxfp4_vmm(x, qw.codes, qw.scales, interpret=True)
    exact = x.astype(jnp.float32) @ w
    assert _rel_err(out, exact) < 0.2


# ---------------------------------------------------------------------------
# Decode attention (KV$-streaming flash-decode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,kvh,d,s,block_s", [
    (1, 8, 8, 64, 256, 128),       # MHA
    (2, 8, 2, 64, 512, 256),       # GQA 4:1
    (4, 16, 2, 128, 384, 128),     # GQA 8:1, odd block count
    (2, 32, 8, 128, 1024, 512),    # llama-like
])
def test_decode_attention_shapes(b, h, kvh, d, s, block_s):
    key = jax.random.PRNGKey(b + h + s)
    q = jax.random.normal(key, (b, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d),
                          jnp.bfloat16)
    cur = jnp.asarray([(s * (i + 1)) // (b + 1) + 1 for i in range(b)],
                      jnp.int32)
    out = gqa_decode_attention(q, k, v, cur, block_s=block_s)
    ref = decode_attention_ref(q, k, v, cur)
    assert _rel_err(out, ref) < 0.02


def test_decode_attention_ignores_invalid_tail():
    """Garbage beyond cur_len must not leak into the output."""
    key = jax.random.PRNGKey(3)
    b, h, kvh, d, s = 2, 4, 2, 64, 256
    q = jax.random.normal(key, (b, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d), jnp.bfloat16)
    cur = jnp.asarray([64, 128], jnp.int32)
    out1 = gqa_decode_attention(q, k, v, cur)
    k2 = k.at[:, 200:].set(1e4)
    v2 = v.at[:, 200:].set(-1e4)
    out2 = gqa_decode_attention(q, k2, v2, cur)
    np.testing.assert_allclose(np.asarray(out1, np.float32),
                               np.asarray(out2, np.float32), atol=1e-3)


# ---------------------------------------------------------------------------
# Quantization formats (Stream Decoder input formats)
# ---------------------------------------------------------------------------


# worst-case relative step near block amax: E2M1 ~ 1/4; E4M3 with a
# floor()ed shared E8M0 scale ~ 2^-3 (x2 scale slack); BFP 8-bit
# mantissa ~ 2^-7 (x2 slack).
_ROUNDTRIP_TOL = {"mxfp4": 0.3, "nxfp4": 0.3, "mxfp8": 0.15,
                  "bfp": 0.02, "bfp16": 0.02}


@pytest.mark.parametrize("fmt", sorted(formats.FORMATS))
def test_format_roundtrip_error_and_byte_accounting(fmt):
    """Every FORMATS entry (aliases included): round-trip error inside the
    format's quantile step, and measured packed bytes == ``packed_nbytes``
    == the advertised bits/element — the budget==storage invariant."""
    key = jax.random.PRNGKey(11)
    w = jax.random.normal(key, (256, 128), jnp.float32)
    p = formats.quantize(w, fmt)
    wd = formats.dequantize(p, fmt, jnp.float32)
    err = np.abs(np.asarray(wd) - np.asarray(w))
    # per-block relative error bounded by the format's quantile step
    rel = np.max(err) / np.max(np.abs(np.asarray(w)))
    assert rel < _ROUNDTRIP_TOL[fmt], rel
    # aliases resolve through the one FormatSpec table (bfp16 KeyError
    # regression: bits_per_element must accept every FORMATS name)
    assert formats.canonical_format(fmt) in ("mxfp4", "mxfp8", "bfp",
                                             "nxfp4")
    measured = sum(np.asarray(c).nbytes for c in p.tree_flatten()[0])
    assert measured == p.nbytes == formats.packed_nbytes(w.shape, fmt)
    # K=256 is a multiple of every block size, so the average is exact
    assert measured == w.size * formats.bits_per_element(fmt) / 8
    # dequantize_any dispatches on the packed type to the same decoder
    np.testing.assert_array_equal(
        np.asarray(formats.dequantize_any(p, jnp.float32)), np.asarray(wd))


def test_mxfp4_packing_layout():
    w = jnp.arange(64 * 4, dtype=jnp.float32).reshape(64, 4) / 64.0
    p = formats.quantize_mxfp4(w)
    assert p.codes.shape == (32, 4)
    assert p.scales.shape == (2, 4)
    assert p.codes.dtype == jnp.uint8
    assert formats.bits_per_element("mxfp4") == pytest.approx(4.25)


def test_nxfp4_beats_mxfp4_on_skewed_blocks():
    """NxFP's micro-exponents should help when sub-blocks differ in scale."""
    key = jax.random.PRNGKey(5)
    base = jax.random.normal(key, (128, 64), jnp.float32)
    scale = jnp.where((jnp.arange(128) % 32) < 8, 8.0, 0.25)[:, None]
    w = base * scale
    e4 = np.abs(np.asarray(formats.dequantize(formats.quantize(w, "mxfp4"),
                                              "mxfp4", jnp.float32) - w)).mean()
    en = np.abs(np.asarray(formats.dequantize(formats.quantize(w, "nxfp4"),
                                              "nxfp4", jnp.float32) - w)).mean()
    assert en <= e4 * 1.02


# ---------------------------------------------------------------------------
# Flash attention (train/prefill fused SDPA — the §Perf beyond-paper kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,h,kvh,d,bq,bk,causal", [
    (2, 256, 4, 2, 64, 128, 128, True),
    (1, 512, 8, 8, 64, 256, 128, False),
    (2, 128, 4, 1, 32, 64, 64, True),      # MQA
    (1, 384, 2, 2, 128, 128, 128, True),   # odd block count
])
def test_flash_attention_vs_blocked(b, s, h, kvh, d, bq, bk, causal):
    from repro.kernels.flash_attention.ops import gqa_flash_attention
    from repro.models.common import blocked_attention
    key = jax.random.PRNGKey(s + h)
    q = jax.random.normal(key, (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, d),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, d),
                          jnp.bfloat16)
    out = gqa_flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = blocked_attention(q, k, v, causal=causal)
    assert _rel_err(out, ref) < 0.02


def test_flash_attention_fallback_unaligned():
    from repro.kernels.flash_attention.ops import gqa_flash_attention
    from repro.models.common import blocked_attention
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 100, 2, 32), jnp.bfloat16)  # 100 % 64 != 0
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 100, 2, 32),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 100, 2, 32),
                          jnp.bfloat16)
    out = gqa_flash_attention(q, k, v, block_q=64, block_k=64)
    ref = blocked_attention(q, k, v, causal=True)
    assert _rel_err(out, ref) < 0.02
