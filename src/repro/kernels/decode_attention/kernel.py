"""Flash-decode GQA attention Pallas kernel — the RPU's memory-bound SDPA
phase (paper §VI Fig 8: "KV$ entries are query-unique ... inherently
memory-bandwidth-bound").

One new query token per sequence attends over the whole KV cache.  The
cache is streamed block-wise HBM->VMEM (the Pallas grid pipeline plays the
role of the RPU's decoupled memory DMA running ahead of compute) with an
online-softmax accumulator living in VMEM scratch across the sequence walk
(the analogue of the TMAC accumulation register file).

Grid: (B, KV_HEADS, S / block_s), sequence innermost.  Each step loads a
(block_s, D) K-tile and V-tile for one kv head and folds them into the
(rep, D) accumulator, where rep = H / KV_HEADS query heads share the tile
— exactly the paper's GQA reuse argument (reuse only among GQA heads).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *,
                        block_s: int, n_s_steps: int, scale: float):
    s_step = pl.program_id(2)

    @pl.when(s_step == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)                 # (rep, D)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (bs, D)
    v = v_ref[0, :, 0].astype(jnp.float32)              # (bs, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (rep, bs)
    # mask out positions beyond the valid cache length
    base = s_step * block_s
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    valid = pos < len_ref[0, 0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                  # (rep, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_step == n_s_steps - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(
    q: jnp.ndarray,          # (B, H, D)
    k_cache: jnp.ndarray,    # (B, S, KVH, D)
    v_cache: jnp.ndarray,    # (B, S, KVH, D)
    cur_len: jnp.ndarray,    # (B,) int32 valid cache length per sequence
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-token GQA decode attention; returns (B, H, D) in q.dtype."""
    b, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    rep = h // kvh
    assert h % kvh == 0
    block_s = min(block_s, s)
    assert s % block_s == 0, f"S={s} % block_s={block_s} != 0"
    n_s = s // block_s
    scale = 1.0 / math.sqrt(d)

    qg = q.reshape(b, kvh, rep, d)
    lens = cur_len.astype(jnp.int32).reshape(b, 1)

    grid = (b, kvh, n_s)
    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, block_s=block_s,
                          n_s_steps=n_s, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, g, ss: (bb, 0)),             # len
            pl.BlockSpec((1, 1, rep, d), lambda bb, g, ss: (bb, g, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bb, g, ss: (bb, ss, g, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bb, g, ss: (bb, ss, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d), lambda bb, g, ss: (bb, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qg, k_cache, v_cache)
    return out.reshape(b, h, d)
