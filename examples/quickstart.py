"""Quickstart: the three layers of the RPU reproduction in ~60 seconds.

  1. analytical core   — design an HBM-CO memory + RPU for a model
  2. simulator         — latency/energy of the deployment (paper Figs 8-12)
  3. JAX framework     — run a real (reduced) model: train step + decode

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core.hbmco import CANDIDATE_CO, HBM3E_LIKE
from repro.models.model import build_model
from repro.runtime.deployment import DeploymentSpec
from repro.runtime.llm import LLMEngine
from repro.runtime.sampling import SamplingParams
from repro.sim.scaling import iso_tdp_comparison, rpu_point
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    # ------------------------------------------------ 1. analytical core
    print("== HBM-CO (paper §III) ==")
    print(" ", HBM3E_LIKE.describe())
    print(" ", CANDIDATE_CO.describe())
    print(f"  energy ratio: {HBM3E_LIKE.energy_pj_per_bit / CANDIDATE_CO.energy_pj_per_bit:.2f}x"
          f"  (paper: 2.4x)")

    # ------------------------------------------------ 2. simulator
    print("\n== RPU deployment for Llama3-70B (paper §VIII) ==")
    p = rpu_point(get_config("llama3-70b"), 204, batch=1, seq_len=8192)
    print(f"  204 CUs, SKU {p.sku.name}: {p.ms_per_token:.2f} ms/token "
          f"(paper: 0.4), {p.tdp_w:.0f} W")
    r = iso_tdp_comparison(get_config("llama3-70b"), batch=1, seq_len=8192)
    print(f"  ISO-TDP vs {r['n_gpus']}xH100: {r['speedup']:.1f}x lower latency")

    # ------------------------------------------------ 3. JAX framework
    print("\n== JAX framework: reduced qwen3, 5 train steps + decode ==")
    cfg = reduced_config(get_config("qwen3-14b"))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, key)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    batch = {"tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab_size)}
    for i in range(5):
        state, metrics = step(state, batch)
        print(f"  step {i}: loss {float(metrics['loss']):.4f}")

    llm = LLMEngine(model, state.params, backend="static", max_len=80)
    outs = llm.generate([batch["tokens"][0, :16], batch["tokens"][1, :16]],
                        SamplingParams(max_tokens=8))
    print(f"  generated: {[o.token_ids for o in outs]}")

    # ---------------------------------------- 4. the seam: spec -> runtime
    # The analytic core (1-2) sizes the serving runtime (3): a hardware
    # point resolves into the paged-KV pool and decode-slot budget.
    print("\n== DeploymentSpec: HBM-CO budget drives the real engine ==")
    spec = DeploymentSpec(sku="rpu-cu", hbmco=CANDIDATE_CO,
                          weight_format="mxfp4", max_len=80,
                          cache_dtype=jnp.float32, max_slots=4)
    sllm = LLMEngine(model, state.params, backend="continuous", spec=spec)
    print(sllm.deployment.describe())
    outs = sllm.generate([batch["tokens"][0, :16], batch["tokens"][1, :16]],
                         SamplingParams(max_tokens=8))
    print(f"  generated: {[o.token_ids for o in outs]}")


if __name__ == "__main__":
    main()
