"""Training substrate: optimizer, train step (w/ remat + cross-pod
compression), atomic sharded checkpointing, fault-tolerant loop."""
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.train.train_step import TrainState, init_train_state, make_train_step
from repro.train.checkpoint import (
    save_checkpoint, restore_checkpoint, restore_latest, list_checkpoints)
from repro.train.loop import LoopConfig, LoopResult, run_training
