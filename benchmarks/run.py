"""Benchmark orchestrator: one module per paper figure/table + the
roofline table from the dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run [--only fig5,fig8,...]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import EXP_DIR, Row, dump

MODULES = [
    ("hbmco_tradeoffs", "Fig 4/5 — HBM-CO design space & candidate device"),
    ("pareto", "Fig 9 — HBM-CO Pareto frontier for 405B/64CU"),
    ("sku_map", "Fig 10 — SKU selection map (Maverick, batch x seq)"),
    ("cu_timeline", "Fig 8 — CU timeline BS=1/BS=32 + decoupling ablations"),
    ("strong_scaling", "Fig 11 — strong scaling + ISO-TDP vs H100"),
    ("batch_sweep", "Fig 13/11b — batch sweeps (speedup, energy, BW util)"),
    ("energy_cost", "Fig 12 — energy & cost vs scale; EDP"),
    ("spec_decode", "Fig 14 — speculative decoding comparison"),
    ("fleet", "ours — fleet router + autoscaler gates (simulated)"),
    ("disagg", "ours — disaggregated prefill/decode gates"),
    ("state_cache", "ours — stateful cache layouts: ring + state residency"),
    ("roofline_table", "ours — 40-cell roofline table from the dry-run"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    args = ap.parse_args(argv)
    want = set(args.only.split(",")) if args.only else None

    all_rows: list[Row] = []
    failures = []
    for name, title in MODULES:
        if want and name not in want:
            continue
        print(f"\n=== {title} [{name}] " + "=" * max(1, 30 - len(name)))
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run()
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
            continue
        for r in rows:
            print(r.render())
        dump(rows, name)
        all_rows.extend(rows)
        print(f"[{time.time()-t0:.1f}s]")

    EXP_DIR.mkdir(parents=True, exist_ok=True)
    (EXP_DIR / "bench_all.json").write_text(json.dumps(
        [r.__dict__ for r in all_rows], indent=1, default=str))
    print(f"\n{len(all_rows)} rows -> {EXP_DIR/'bench_all.json'}")
    if failures:
        print("FAILURES:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
