"""Fleet-serving benchmark: router gate, autoscaler gate, diurnal sweep,
and the sim-vs-real calibration cross-check.

``run()`` (used by ``benchmarks.run``; same as ``--smoke``) is the fast
tier — no real engine, everything analytic or simulated:

- **router gate**: the shared-prefix tenant workload (12 tenants, 96 of
  ~128 prompt tokens shared) over 4 simulated replicas.  Asserts the
  prefix-affinity router beats round-robin on BOTH goodput and p95 TTFT
  under a tight SLO — the claim the router exists for.
- **autoscaler gate**: plan a qwen3-14b fleet (mxfp4 weights, fp8 KV)
  from a diurnal traffic envelope.  Asserts the chosen RPU (SKU,
  replicas) meets the SLO at lower modeled die-mm2 AND J/token than a
  fixed h200 fleet sized for the same envelope.

``main()`` adds the slow tier: the router gate over three seeds, a
diurnal sweep of SLO attainment / goodput / energy vs replica count,
and (default on, ``--skip-cross-check`` to skip) the calibration
cross-check — a real reduced-arch ``ContinuousServeEngine`` is timed
into a :class:`LatencyTable`, the same trace is replayed through engine
and simulator, and the throughput ratio must land in [0.7, 1.4] (the
simulator's stated +-40% fidelity envelope on shared CI hardware).

  PYTHONPATH=src python -m benchmarks.fleet --smoke
  PYTHONPATH=src python -m benchmarks.fleet [--skip-cross-check]
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import time

from benchmarks.common import Row, dump
from repro.configs import get_config
from repro.fleet import (SLO, FleetSimulator, PrefixAffinityRouter,
                         ReplicaSpec, RoundRobinRouter, TrafficEnvelope,
                         cross_check, default_candidates, plan_fleet)
from repro.fleet import traffic as tr
from repro.fleet.autoscaler import plan_candidate
from repro.launch.fleet import gate_table, gate_workload
from repro.models.model import build_model
from repro.runtime.deployment import DeploymentSpec

# the tuned router-gate setup: replica prefix capacity (24 blocks) is
# scarce against 12 tenants x 6 shared blocks, so spraying tenants
# round-robin thrashes every replica's prefix index
GATE_SLO = SLO(ttft_s=0.025, tpot_s=0.012)
GATE_REPLICAS = 4
GATE_REQUESTS = 1200
GATE_RATE = 100.0


def _gate_spec() -> ReplicaSpec:
    return ReplicaSpec(latency=gate_table(), num_slots=8, max_queue=16,
                       page_size=16, prefix_blocks=24,
                       energy_j_per_token=1e-4)


def _run_router(seed: int, router_cls) -> dict:
    trace = gate_workload(GATE_REQUESTS, seed, "diurnal", GATE_RATE)
    sim = FleetSimulator(_gate_spec(), GATE_REPLICAS, router_cls(slo=GATE_SLO))
    fs = sim.run(trace)
    return {"goodput": fs.goodput_tokens_per_s(GATE_SLO),
            "p95_ttft": fs.ttft_quantiles()["p95"],
            "attainment": fs.slo_attainment(GATE_SLO),
            "shed": len(fs.shed)}


def router_gate_rows(seeds=(7,)) -> list[Row]:
    rows = []
    for seed in seeds:
        aff = _run_router(seed, PrefixAffinityRouter)
        rr = _run_router(seed, RoundRobinRouter)
        ratio = aff["goodput"] / max(rr["goodput"], 1e-9)
        rows += [
            Row("ours:fleet", f"affinity goodput (seed {seed})",
                round(aff["goodput"], 1), unit=" tok/s",
                note=f"{ratio:.2f}x round-robin"),
            Row("ours:fleet", f"affinity p95 TTFT (seed {seed})",
                round(aff["p95_ttft"] * 1e3, 2), unit=" ms",
                note=f"rr {rr['p95_ttft']*1e3:.2f} ms"),
            Row("ours:fleet", f"affinity SLO attainment (seed {seed})",
                round(aff["attainment"], 3),
                note=f"rr {rr['attainment']:.3f}"),
        ]
        # the gate: affinity must win goodput AND p95 TTFT outright
        assert aff["goodput"] > rr["goodput"] * 1.05, \
            f"seed {seed}: affinity goodput {aff['goodput']:.0f} <= " \
            f"1.05x round-robin {rr['goodput']:.0f}"
        assert aff["p95_ttft"] < rr["p95_ttft"], \
            f"seed {seed}: affinity p95 TTFT {aff['p95_ttft']:.4f}s >= " \
            f"round-robin {rr['p95_ttft']:.4f}s"
    return rows


def autoscaler_gate_rows() -> list[Row]:
    model = build_model(get_config("qwen3-14b"))
    lengths = tr.LengthMix(prompt_mean=512.0, prompt_min=64, prompt_max=1024,
                           output_mean=256.0, output_min=32, output_max=512)
    trace = tr.make_trace(600, 0, kind="diurnal", rate=200.0, lengths=lengths)
    env = TrafficEnvelope.from_trace(trace)
    slo = SLO(ttft_s=2.0, tpot_s=0.05)
    base = DeploymentSpec(max_len=2048, weight_format="mxfp4",
                          cache_dtype="fp8", max_slots=32)
    best, plans = plan_fleet(model, env, slo, default_candidates(model, base))
    baseline = plan_candidate(
        model, dataclasses.replace(base, sku="h200", hbmco=None), env, slo)
    die_win = baseline.die_mm2 / best.die_mm2
    energy_win = baseline.energy_j_per_token / best.energy_j_per_token
    rows = [
        Row("ours:fleet", "autoscaler choice",
            f"{best.name} x {best.replicas}",
            note=f"peak {env.peak_decode_tokens_per_s:.0f} tok/s envelope"),
        Row("ours:fleet", "die-mm2 vs fixed h200 fleet", round(die_win, 1),
            unit="x", note=f"{best.die_mm2:.0f} vs {baseline.die_mm2:.0f}"),
        Row("ours:fleet", "J/token vs fixed h200 fleet",
            round(energy_win, 1), unit="x"),
    ]
    # the gate: the planner's pick meets the SLO at lower modeled cost
    # AND energy than the fixed-GPU baseline sized for the same envelope
    assert best.feasible and best.ttft_est_s <= slo.ttft_s \
        and best.tpot_est_s <= slo.tpot_s
    assert baseline.feasible, "h200 baseline should meet this SLO too"
    assert best.die_mm2 < baseline.die_mm2, \
        f"chosen {best.name} die {best.die_mm2:.0f} mm2 >= " \
        f"h200 {baseline.die_mm2:.0f} mm2"
    assert best.energy_j_per_token < baseline.energy_j_per_token, \
        f"chosen {best.name} {best.energy_j_per_token:.4f} J/tok >= " \
        f"h200 {baseline.energy_j_per_token:.4f} J/tok"
    return rows


def sweep_rows(seed: int = 7) -> list[Row]:
    """Diurnal sweep: SLO attainment / goodput / energy vs replica count."""
    trace = gate_workload(GATE_REQUESTS, seed, "diurnal", GATE_RATE)
    rows = []
    for n in (2, 3, 4, 6, 8):
        sim = FleetSimulator(_gate_spec(), n,
                             PrefixAffinityRouter(slo=GATE_SLO))
        fs = sim.run(trace)
        rows.append(Row(
            "ours:fleet", f"diurnal sweep @ {n} replicas",
            round(fs.slo_attainment(GATE_SLO), 3),
            note=f"goodput {fs.goodput_tokens_per_s(GATE_SLO):.0f} tok/s, "
                 f"{fs.energy_j_per_token() * 1e6:.1f} uJ/tok, "
                 f"shed {len(fs.shed)}"))
    return rows


def cross_check_rows(requests: int = 40, rate: float = 30.0,
                     seed: int = 0) -> list[Row]:
    """Calibrate a real engine, replay the trace in both, gate the ratio."""
    import jax
    import jax.numpy as jnp
    from repro.configs import reduced_config
    from repro.models.common import ModelConfig
    from repro.runtime.engine import ContinuousServeEngine

    cfg = ModelConfig(name="fleet-bench", family="dense", n_layers=4,
                      d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
                      d_ff=512, vocab_size=1024)
    model = build_model(cfg)
    params = jax.device_put(model.init(jax.random.PRNGKey(seed)))
    max_len = 160
    eng = ContinuousServeEngine(
        model, params, num_slots=8, page_size=16,
        num_pages=1 + 8 * 2 * (max_len // 16), max_len=max_len,
        cache_dtype=jnp.float32, prefill_chunk=32,
        enable_prefix_cache=False)
    lengths = tr.LengthMix(prompt_mean=48.0, prompt_min=16, prompt_max=96,
                           output_mean=16.0, output_min=4, output_max=32)
    trace = tr.make_trace(requests, seed, kind="poisson", rate=rate,
                          vocab=cfg.vocab_size, lengths=lengths,
                          tenants=tr.TenantMix(n_tenants=1, prefix_len=0))
    res = cross_check(eng, trace)
    ratio = res["throughput_ratio"]
    rows = [
        Row("ours:fleet", "sim/real throughput ratio", round(ratio, 3),
            note=f"real {res['real_tokens_per_s']:.1f} tok/s, "
                 f"sim {res['sim_tokens_per_s']:.1f} tok/s"),
        Row("ours:fleet", "real TTFT p50", round(res["real_ttft_p50"], 4),
            unit=" s", note=f"sim {res['sim_ttft_p50']:.4f} s"),
    ]
    assert 0.7 <= ratio <= 1.4, \
        f"sim/real throughput ratio {ratio:.3f} outside [0.7, 1.4]"
    return rows


def run() -> list[Row]:
    """Fast tier for ``benchmarks.run``: both gates, no real engine."""
    return router_gate_rows(seeds=(7,)) + autoscaler_gate_rows()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast tier only (router + autoscaler gates)")
    ap.add_argument("--skip-cross-check", action="store_true",
                    help="skip the real-engine calibration cross-check")
    ap.add_argument("--requests", type=int, default=40,
                    help="cross-check trace size")
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.smoke:
        rows = run()
    else:
        rows = router_gate_rows(seeds=(7, 11, 23))
        rows += autoscaler_gate_rows()
        rows += sweep_rows()
        if not args.skip_cross_check:
            rows += cross_check_rows(requests=args.requests)
    for r in rows:
        print(r.render())
    dump(rows, "fleet")
    print(f"[{time.time() - t0:.1f}s] all fleet gates passed "
          f"-> experiments/bench_fleet.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
