"""Public op wrapper for the MXFP4 VMM kernel.

``mxfp4_matmul`` is the user-facing op: takes a ``PackedMXFP4`` weight and
(B, K) activations, dispatches to the Pallas kernel (interpret-mode on CPU,
compiled on TPU), and falls back to the jnp oracle for shapes the kernel's
tiling can't cover (tiny smoke configs).  The fallback is *surfaced*: it
bumps ``FALLBACK_STATS`` and warns once, so production configs silently
bypassing the kernel are visible (the llama3-8b serve projections are
asserted tileable in tests).
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.quant.formats import MX_BLOCK, PackedMXFP4
from repro.kernels import on_cpu
from repro.kernels.mxfp4_vmm.kernel import mxfp4_vmm
from repro.kernels.mxfp4_vmm.ref import mxfp4_vmm_ref

# trace-time dispatch counters: {"kernel": .., "fallback": ..}; a fallback
# also warns once per process so silent oracle serving is visible
FALLBACK_STATS = {"kernel": 0, "fallback": 0}
_warned = False


def mxfp4_tileable(k: int, n: int, *, block_n: int = 256,
                   block_k: int = 512) -> bool:
    """True when a (K, N) mxfp4 weight takes the Pallas kernel path."""
    bk, bn = min(block_k, k), min(block_n, n)
    return k % bk == 0 and bk % MX_BLOCK == 0 and n % bn == 0


def _note_fallback(k: int, n: int) -> None:
    global _warned
    FALLBACK_STATS["fallback"] += 1
    if not _warned:
        _warned = True
        warnings.warn(
            f"mxfp4_matmul: weight shape ({k}, {n}) is not tileable by the "
            f"Pallas VMM kernel; using the jnp dequant oracle (reported "
            f"once; see kernels.mxfp4_vmm.ops.FALLBACK_STATS)",
            RuntimeWarning, stacklevel=3)


def mxfp4_matmul(x: jnp.ndarray, w: PackedMXFP4, *,
                 block_n: int = 256, block_k: int = 512,
                 out_dtype=jnp.bfloat16, impl: str = "auto") -> jnp.ndarray:
    """x: (..., K) @ dequant(w): (K, N) -> (..., N).

    ``impl``: "fused" runs the Pallas kernel (interpret-mode on CPU),
    "reference" the jnp oracle, "auto" picks the oracle on CPU (interpret
    mode inside a serve step is orders of magnitude slower) and the kernel
    on accelerators.  Non-tileable shapes always take the oracle — counted
    in ``FALLBACK_STATS`` and warned once.
    """
    if impl not in ("auto", "fused", "reference"):
        raise ValueError(f"impl must be auto|fused|reference, got {impl!r}")
    k, n = w.shape[-2:]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k).astype(jnp.bfloat16)
    if impl == "auto":
        impl = "reference" if on_cpu() else "fused"
    tileable = mxfp4_tileable(k, n, block_n=block_n, block_k=block_k)
    if impl == "fused" and not tileable:
        _note_fallback(k, n)
        impl = "reference"
    if impl == "reference":
        out = mxfp4_vmm_ref(x2, w.codes, w.scales)
    else:
        FALLBACK_STATS["kernel"] += 1
        out = mxfp4_vmm(x2, w.codes, w.scales, block_n=min(block_n, n),
                        block_k=min(block_k, k), interpret=on_cpu())
    return out.reshape(*lead, n).astype(out_dtype)
