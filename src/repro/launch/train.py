"""Training launcher.

Runs a real (small-scale) training job with the same code paths the
production mesh uses: sharded params via ``ParallelPlan``, fault-tolerant
loop (checkpoint / NaN rollback / resume), host-sharded data pipeline.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
      --steps 100 --batch 8 --seq 128

On a real TPU slice the same entry point is used with --no-reduced and the
production mesh; this container runs the reduced config on CPU.
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.mesh import make_small_mesh
from repro.models.model import build_model
from repro.parallel.hints import sharding_rules
from repro.parallel.plan import make_plan
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)

    mesh = make_small_mesh()
    plan = make_plan(cfg, mesh, global_batch=args.batch, shape_kind="train")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    step_fn = make_train_step(model, opt_cfg, remat=args.remat)
    state = init_train_state(model, jax.random.PRNGKey(args.seed))

    pipeline = SyntheticTokenPipeline(
        cfg, global_batch=args.batch, seq_len=args.seq, seed=args.seed)

    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir, log_every=10)

    with mesh, sharding_rules(plan.rules()):
        result = run_training(step_fn, state, pipeline, loop_cfg)

    n = model.param_count(result.state.params)
    if result.losses:
        span = (f"first_loss={result.losses[0]:.4f} "
                f"last_loss={result.losses[-1]:.4f}")
    else:
        span = f"(resumed at step {result.resumed_from}: already complete)"
    print(f"arch={cfg.name} params={n/1e6:.1f}M steps={len(result.losses)} "
          f"{span} rollbacks={result.rollbacks}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
