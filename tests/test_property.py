"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dep: property tests need hypothesis (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro.core.hbmco import HBMCOConfig
from repro.models.common import blocked_attention, decode_attention_ref
from repro.quant import formats

_settings = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Blocked (flash-style) attention == naive attention
# ---------------------------------------------------------------------------


def _naive_attention(q, k, v, causal, window):
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf)
    s = s / np.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


@given(
    sq=st.integers(1, 48),
    h=st.sampled_from([1, 2, 4]),
    grp=st.sampled_from([1, 2]),
    d=st.sampled_from([8, 16]),
    causal=st.booleans(),
    window=st.sampled_from([None, 4, 16]),
    qb=st.sampled_from([4, 16, 64]),
    kb=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**30),
)
@settings(**_settings)
def test_blocked_attention_equals_naive(sq, h, grp, d, causal, window, qb,
                                        kb, seed):
    if h % grp:
        grp = 1
    kvh = h // grp
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, sq, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, sq, kvh, d),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, sq, kvh, d),
                          jnp.float32)
    out = blocked_attention(q, k, v, causal=causal, window=window,
                            q_block=qb, kv_block=kb)
    ref = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-3)


@given(
    s=st.integers(1, 64),
    h=st.sampled_from([2, 4]),
    d=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**30),
)
@settings(**_settings)
def test_decode_attention_is_last_row_of_prefill(s, h, d, seed):
    """decode(q_t | K,V) == full causal attention's last row."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, h, d), jnp.float32)
    full = _naive_attention(q, k, v, True, None)[:, -1]       # (1, h, d)
    dec = decode_attention_ref(q[:, -1], k, v, jnp.asarray([s], jnp.int32))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# Quantization formats
# ---------------------------------------------------------------------------


@given(
    fmt=st.sampled_from(["mxfp4", "mxfp8", "bfp16", "nxfp4"]),
    rows=st.integers(1, 8).map(lambda r: r * 32),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**30),
)
@settings(**_settings)
def test_quant_block_relative_error_bounded(fmt, rows, scale, seed):
    """Per-block relative error is bounded by the format's step size for
    any input scale (shared exponents track magnitude)."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (rows, 32), jnp.float32) * scale
    wd = formats.dequantize(formats.quantize(w, fmt), fmt, jnp.float32)
    err = np.abs(np.asarray(wd - w))
    amax = np.abs(np.asarray(w)).max() + 1e-30
    bound = {"mxfp4": 0.35, "nxfp4": 0.35, "mxfp8": 0.15, "bfp16": 0.02}[fmt]
    assert err.max() / amax <= bound


@given(seed=st.integers(0, 2**30))
@settings(**_settings)
def test_quant_scale_equivariance_mxfp4(seed):
    """Quantizing 2^k * W == 2^k * quantizing W (E8M0 shared scale)."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (64, 32), jnp.float32)
    a = formats.dequantize(formats.quantize(w, "mxfp4"), "mxfp4", jnp.float32)
    b = formats.dequantize(formats.quantize(w * 8.0, "mxfp4"), "mxfp4",
                           jnp.float32)
    np.testing.assert_allclose(np.asarray(a) * 8.0, np.asarray(b),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# HBM-CO model invariants
# ---------------------------------------------------------------------------


@given(
    ranks=st.sampled_from([1, 2, 4]),
    ch=st.sampled_from([1, 2, 4]),
    banks=st.sampled_from([1, 2, 4]),
    mb=st.sampled_from([1.5, 3.0, 6.0, 12.0, 24.0]),
)
@settings(**_settings)
def test_hbmco_invariants(ranks, ch, banks, mb):
    c = HBMCOConfig(ranks=ranks, channels_per_layer=ch, banks_per_group=banks,
                    bank_mb=mb)
    # energy grows with capacity at fixed bandwidth structure
    bigger = HBMCOConfig(ranks=ranks, channels_per_layer=ch,
                         banks_per_group=banks, bank_mb=mb * 2)
    assert bigger.energy_pj_per_bit >= c.energy_pj_per_bit
    assert bigger.module_cost >= c.module_cost
    # cost per GB falls with capacity (fixed costs amortize)
    assert bigger.cost_per_gb <= c.cost_per_gb + 1e-9
    # BW/Cap inverse to capacity at fixed bandwidth
    assert c.bw_per_cap == pytest.approx(
        c.bandwidth_gbs / c.capacity_gb, rel=1e-9)


# ---------------------------------------------------------------------------
# Online softmax invariance (the decoupled-pipeline numerical core)
# ---------------------------------------------------------------------------


@given(
    n=st.integers(2, 200),
    chunks=st.integers(1, 8),
    shift=st.floats(-100, 100),
    seed=st.integers(0, 2**30),
)
@settings(**_settings)
def test_online_softmax_chunk_invariance(n, chunks, shift, seed):
    """Two-pass online softmax over arbitrary chunkings == full softmax."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n,), jnp.float32) * 10 + shift
    bounds = np.linspace(0, n, chunks + 1).astype(int)
    m, l = -np.inf, 0.0
    for i in range(chunks):
        blk = np.asarray(x[bounds[i]:bounds[i + 1]])
        if blk.size == 0:
            continue
        m_new = max(m, blk.max())
        l = l * np.exp(m - m_new) + np.exp(blk - m_new).sum()
        m = m_new
    lse = m + np.log(l)
    ref = float(jax.scipy.special.logsumexp(x))
    assert lse == pytest.approx(ref, rel=1e-5, abs=1e-5)
