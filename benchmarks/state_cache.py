"""Stateful cache layouts: ring-page reclamation + SSM state-pool gates.

``run()`` (used by ``benchmarks.run``; same as ``--smoke``) is the fast
tier:

- **ring residency gate**: a real tiny engine decodes a sliding-window
  arch (h2o-danube reduced) far past its window and we track the MAX
  live ring blocks any slot holds at any decode step.  The gate is the
  paper's capacity claim made concrete: residency stays at
  ``ceil(window/page) + 1`` pages per slot however long the stream runs,
  where the no-reclamation baseline (what this repo allocated before the
  ring space landed) holds ``ceil(pos/page)`` — O(context).
- **decode HBM bytes/token**: the bandwidth half of the same claim at
  paper scale — the full h2o-danube-1.8b config priced through
  ``DeploymentSpec``: a decode step streams O(window) KV bytes per slot
  instead of O(context).
- **state-pool residency**: mamba2-370m / hymba-1.5b constant per-slot
  state bytes (``state_cache.state_bytes_per_slot``) against what a
  full-KV layout would hold at the same context.

``main()`` adds the slow tier — a longer decode sweep over several
window/page geometries plus SSM and hybrid byte-identity gates
(continuous == static greedy) — and writes
``experiments/bench_state_cache.json``.

  PYTHONPATH=src python -m benchmarks.state_cache --smoke
  PYTHONPATH=src python -m benchmarks.state_cache
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import Row, dump
from repro.configs import get_config, reduced_config

# ---------------------------------------------------------------------------
# ring residency: real engine, measured per-step
# ---------------------------------------------------------------------------


def _measure_ring_residency(cfg, *, page_size: int, max_new: int,
                            prompt_len: int, num_slots: int = 2):
    """Serve one windowed request end to end; return (max live ring
    blocks seen at any decode step, final position, ring cap)."""
    import jax
    from repro.models.model import build_model
    from repro.runtime.engine import ContinuousServeEngine
    from repro.runtime.scheduler import Request

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = prompt_len + max_new + 1
    max_blocks = -(-max_len // page_size)
    eng = ContinuousServeEngine(model, params, num_slots=num_slots,
                                page_size=page_size,
                                num_pages=1 + max_blocks,
                                max_len=max_len, prefill_chunk=8)
    prompt = (np.arange(1, prompt_len + 1) % cfg.vocab_size).astype(np.int32)
    eng.add_request(Request(rid=0, prompt=prompt, max_new_tokens=max_new))
    peak, pos = 0, 0
    while eng.has_unfinished():
        eng.step()
        ring = eng.cache.ring
        ring.check()
        for r in eng._sched.decoding():
            peak = max(peak, ring.live_blocks(r.slot))
            pos = max(pos, r.pos)
    return peak, pos, ring.decode_cap


def ring_residency_rows(*, page_size: int = 4, max_new: int = 48,
                        prompt_len: int = 12) -> list[Row]:
    cfg = reduced_config(get_config("h2o-danube-1.8b"))
    peak, pos, cap = _measure_ring_residency(cfg, page_size=page_size,
                                             max_new=max_new,
                                             prompt_len=prompt_len)
    baseline = -(-pos // page_size)         # no reclamation: O(pos) blocks
    rows = [
        Row("ours:state_cache", f"ring pages/slot peak (w={cfg.sliding_window}"
            f", page={page_size}, pos={pos})", peak, unit="pages",
            note=f"bound ceil(w/page)+1 = {cap}"),
        Row("ours:state_cache", "no-reclamation baseline pages/slot",
            baseline, unit="pages", note="ceil(pos/page), pre-ring layout"),
        Row("ours:state_cache", "residency reduction at this pos",
            baseline / max(peak, 1), unit="x",
            note="grows with context; unbounded as pos -> inf"),
    ]
    assert peak <= cap, f"ring residency {peak} exceeded bound {cap}"
    return rows


# ---------------------------------------------------------------------------
# paper-scale pricing: decode HBM bytes/token + state residency
# ---------------------------------------------------------------------------


def pricing_rows(*, max_len: int = 8192) -> list[Row]:
    from repro.models.model import Model
    from repro.parallel.plan import paged_kv_token_bytes_split
    from repro.runtime.state_cache import (model_cache_layout,
                                           state_bytes_per_slot)

    rows: list[Row] = []
    cfg = get_config("h2o-danube-1.8b")
    model = Model(cfg)
    kv_full, kv_ring = paged_kv_token_bytes_split(model)
    lay = model_cache_layout(model.plan)
    w = lay.ring_window
    # Price the stream past the window, else ring == full trivially.
    ctx = max(max_len // 2, 4 * w)
    ring_stream = kv_full * ctx + kv_ring * min(ctx, w)
    full_stream = (kv_full + kv_ring) * ctx
    rows += [
        Row("ours:state_cache", f"danube decode KV stream @ctx={ctx} (ring)",
            ring_stream / 1e6, unit="MB/token",
            note=f"window {w}: O(window) not O(ctx)"),
        Row("ours:state_cache", "danube decode KV stream (no reclamation)",
            full_stream / 1e6, unit="MB/token",
            note=f"{full_stream / max(ring_stream, 1):.2f}x the ring stream"),
    ]
    for mk in ("mamba2-370m", "hymba-1.5b"):
        c = get_config(mk)
        m = Model(c)
        sb = state_bytes_per_slot(c)
        kf, kr = paged_kv_token_bytes_split(m)
        resident = sb + kf * max_len \
            + kr * min(max_len, model_cache_layout(m.plan).ring_window or 0)
        dense_equiv = (kf + kr) * max_len if (kf + kr) else None
        rows.append(Row("ours:state_cache", f"{mk} resident/slot @max_len="
                        f"{max_len}", resident / 1e6, unit="MB",
                        note=f"state {sb / 1e6:.2f}MB + KV"
                        + (f"; all-full would be {dense_equiv / 1e6:.1f}MB"
                           if dense_equiv else "; no token-indexed KV")))
    return rows


# ---------------------------------------------------------------------------
# byte-identity gates (slow tier)
# ---------------------------------------------------------------------------


def byte_identity_rows() -> list[Row]:
    import jax
    import jax.numpy as jnp
    from repro.models.model import build_model
    from repro.runtime.engine import ContinuousServeEngine, ServeEngine
    from repro.runtime.scheduler import Request

    rows = []
    hy = dataclasses.replace(reduced_config(get_config("hymba-1.5b")),
                             n_layers=3, global_attn_every=3)
    for cfg in (reduced_config(get_config("mamba2-370m")), hy):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (16, 32, 16)]
        G = [8, 6, 7]
        ref = ServeEngine(model, params, max_len=48, donate_cache=False)
        refs = [np.asarray(ref.generate({"tokens": jnp.asarray(p)[None]},
                                        max_new_tokens=g).tokens[0])
                for p, g in zip(prompts, G)]
        eng = ContinuousServeEngine(model, params, num_slots=2, page_size=4,
                                    num_pages=14, max_len=48,
                                    prefill_chunk=cfg.ssm_chunk)
        stats = eng.run([Request(rid=i, prompt=prompts[i],
                                 max_new_tokens=G[i], arrival_time=0.002 * i)
                         for i in range(3)])
        ok = all(np.array_equal(refs[i], stats.results[i]) for i in range(3))
        assert ok, f"{cfg.name}: continuous != static"
        rows.append(Row("ours:state_cache", f"{cfg.name} continuous==static "
                        f"(preemptions={stats.preemptions})", "PASS",
                        note="greedy byte-identity through state pools"))
    return rows


def run() -> list[Row]:
    """Fast tier for ``benchmarks.run``."""
    return ring_residency_rows() + pricing_rows()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast tier only (same rows as benchmarks.run)")
    args = ap.parse_args(argv)
    t0 = time.time()
    rows = run()
    if not args.smoke:
        rows += byte_identity_rows()
        for page in (2, 8):
            rows += ring_residency_rows(page_size=page, max_new=64)
    for r in rows:
        print(r.render())
    dump(rows, "state_cache")
    print(f"[{time.time() - t0:.1f}s] -> experiments/bench_state_cache.json")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
