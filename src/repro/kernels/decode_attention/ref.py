"""Pure-jnp oracles for flash-decode GQA attention (dense and paged)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import decode_attention_ref  # noqa: F401


def gather_pages(pages: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """(P, page, ...) pool + (B, n_blocks) table -> (B, n_blocks*page, ...)
    position-ordered dense view (block i of row b = physical page
    ``page_table[b, i]``)."""
    g = pages[page_table]                     # (B, n_blocks, page, ...)
    b, nb, ps = g.shape[:3]
    return g.reshape((b, nb * ps) + g.shape[3:])


def paged_valid_mask(page_table: jnp.ndarray, page_size: int,
                     pos: jnp.ndarray, *, window=None) -> jnp.ndarray:
    """(B, n_blocks*page) bool mask of logical positions visible to the
    token being decoded at per-row position ``pos`` (inclusive: the new
    token's own k/v has already been scattered at ``pos``)."""
    s = page_table.shape[1] * page_size
    idx = jnp.arange(s)[None, :]
    valid = idx <= pos[:, None]
    if window is not None:
        valid = valid & (idx > pos[:, None] - window)
    return valid


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, pos, *,
                               window=None, scale=None):
    """Paged single-token decode attention oracle.

    q:          (B, H, D) — one new token per slot
    k_pages:    (P, page, KVH, D) physical page pool
    v_pages:    (P, page, KVH, Dv)
    page_table: (B, n_blocks) int32 — logical block -> physical page
    pos:        (B,) int32 — per-slot position of the new token

    Gathers pages into a position-ordered dense view and reuses the dense
    oracle, so paged-vs-dense equivalence is exact by construction.
    """
    k = gather_pages(k_pages, page_table)
    v = gather_pages(v_pages, page_table)
    valid = paged_valid_mask(page_table, k_pages.shape[1], pos, window=window)
    return decode_attention_ref(q, k, v, None, valid=valid, scale=scale)
