"""Distribution layer: sharding plans, RPU-style ring collective matmuls,
and cross-pod gradient compression."""
from repro.parallel.hints import shard_hint, sharding_rules
from repro.parallel.plan import ParallelPlan, make_plan
from repro.parallel.collective_matmul import (
    ring_allgather_matmul, ring_matmul_reducescatter, tp_linear_overlapped,
)
from repro.parallel.compression import (
    compressed_mean, tree_compressed_mean, init_error_state,
    int8_quantize, int8_dequantize,
)
