"""Assigned input shapes and ShapeDtypeStruct stand-ins per (arch x shape).

The four assigned LM shapes (seq_len x global_batch):
  train_4k     4,096 x 256    lowers ``train_step``
  prefill_32k  32,768 x 32    lowers the prefill forward
  decode_32k   32,768 x 128   lowers ``serve_step`` (1 new token, KV$ of S)
  long_500k    524,288 x 1    lowers ``serve_step``; sub-quadratic archs only

``input_specs`` returns weak-type-correct ``jax.ShapeDtypeStruct`` trees for
every model input (params / batch / cache) — shardable, no allocation.
Frontends are stubs per the assignment: ``[audio]`` provides frame
embeddings, ``[vlm]`` provides patch embeddings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.model import Model

# image tokens prepended for the VLM frontend stub (InternViT 448px ~ 256
# patch tokens per tile).
VLM_IMAGE_TOKENS = 256


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode | long_decode
    seq_len: int
    global_batch: int

    @property
    def step_kind(self) -> str:
        return {"train": "train", "prefill": "prefill",
                "decode": "decode", "long_decode": "decode"}[self.kind]


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "long_decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether this (arch x shape) cell is runnable, with the skip reason."""
    if shape.kind in ("decode", "long_decode") and not cfg.has_decode:
        return False, "encoder-only: no decode step"
    if shape.kind == "long_decode" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the data batch of a train/prefill step."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio":
        return {"features": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "labels": _sds((b, s), jnp.int32)}
    batch = {"tokens": _sds((b, s - (VLM_IMAGE_TOKENS if cfg.frontend == "vision" else 0)),
                            jnp.int32)}
    if cfg.frontend == "vision":
        batch["image_embeds"] = _sds((b, VLM_IMAGE_TOKENS, cfg.d_model),
                                     jnp.bfloat16)
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, model: Model):
    """(tokens, cache, cur_pos) ShapeDtypeStructs for a serve_step."""
    b, s = shape.global_batch, shape.seq_len
    tokens = _sds((b,), jnp.int32)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    cur_pos = _sds((), jnp.int32)
    return tokens, cache, cur_pos


def param_specs(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
