from repro.kernels.mxfp4_vmm.kernel import mxfp4_vmm
from repro.kernels.mxfp4_vmm.ops import mxfp4_matmul
from repro.kernels.mxfp4_vmm.ref import mxfp4_vmm_ref
