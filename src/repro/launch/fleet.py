"""Fleet serving launcher: simulate, plan, and cross-check a replica fleet.

Three modes over one seeded workload (``--arrival poisson|diurnal|mmpp``):

  **simulate** (default) — route the trace over ``--replicas`` simulated
  engine replicas with the prefix-affinity SLO router (or
  ``--router round-robin``; ``--compare-routers`` runs both) and print
  the fleet summary: TTFT/TPOT quantiles, SLO attainment, goodput,
  shed/retries, per-replica utilization.  The per-replica service model
  is the analytic memory-roofline table of the ``--sku``/``--hbmco``
  deployment; add ``--autoscale`` to close the loop with the reactive
  replica scaler.

  **--plan** — size the fleet from the trace's traffic envelope: resolve
  candidate (SKU, HBM-CO stack) specs via ``DeploymentSpec.resolve``,
  price them with the paper's provisioning models (TDP, die-mm2, J/tok),
  and report the cheapest feasible (SKU, replica-count) next to a fixed
  GPU baseline.

  **--calibrate** — build a small real ``ContinuousServeEngine``
  (``--arch`` reduced), time its steps into a latency table, replay the
  trace through engine AND simulator, and report the throughput ratio.

  PYTHONPATH=src python -m repro.launch.serve --fleet --requests 1200 \
      --arrival diurnal --rate 100 --replicas 4 --compare-routers
  PYTHONPATH=src python -m repro.launch.serve --fleet --plan \
      --arch qwen3-14b --no-reduced --weight-format mxfp4
  PYTHONPATH=src python -m repro.launch.serve --fleet --calibrate \
      --requests 40 --rate 30
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

from repro.configs import get_config, reduced_config
from repro.fleet import traffic as tr
from repro.fleet.autoscaler import (ReactiveAutoscaler, TrafficEnvelope,
                                    default_candidates, plan_candidate,
                                    plan_disagg_fleet, plan_fleet,
                                    replica_power_w)
from repro.fleet.router import SLO, PrefixAffinityRouter, RoundRobinRouter
from repro.fleet.simulator import (FleetSimulator, LatencyTable, ReplicaSpec,
                                   calibrate, cross_check)
from repro.models.model import build_model
from repro.runtime.deployment import DeploymentSpec


def gate_workload(n: int, seed: int, kind: str, rate: float,
                  prefix_len: int = 96, n_tenants: int = 12) -> tr.Trace:
    """The shared-prefix tenant workload the router gates run on."""
    lengths = tr.LengthMix(prompt_mean=128.0, prompt_sigma=0.25,
                           prompt_min=100, prompt_max=224, output_mean=24.0,
                           output_min=4, output_max=48)
    tenants = tr.TenantMix(n_tenants=n_tenants, prefix_len=prefix_len,
                           zipf_s=0.8)
    return tr.make_trace(n, seed, kind=kind, rate=rate, lengths=lengths,
                         tenants=tenants)


def gate_table() -> LatencyTable:
    """Synthetic service model for SKU-independent router experiments."""
    return LatencyTable(batches=(1, 4, 8), contexts=(32, 256),
                        decode_s=np.full((3, 2), 0.002),
                        prefill_chunk_s=0.002, prefill_chunk=32)


def _spec_from_args(args) -> DeploymentSpec:
    import jax.numpy as jnp
    cache = {"bf16": jnp.bfloat16, "f32": jnp.float32,
             "fp8": "fp8", "int8": "int8", None: None}[args.cache_dtype]
    return DeploymentSpec(
        sku=args.sku, hbmco=args.hbmco, max_len=args.max_len,
        weight_format=args.weight_format, cache_dtype=cache,
        max_slots=args.max_slots, stacks_per_device=args.stacks)


def _calib_path(args, cfg) -> str:
    """``experiments/calibration/<arch>--<sku-key>.json`` — the (arch,
    SKU) key the calibrated table is persisted and looked up under."""
    sku = args.sku if args.sku != "rpu-cu" else f"rpu-cu{args.stacks}"
    return os.path.join(args.calibration_dir, f"{cfg.name}--{sku}.json")


def _simulate(args, trace: tr.Trace, slo: SLO) -> int:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    spec = _spec_from_args(args)
    # a persisted calibration for this (arch, SKU) beats the analytic
    # roofline; the roofline beats the synthetic gate table
    table = None
    cpath = _calib_path(args, cfg)
    if os.path.exists(cpath):
        table = LatencyTable.load(cpath)
        print(f"using calibrated table {cpath}")
    try:
        resolved = spec.resolve(model)
        if table is None:
            table = LatencyTable.from_roofline(resolved)
        num_slots = resolved.num_slots
        power = replica_power_w(spec, resolved.tp)
    except Exception as e:   # tiny reduced models may not resolve a SKU
        if table is None:
            print(f"note: roofline table unavailable ({e}); "
                  f"using the synthetic gate table")
            table = gate_table()
        num_slots, power = 8, None
    rspec = ReplicaSpec(latency=table, num_slots=num_slots,
                        max_queue=2 * num_slots, page_size=spec.page_size,
                        prefix_blocks=args.prefix_blocks, power_w=power)
    routers = {"affinity": lambda: PrefixAffinityRouter(slo=slo),
               "round-robin": lambda: RoundRobinRouter(slo=slo)}
    names = list(routers) if args.compare_routers else [args.router]
    for name in names:
        scaler = ReactiveAutoscaler(min_replicas=1,
                                    max_replicas=4 * args.replicas) \
            if args.autoscale else None
        sim = FleetSimulator(rspec, args.replicas, routers[name](),
                             autoscaler=scaler)
        fs = sim.run(trace)
        print(f"--- router={name}")
        print(json.dumps(fs.summary(slo), indent=2))
        if scaler is not None and scaler.decisions:
            print("autoscaler decisions:", scaler.decisions)
    return 0


def _plan(args, trace: tr.Trace, slo: SLO) -> int:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    env = TrafficEnvelope.from_trace(trace)
    print(f"envelope: peak {env.peak_rate:.1f} req/s, "
          f"mean {env.mean_rate:.1f} req/s, "
          f"prompt ~{env.mean_prompt:.0f} tok, "
          f"output ~{env.mean_output:.0f} tok "
          f"-> peak decode {env.peak_decode_tokens_per_s:.0f} tok/s")
    base = _spec_from_args(args)
    best, plans = plan_fleet(model, env, slo, default_candidates(model, base),
                             headroom=args.headroom)
    for p in plans:
        print(json.dumps(p.as_dict()))
    baseline = plan_candidate(
        model, dataclasses.replace(base, sku=args.baseline_sku, hbmco=None),
        env, slo, headroom=args.headroom)
    print(f"chosen: {best.name} x {best.replicas} "
          f"({best.die_mm2:.0f} mm2, {best.power_w:.0f} W fleet)")
    print(f"baseline {baseline.name} x {baseline.replicas}: "
          f"{baseline.die_mm2 / best.die_mm2:.1f}x die, "
          f"{baseline.energy_j_per_token / best.energy_j_per_token:.1f}x "
          f"J/token vs chosen")
    if args.disagg:
        cands = default_candidates(model, base)
        dbest, dplans = plan_disagg_fleet(model, env, slo, cands, cands,
                                          headroom=args.headroom,
                                          handoff_gbs=args.handoff_gbs)
        print("--- disaggregated (phase-specialized SKUs)")
        for p in dplans:
            if p.feasible:
                print(json.dumps(p.as_dict()))
        print(f"chosen: {dbest.prefill.name} x {dbest.prefill.replicas} "
              f"prefill + {dbest.decode.name} x {dbest.decode.replicas} "
              f"decode ({dbest.die_mm2:.0f} mm2, {dbest.power_w:.0f} W, "
              f"{dbest.energy_j_per_token:.4f} J/tok)")
        print(f"vs colocated {best.name} x {best.replicas}: "
              f"{best.die_mm2 / dbest.die_mm2:.2f}x die, "
              f"{best.energy_j_per_token / dbest.energy_j_per_token:.2f}x "
              f"J/token")
    return 0


def _calibrate(args, trace: tr.Trace, slo: SLO) -> int:
    import jax
    import jax.numpy as jnp
    from repro.runtime.engine import ContinuousServeEngine

    cfg = reduced_config(get_config(args.arch))
    model = build_model(cfg)
    params = jax.device_put(model.init(jax.random.PRNGKey(args.seed)))
    if trace.vocab > cfg.vocab_size:
        # materialized prompts must be valid token ids for the reduced
        # model that replays them (presence rows index by token id)
        trace = dataclasses.replace(trace, vocab=cfg.vocab_size)
    max_len = max(trace.lengths.prompt_max + trace.lengths.output_max + 8,
                  args.max_len)
    eng = ContinuousServeEngine(
        model, params, num_slots=8, page_size=16,
        num_pages=1 + 16 * -(-max_len // 16), max_len=max_len,
        cache_dtype=jnp.float32, prefill_chunk=32,
        enable_prefix_cache=False)
    res = cross_check(eng, trace)
    table = LatencyTable.from_dict(res.pop("table"))
    cpath = _calib_path(args, cfg)
    table.save(cpath)
    print(f"calibration table -> {cpath}")
    print(json.dumps(res, indent=2))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.fleet")
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--plan", action="store_true",
                    help="size the fleet from the traffic envelope")
    ap.add_argument("--calibrate", action="store_true",
                    help="calibrate a real engine + cross-check the sim")
    ap.add_argument("--requests", type=int, default=1200)
    ap.add_argument("--rate", type=float, default=100.0, help="req/s mean")
    ap.add_argument("--arrival", default="diurnal",
                    choices=list(tr.ARRIVAL_KINDS))
    ap.add_argument("--tenants", type=int, default=12)
    ap.add_argument("--prefix-len", type=int, default=96,
                    help="shared tokens per tenant (system prompt)")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--router", default="affinity",
                    choices=["affinity", "round-robin"])
    ap.add_argument("--compare-routers", action="store_true")
    ap.add_argument("--autoscale", action="store_true",
                    help="closed-loop replica scaling during the sim")
    ap.add_argument("--ttft-slo", type=float, default=0.025,
                    help="seconds, arrival -> first token")
    ap.add_argument("--tpot-slo", type=float, default=0.012,
                    help="seconds per token after the first")
    ap.add_argument("--prefix-blocks", type=int, default=24,
                    help="per-replica prefix-index capacity (blocks)")
    ap.add_argument("--sku", default="rpu-cu")
    ap.add_argument("--hbmco", default=None)
    ap.add_argument("--stacks", type=int, default=2)
    ap.add_argument("--weight-format", default=None)
    ap.add_argument("--cache-dtype", default=None,
                    choices=["bf16", "f32", "fp8", "int8"])
    ap.add_argument("--max-slots", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--headroom", type=float, default=1.25)
    ap.add_argument("--baseline-sku", default="h200")
    ap.add_argument("--disagg", action="store_true",
                    help="with --plan: also price phase-specialized "
                         "prefill/decode SKU pairings")
    ap.add_argument("--handoff-gbs", type=float, default=64.0,
                    help="KV handoff bandwidth between phases, GB/s")
    ap.add_argument("--calibration-dir", default="experiments/calibration",
                    help="persisted (arch, SKU) latency tables: "
                         "--calibrate writes, simulate reads")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    trace = gate_workload(args.requests, args.seed, args.arrival, args.rate,
                          prefix_len=args.prefix_len,
                          n_tenants=args.tenants)
    slo = SLO(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo)
    print(f"trace: {len(trace.requests)} requests over "
          f"{trace.duration:.1f}s ({args.arrival}, seed {args.seed})")
    if args.plan:
        return _plan(args, trace, slo)
    if args.calibrate:
        return _calibrate(args, trace, slo)
    return _simulate(args, trace, slo)


if __name__ == "__main__":
    raise SystemExit(main())
