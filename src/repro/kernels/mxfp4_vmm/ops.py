"""Public op wrapper for the MXFP4 VMM kernel.

``mxfp4_matmul`` is the user-facing op: takes a ``PackedMXFP4`` weight and
(B, K) activations, dispatches to the Pallas kernel (interpret-mode on CPU,
compiled on TPU), and falls back to the jnp oracle for shapes the kernel's
tiling can't cover (tiny smoke configs).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.formats import MX_BLOCK, PackedMXFP4
from repro.kernels import on_cpu
from repro.kernels.mxfp4_vmm.kernel import mxfp4_vmm
from repro.kernels.mxfp4_vmm.ref import mxfp4_vmm_ref


def mxfp4_matmul(x: jnp.ndarray, w: PackedMXFP4, *,
                 block_n: int = 256, block_k: int = 512,
                 out_dtype=jnp.bfloat16) -> jnp.ndarray:
    """x: (..., K) @ dequant(w): (K, N) -> (..., N)."""
    k, n = w.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k).astype(jnp.bfloat16)
    bk = min(block_k, k)
    bn = min(block_n, n)
    tileable = (k % bk == 0 and bk % MX_BLOCK == 0 and n % bn == 0)
    if not tileable:
        out = mxfp4_vmm_ref(x2, w.codes, w.scales)
    else:
        out = mxfp4_vmm(x2, w.codes, w.scales, block_n=bn, block_k=bk,
                        interpret=on_cpu())
    return out.reshape(*lead, n).astype(out_dtype)
