"""Public op: GQA-aware fused attention for train/prefill.

Flattens (B, S, H, D) attention onto the kernel's (BH, S, D) layout,
expanding GQA KV heads.  Dispatches to the Pallas kernel (interpret mode
on CPU, compiled on TPU); shapes the kernel's tiling can't cover fall
back to the jnp oracle.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def gqa_flash_attention(q, k, v, *, causal: bool = True,
                        block_q: int = 512, block_k: int = 512):
    """q: (B, Sq, H, D); k/v: (B, Skv, KVH, D) -> (B, Sq, H, Dv)."""
    b, sq, h, d = q.shape
    skv, kvh, dv = k.shape[1], k.shape[2], v.shape[3]
    rep = h // kvh
    kf = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vf = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = kf.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    vt = vf.transpose(0, 2, 1, 3).reshape(b * h, skv, dv)
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq or skv % bk:
        out = flash_attention_ref(qt, kt, vt, causal=causal)
    else:
        out = flash_attention(qt, kt, vt, block_q=bq, block_k=bk,
                              causal=causal, interpret=on_cpu())
    return out.reshape(b, h, sq, dv).transpose(0, 2, 1, 3)
