"""Pure-jnp oracle for the MXFP4 stream-decoded VMM."""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.formats import PackedMXFP4, dequantize_mxfp4


def mxfp4_vmm_ref(x: jnp.ndarray, codes: jnp.ndarray, scales: jnp.ndarray
                  ) -> jnp.ndarray:
    """Dequantize the whole matrix, then a plain fp32-accumulating matmul."""
    k = x.shape[1]
    n = codes.shape[1]
    w = dequantize_mxfp4(PackedMXFP4(codes, scales, (k, n)), jnp.bfloat16)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)
