"""Replica routing: prefix affinity x load, SLO admission, retry/shed.

The router sees a fleet of replica *views* — anything exposing the small
protocol below (the discrete-event simulator's replicas implement it; a
live serving tier would back it with engine telemetry) — and returns a
:class:`RouteDecision` per request:

- ``admit``: send to the chosen replica.
- ``retry``: every replica is saturated; come back after a backoff
  (bounded — after ``max_retries`` the request is shed instead).
- ``shed``: predicted TTFT or TPOT exceeds the SLO on every candidate,
  or retries ran out.  Shedding at the door is what protects the SLO of
  requests already admitted.

Scoring.  Each candidate replica gets

    score = affinity_weight * (matched_prefix_tokens / prompt_len)
          - load_weight * normalized_load

where ``matched_prefix_tokens`` comes from the replica's view of its
prefix-cache index keyed by the chained block hashes of
``runtime.kv_cache`` (the router hands it the request's hash chain, the
replica reports how many leading blocks it still holds).  Affinity
concentrates a tenant's shared prefix on one replica — one cold prefill
per tenant instead of one per replica — while the load term keeps a hot
tenant from melting its favourite replica.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Protocol, Sequence


@dataclasses.dataclass(frozen=True)
class SLO:
    """Latency targets a served request must meet."""
    ttft_s: float = 2.0       # arrival -> first token
    tpot_s: float = 0.25      # mean inter-token gap after the first

    def met(self, ttft: float | None, tpot: float | None) -> bool:
        """True when a finished request hit both targets (a request with
        no measurable TPOT — single-token output — only needs TTFT)."""
        if ttft is None or ttft > self.ttft_s:
            return False
        return tpot is None or tpot <= self.tpot_s


class ReplicaView(Protocol):
    """What a router needs to know about one replica."""

    def queue_depth(self) -> int:
        """Requests admitted but not finished (running + queued)."""
        ...

    def load(self) -> float:
        """queue_depth normalized by decode slots (1.0 = slots full)."""
        ...

    def saturated(self) -> bool:
        """Admission would exceed the replica's queue bound."""
        ...

    def match_tokens(self, chain: Sequence[bytes]) -> int:
        """Prompt tokens covered by the longest *leading* run of the hash
        chain present in this replica's prefix index."""
        ...

    def predicted_ttft(self, now: float, prompt_len: int,
                       hit_tokens: int) -> float:
        """Estimated arrival->first-token if admitted now."""
        ...

    def predicted_tpot(self) -> float:
        """Estimated steady-state inter-token seconds at current load."""
        ...


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    action: str                    # "admit" | "retry" | "shed"
    replica: int | None = None     # index into the replica list (admit)
    hit_tokens: int = 0            # predicted prefix-cache hit (admit)
    predicted_ttft: float | None = None
    predicted_tpot: float | None = None
    delay_s: float = 0.0           # backoff before re-routing (retry)
    reason: str = ""


class PrefixAffinityRouter:
    """Score replicas by prefix affinity minus load; admit under SLO."""

    def __init__(self, *, slo: SLO | None = None,
                 affinity_weight: float = 1.0, load_weight: float = 0.5,
                 slo_slack: float = 1.0, retry_backoff_s: float = 0.05,
                 max_retries: int = 3):
        self.slo = slo
        self.affinity_weight = affinity_weight
        self.load_weight = load_weight
        self.slo_slack = slo_slack          # admit while pred <= slo*slack
        self.retry_backoff_s = retry_backoff_s
        self.max_retries = max_retries
        self.admitted = 0
        self.retried = 0
        self.shed = 0
        # drain handoff: block hash -> replica view adopted as the new
        # home for that prefix when its old replica was scaled down.
        # Entries give a partial affinity bonus until the adoptive
        # replica's own index warms up; the LRU cap bounds staleness.
        self.placement: OrderedDict = OrderedDict()
        self.placement_cap = 4096

    def adopt_placement(self, keys: Sequence[bytes], replica) -> int:
        """Point a draining replica's prefix heat at ``replica`` so
        tenant affinity survives the scale-down (simulator calls this
        when it marks a victim draining). Returns entries adopted."""
        n = 0
        for h in keys:
            self.placement[h] = replica
            self.placement.move_to_end(h)
            n += 1
        while len(self.placement) > self.placement_cap:
            self.placement.popitem(last=False)
        return n

    def _adopted_frac(self, chain: Sequence[bytes], rep) -> float:
        """Leading fraction of the chain whose adopted home is ``rep``."""
        n = 0
        for h in chain:
            if self.placement.get(h) is not rep:
                break
            n += 1
        return n / max(len(chain), 1)

    # -- scoring (overridable) --
    def order(self, now: float, prompt_len: int, chain: Sequence[bytes],
              replicas: Sequence[ReplicaView]) -> list[tuple[float, int, int]]:
        """(score, hit_tokens, index) per replica, best first."""
        scored = []
        for i, rep in enumerate(replicas):
            hit = rep.match_tokens(chain)
            score = (self.affinity_weight * hit / max(prompt_len, 1)
                     - self.load_weight * rep.load())
            if self.placement:
                # half-strength credit: the blocks were promised to this
                # replica at drain time but may not be resident yet
                score += 0.5 * self.affinity_weight \
                    * self._adopted_frac(chain, rep)
            scored.append((score, hit, i))
        scored.sort(key=lambda t: (-t[0], t[2]))
        return scored

    def route(self, now: float, prompt_len: int, chain: Sequence[bytes],
              replicas: Sequence[ReplicaView], *,
              retries: int = 0) -> RouteDecision:
        best_over_slo = None
        for score, hit, i in self.order(now, prompt_len, chain, replicas):
            rep = replicas[i]
            if rep.saturated():
                continue
            ttft = rep.predicted_ttft(now, prompt_len, hit)
            tpot = rep.predicted_tpot()
            if self.slo is not None:
                if ttft > self.slo.ttft_s * self.slo_slack or \
                        tpot > self.slo.tpot_s * self.slo_slack:
                    if best_over_slo is None:
                        best_over_slo = (i, ttft, tpot)
                    continue
            self.admitted += 1
            return RouteDecision("admit", replica=i, hit_tokens=hit,
                                 predicted_ttft=ttft, predicted_tpot=tpot)
        if best_over_slo is not None:
            i, ttft, tpot = best_over_slo
            self.shed += 1
            return RouteDecision("shed", predicted_ttft=ttft,
                                 predicted_tpot=tpot,
                                 reason="predicted SLO violation")
        # every replica saturated: bounded retry with backoff, then shed
        if retries < self.max_retries:
            self.retried += 1
            return RouteDecision("retry",
                                 delay_s=self.retry_backoff_s * (2 ** retries),
                                 reason="all replicas saturated")
        self.shed += 1
        return RouteDecision("shed", reason="retries exhausted")


class RoundRobinRouter(PrefixAffinityRouter):
    """Baseline: same SLO admission and retry/shed policy, but candidate
    order cycles round-robin and ignores prefix affinity entirely."""

    def __init__(self, **kw):
        kw.setdefault("affinity_weight", 0.0)
        super().__init__(**kw)
        self._next = 0

    def order(self, now, prompt_len, chain, replicas):
        n = len(replicas)
        start = self._next
        self._next = (self._next + 1) % n
        return [(0.0, 0, (start + k) % n) for k in range(n)]
