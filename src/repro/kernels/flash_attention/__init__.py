from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import gqa_flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
