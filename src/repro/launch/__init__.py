"""Launch layer: production mesh, dry-run, and train/serve drivers."""
