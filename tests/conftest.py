"""Shared fixtures.  NOTE: no XLA device-count flag here — smoke tests and
benches must see the real (single) CPU device; only launch/dryrun.py forces
512 host devices, in its own process."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced_config
from repro.models.model import build_model


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def make_batch(cfg, key, b=2, s=32):
    if cfg.frontend == "audio":
        return {"features": jax.random.normal(key, (b, s, cfg.d_model),
                                              jnp.bfloat16),
                "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 7), (b, 8, cfg.d_model), jnp.bfloat16)
    return batch
