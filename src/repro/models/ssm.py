"""Mamba2 SSD (state-space duality) layer — chunked prefill + recurrent decode.

Follows the Mamba-2 formulation (arXiv:2405.21060):
  in_proj -> [z, x, B, C, dt]; depthwise causal conv over [x, B, C];
  SSD:  h_t = h_{t-1} * exp(dt_t * A) + dt_t * (B_t ⊗ x_t)
        y_t = C_t · h_t + D * x_t
  gated RMSNorm(y, z) -> out_proj.

Prefill/training uses the **chunked** algorithm: within chunks of length Q
the recurrence is expanded into a (Q x Q) lower-triangular attention-like
form; across chunks the state is carried by a sequential ``lax.scan`` (one
step per chunk — S/Q steps, tiny matmuls, O(1) HLO).  Decode is the exact
single-step recurrence over the carried state — this is the attention-free
fast path that makes the ``long_500k`` shape trivial (constant state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, gated_rmsnorm, split_keys


def init_ssm(key, cfg: ModelConfig) -> dict:
    """SSM mixer parameters.

    The input projection is stored as four separate matrices (w_z, w_x,
    w_bc, w_dt) rather than one fused [z|x|B|C|dt] matrix: the fused
    layout cannot be column-sharded without slicing across segment
    boundaries, which is why naive TP replicates SSM blocks (the 16x
    redundancy the §Perf SSM hillclimb removes).  w_z/w_x column-shard
    over the model axis (head dim); w_bc/w_dt are small and replicated.
    """
    d, di = cfg.d_model, cfg.d_inner
    h, p, n, g = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    conv_dim = di + 2 * g * n
    ks = split_keys(key, 6)
    return {
        "w_z": dense_init(ks[0], d, di),
        "w_x": dense_init(ks[1], d, di),
        "w_bc": dense_init(ks[2], d, 2 * g * n),
        "w_dt": dense_init(ks[3], d, h),
        "conv_w": (jax.random.normal(ks[4], (cfg.conv_kernel, conv_dim), jnp.float32)
                   * 0.1).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((conv_dim,), jnp.bfloat16),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d),
    }


def _in_proj(x: jnp.ndarray, p: dict, cfg: ModelConfig):
    """Split input projections -> (z, x_in, b, c, dt)."""
    g, n = cfg.ssm_groups, cfg.ssm_state
    z = x @ p["w_z"]
    xin = x @ p["w_x"]
    bc = x @ p["w_bc"]
    dt = x @ p["w_dt"]
    b, c = jnp.split(bc, 2, axis=-1)
    return z, xin, b, c, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None, valid=None):
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).

    Returns (out, new_state) where state is the last (K-1) inputs.
    ``valid`` (B,) counts real (non-padding) positions per row; the carried
    state then ends at the valid boundary instead of the padded tail, so a
    short chunk leaves exactly the state a full-length pass would have
    (``valid=0`` rows return their incoming state unchanged).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    out = jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)).astype(x.dtype)
    if k <= 1:
        return out, state
    if valid is None:
        return out, xp[:, -(k - 1):]
    # window of the last K-1 inputs ENDING at the valid position:
    # new_state[b, j] = xp[b, valid_b + j] (valid = S reproduces the tail)
    idx = valid[:, None] + jnp.arange(k - 1)[None, :]            # (B, K-1)
    new_state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return out, new_state


def _ssd_chunked(x, b, c, dt, A, cfg: ModelConfig, h0=None):
    """Chunked SSD scan.

    x : (B, S, H, P)   b,c : (B, S, G, N)   dt : (B, S, H)   A : (H,)
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    B_, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    Q = min(cfg.ssm_chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    # heads share groups: expand group-wise B/C to heads
    rep = H // G
    bh = jnp.repeat(b, rep, axis=2)                    # (B, S, H, N)
    ch = jnp.repeat(c, rep, axis=2)

    xc = x.reshape(B_, nc, Q, H, P).astype(jnp.float32)
    bc_ = bh.reshape(B_, nc, Q, H, N).astype(jnp.float32)
    cc = ch.reshape(B_, nc, Q, H, N).astype(jnp.float32)
    dtc = dt.reshape(B_, nc, Q, H).astype(jnp.float32)

    dA = dtc * (-A)[None, None, None, :]               # decay exponents <= 0
    # cumulative within chunk: L[i,j] = exp(sum_{j<k<=i} dA_k), j<=i
    cum = jnp.cumsum(dA, axis=2)                       # (B, nc, Q, H)

    # intra-chunk ("diagonal block") output:
    # y_intra[i] = sum_{j<=i} C_i . B_j exp(cum_i - cum_j) dt_j x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: masked entries can have seg >> 0, whose exp is +inf
    # and poisons the backward pass through jnp.where (NaN x 0 = NaN).
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bnqhs,bnkhs->bnqkh", cc, bc_)          # (B,nc,Q,Q,H)
    att = scores * decay
    xdt = xc * dtc[..., None]                                   # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bnqkh,bnkhp->bnqhp", att, xdt)

    # chunk-final states: h_chunk = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)             # (B,nc,Q,H)
    hc = jnp.einsum("bnqh,bnqhs,bnqhp->bnhps", decay_to_end, bc_, xdt)

    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # (B,nc,H)

    def scan_fn(h, inp):
        hc_n, cd_n = inp                                        # (B,H,P,N),(B,H)
        h_out = h                                               # state entering chunk
        h_next = h * cd_n[..., None, None] + hc_n
        return h_next, h_out

    h_init = (jnp.zeros((B_, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    hcs = jnp.moveaxis(hc, 1, 0)                                # (nc,B,H,P,N)
    cds = jnp.moveaxis(chunk_decay, 1, 0)                       # (nc,B,H)
    h_final, h_enter = jax.lax.scan(scan_fn, h_init, (hcs, cds))
    # inter-chunk contribution: y_inter[i] = C_i . (exp(cum_i) h_enter)
    h_enter = jnp.moveaxis(h_enter, 0, 1)                       # (B,nc,H,P,N)
    y_inter = jnp.einsum("bnqhs,bnqh,bnhps->bnqhp",
                         cc, jnp.exp(cum), h_enter)

    y = (y_intra + y_inter).reshape(B_, Sp, H, P)[:, :S]
    return y, h_final


def ssm_forward(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                state: dict | None = None, valid=None):
    """Full SSM mixer over (B, S, D).  Returns (out, new_state).

    ``valid`` (B,) int32 masks per-row padding at the tail of the chunk:
    padded positions enter the SSD with ``dt = 0`` (decay ``exp(0) = 1``,
    zero input — an identity state update), and the conv state is taken at
    the valid boundary, so ``new_state`` equals what an unpadded pass over
    the first ``valid`` tokens would produce.  Outputs at padded positions
    are garbage and must be discarded by the caller.
    """
    B_, S, D = x.shape
    H, P, N, G = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xin, b, c, dt = _in_proj(x, p, cfg)

    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state, valid=valid)
    xin, b, c = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)

    xh = xin.reshape(B_, S, H, P)
    bg = b.reshape(B_, S, G, N)
    cg = c.reshape(B_, S, G, N)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    if valid is not None:
        ok = jnp.arange(S)[None, :] < valid[:, None]             # (B, S)
        dt_sp = jnp.where(ok[:, :, None], dt_sp, 0.0)
    A = jnp.exp(p["A_log"])

    h0 = None if state is None else state["ssm"]
    y, h_final = _ssd_chunked(xh, bg, cg, dt_sp, A, cfg, h0)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, S, cfg.d_inner).astype(x.dtype)

    out = gated_rmsnorm(y, z, p["norm_w"], cfg.norm_eps) @ p["out_proj"]
    new_state = {"conv": new_conv, "ssm": h_final}
    return out, new_state


def ssm_decode_step(x: jnp.ndarray, p: dict, cfg: ModelConfig, state: dict):
    """Single-token recurrent step.  x: (B, D); state carries conv+ssm."""
    B_, D = x.shape
    H, P, N, G = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xin, b, c, dt = _in_proj(x, p, cfg)

    conv_in = jnp.concatenate([xin, b, c], axis=-1)               # (B, C)
    k = p["conv_w"].shape[0]
    window = jnp.concatenate([state["conv"], conv_in[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = window[:, 1:]

    xin, b, c = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    xh = xin.reshape(B_, H, P).astype(jnp.float32)
    bg = jnp.repeat(b.reshape(B_, G, N), H // G, axis=1).astype(jnp.float32)
    cg = jnp.repeat(c.reshape(B_, G, N), H // G, axis=1).astype(jnp.float32)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])  # (B,H)
    A = jnp.exp(p["A_log"])

    h = state["ssm"]                                              # (B,H,P,N)
    decay = jnp.exp(-dt_sp * A[None, :])                          # (B,H)
    h_new = (h * decay[..., None, None]
             + jnp.einsum("bh,bhn,bhp->bhpn", dt_sp, bg, xh))
    y = jnp.einsum("bhn,bhpn->bhp", cg, h_new)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B_, cfg.d_inner).astype(x.dtype)

    out = gated_rmsnorm(y, z, p["norm_w"], cfg.norm_eps) @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": h_new}


def init_ssm_state(cfg: ModelConfig, batch: int) -> dict:
    H, P, N, G = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    conv_dim = cfg.d_inner + 2 * G * N
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), jnp.bfloat16),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }
