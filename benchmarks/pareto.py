"""Paper Fig 9: HBM-CO Pareto frontier for Llama3-405B on a 64-CU RPU —
energy per inference vs system memory capacity, with the optimal-SKU
annotation rule."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.hbmco import enumerate_design_space, pareto_frontier
from repro.models.footprint import compute_footprint
from repro.sim.scaling import rpu_point


def run() -> list[Row]:
    cfg = get_config("llama3-405b")
    fp = compute_footprint(cfg)
    frontier = pareto_frontier(enumerate_design_space())
    need_per_chiplet = fp.capacity_bytes(1, 8192) / (64 * 2)

    rows: list[Row] = []
    curve = []
    for sku in frontier:
        fits = sku.capacity_bytes >= need_per_chiplet
        p = rpu_point(cfg, 64, batch=1, seq_len=8192, sku=sku) if fits else None
        curve.append(f"{sku.capacity_mb:.0f}MB:"
                     f"{(p.sim.energy_j if p else float('nan')):.2f}J"
                     f"{'' if fits else '(too small)'}")
    rows.append(Row("Fig9", "energy/token across frontier SKUs (64CU, 405B)",
                    "  ".join(curve), None, "",
                    "smaller SKUs are more efficient but must fit the model"))
    opt = rpu_point(cfg, 64, batch=1, seq_len=8192)
    rows.append(Row("Fig9", "optimal SKU capacity per chiplet",
                    opt.sku.capacity_mb, None, " MB",
                    f"paper: 192MB/core-class optimum at 64 CUs; "
                    f"need={need_per_chiplet/2**20:.0f}MB"))
    rows.append(Row("Fig9", "unlocking smaller SKUs needs more CUs",
                    " ".join(
                        f"{n}CU:{rpu_point(cfg, n, batch=1, seq_len=8192).sku.capacity_mb:.0f}MB"
                        for n in (64, 128, 256, 428))))
    return rows
