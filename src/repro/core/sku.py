"""HBM-CO SKU selection map (paper Fig 10).

For a fixed-bandwidth RPU deployment (e.g. 64 CUs = 128 memory chiplets =
32 TB/s), system capacity is tuned by choosing the HBM-CO chiplet SKU from
the Pareto frontier: the smallest capacity that fits

    active parameter bytes + KV-cache bytes(batch, seq)

per device.  High-BW/Cap SKUs maximize efficiency but limit the supported
(batch x seq) envelope; this module reproduces the selection map and the
slowdown model of Fig 10 (bottom).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import hardware
from repro.core.hbmco import HBMCOConfig, enumerate_design_space, pareto_frontier, select_sku


@dataclasses.dataclass(frozen=True)
class WorkloadFootprint:
    """Capacity model of one LLM deployment."""

    name: str
    param_bytes: float                 # total stored parameters (quantized)
    kv_bytes_per_token: float          # per sequence-token KV$ footprint
    active_param_bytes: float          # bytes streamed per generated token

    def capacity_bytes(self, batch: int, seq_len: int) -> float:
        return self.param_bytes + self.kv_bytes_per_token * batch * seq_len

    def streamed_bytes_per_token(self, batch: int, seq_len: int) -> float:
        """Bytes that must be read from memory per generated token step:
        every active parameter once (batched queries share the read) plus
        each query's unique KV history (paper: 'KV$ entries are query-unique')."""
        return self.active_param_bytes + self.kv_bytes_per_token * batch * seq_len

    @classmethod
    def from_model(cls, model, *, weight_format: str | None = None,
                   cache_dtype=None) -> "WorkloadFootprint":
        """Footprint of a built model under a weight/KV quantization choice.

        ``weight_format`` is a ``repro.quant.formats`` name (None = bf16
        storage, 2 bytes/param); ``cache_dtype`` follows the paged-KV pool
        convention ("fp8"/"int8" strings or a jnp dtype, None = pool default).
        """
        from repro.models.footprint import compute_footprint
        from repro.parallel.plan import paged_kv_token_bytes
        from repro.quant import formats

        fp = compute_footprint(model.cfg)
        per = (formats.bits_per_element(weight_format) / 8.0
               if weight_format else 2.0)
        kv_tok = paged_kv_token_bytes(model, cache_dtype=cache_dtype)
        return cls(name=model.cfg.name,
                   param_bytes=fp.total_params * per,
                   kv_bytes_per_token=kv_tok,
                   active_param_bytes=fp.active_params * per)


@dataclasses.dataclass(frozen=True)
class SKUCell:
    batch: int
    seq_len: int
    sku: HBMCOConfig | None
    bw_per_cap: float | None
    slowdown_vs_ref: float | None
    kv_fraction: float | None          # fraction of streamed bytes that is KV$


def sku_map(
    workload: WorkloadFootprint,
    batches: Sequence[int],
    seq_lens: Sequence[int],
    *,
    n_cus: int = 64,
    rpu: hardware.RPUChipParams = hardware.RPU_DEFAULT,
    ref_batch: int = 1,
    ref_seq: int = 8192,
) -> list[SKUCell]:
    """Compute the Fig-10 style SKU selection + slowdown map.

    Slowdown is per-query token latency relative to (ref_batch, ref_seq):
    token_time = streamed_bytes / system_bw (memory-bound decode regime).
    """
    chiplets = n_cus * 2
    system_bw = n_cus * rpu.cu_mem_bw
    frontier = pareto_frontier(enumerate_design_space())
    ref_time = workload.streamed_bytes_per_token(ref_batch, ref_seq) / system_bw
    out: list[SKUCell] = []
    for b in batches:
        for s in seq_lens:
            need = workload.capacity_bytes(b, s) / chiplets
            sku = select_sku(need, frontier)
            streamed = workload.streamed_bytes_per_token(b, s)
            kv = workload.kv_bytes_per_token * b * s
            out.append(SKUCell(
                batch=b, seq_len=s, sku=sku,
                bw_per_cap=sku.bw_per_cap if sku else None,
                slowdown_vs_ref=(streamed / system_bw) / ref_time if sku else None,
                kv_fraction=kv / streamed if sku else None,
            ))
    return out
