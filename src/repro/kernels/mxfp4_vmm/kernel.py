"""MXFP4 weight-streaming VMM Pallas kernel — the TPU realization of the
RPU's Stream Decoder + TMAC stripe dataflow (paper §V, Fig 7).

Mapping of the paper's microarchitecture onto TPU/Pallas:

  paper                         | this kernel
  ------------------------------+------------------------------------------
  weights compressed in HBM     | codes (uint8 nibbles) + E8M0 scales in HBM
  memory DMA -> memory buffer   | Pallas grid pipeline HBM->VMEM (BlockSpec)
  Stream Decoder (fp4 -> bf16)  | branch-free arithmetic E2M1 decode in VMEM
  TMAC 8x8 weight-streaming     | MXU dot over (bk x bn) dequantized tile
  stripe-based execution        | grid = (N/bn outer, K/bk inner): for one
                                | output stripe, iterate K-tiles (output-
                                | stationary), then advance to next stripe
  output-stationary reg file    | out block revisited across the K grid dim
  decoupled mem/compute pipes   | Pallas double-buffers the next tile's DMA
                                | while the MXU works on the current tile

The kernel computes ``out[B, N] = x[B, K] @ dequant(codes, scales)[K, N]``
with fp32 accumulation.  K must be a multiple of the MX block (32) and of
``block_k``; layouts follow ``repro.quant.formats.PackedMXFP4``:
codes ``(K//2, N)`` (two K-nibbles per byte), scales ``(K//32, N)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.formats import MX_BLOCK

_E8M0_BIAS = 127.0


def _decode_e2m1(codes: jnp.ndarray) -> jnp.ndarray:
    """Branch-free E2M1 decode: uint8 code (0..15) -> f32 value.

    value = sign * (e == 0 ? 0.5*m : (1 + 0.5*m) * 2^(e-1))
    """
    c = codes.astype(jnp.int32)
    sign = 1.0 - 2.0 * ((c >> 3) & 1).astype(jnp.float32)
    e = ((c >> 1) & 3).astype(jnp.float32)
    m = (c & 1).astype(jnp.float32)
    sub = 0.5 * m
    norm = (1.0 + 0.5 * m) * jnp.exp2(e - 1.0)
    return sign * jnp.where(e == 0.0, sub, norm)


def _vmm_kernel(x_ref, codes_ref, scales_ref, out_ref, *, block_k: int,
                n_k_steps: int):
    """One (stripe j, K-tile k) grid step."""
    k_step = pl.program_id(1)

    # ---- Stream Decoder: dequantize the (block_k, bn) weight tile in VMEM
    packed = codes_ref[...]                          # (bk//2, bn) uint8
    lo = _decode_e2m1(packed & 0xF)                  # even k
    hi = _decode_e2m1(packed >> 4)                   # odd k
    vals = jnp.stack([lo, hi], axis=1)               # (bk//2, 2, bn)
    vals = vals.reshape(block_k, -1)                 # (bk, bn) interleaved

    exp = scales_ref[...].astype(jnp.float32) - _E8M0_BIAS   # (bk//32, bn)
    scale = jnp.repeat(jnp.exp2(exp), MX_BLOCK, axis=0)      # (bk, bn)
    w_tile = (vals * scale).astype(jnp.bfloat16)

    # ---- TMAC: MXU matmul with fp32 accumulation, output-stationary
    acc = jnp.dot(x_ref[...], w_tile, preferred_element_type=jnp.float32)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(k_step > 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def mxfp4_vmm(
    x: jnp.ndarray,        # (B, K) bf16 activations
    codes: jnp.ndarray,    # (K//2, N) uint8
    scales: jnp.ndarray,   # (K//32, N) uint8 (E8M0, bias 127)
    *,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Stream-decoded VMM: returns (B, N) f32."""
    b, k = x.shape
    n = codes.shape[1]
    assert codes.shape[0] == k // 2 and scales.shape[0] == k // MX_BLOCK
    block_k = min(block_k, k)
    block_n = min(block_n, n)
    assert k % block_k == 0 and block_k % MX_BLOCK == 0 and block_k % 2 == 0
    assert n % block_n == 0
    n_k_steps = k // block_k

    grid = (n // block_n, n_k_steps)
    return pl.pallas_call(
        functools.partial(_vmm_kernel, block_k=block_k, n_k_steps=n_k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, block_k), lambda j, kk: (0, kk)),
            pl.BlockSpec((block_k // 2, block_n), lambda j, kk: (kk, j)),
            pl.BlockSpec((block_k // MX_BLOCK, block_n), lambda j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scales)
