"""Serving runtime: engines, paged KV cache, scheduler, sampling, speculative."""
from repro.runtime.engine import (
    ContinuousServeEngine, ContinuousStats, ServeEngine, prefill_step_fn,
    serve_step_fn,
)
from repro.runtime.kv_cache import PageAllocator, PagedKVCache, SCRATCH_PAGE
from repro.runtime.sampling import greedy, sample, probs
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.speculative import speculative_generate, SpecStats, make_speculative_window
