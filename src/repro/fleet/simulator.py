"""Discrete-event fleet simulator over calibrated engine replicas.

The per-replica service model mirrors one ``ContinuousServeEngine``
iteration exactly as ``engine.step()`` executes it: admit from the
queue into free slots, advance every prefilling request by one chunk,
run one fused decode step over the decoding slots.  An iteration's cost
comes from a :class:`LatencyTable` — either **calibrated** by timing a
real engine (:func:`calibrate`) or derived analytically from a
``ResolvedDeployment``'s memory roofline
(:meth:`LatencyTable.from_roofline`) — so CI can push fleet-scale
traffic through the simulator in seconds and still speak in measured
units.

Scale comes from *jump batching*: when a replica's composition (who is
prefilling, who is decoding) cannot change for the next ``k``
iterations, the simulator advances all ``k`` at once — one heap event
per composition change, not per token.  ``k`` is capped by the nearest
prefill completion, the nearest decode finish, a context-refresh bound
(decode cost drifts as contexts grow), and a small admission-poll bound
while free slots remain, so arrivals are picked up promptly.

:func:`cross_check` closes the loop: calibrate a table from a real
engine, replay the same seeded trace through the simulator and the
engine, and compare throughput — the tolerance band every CI gate is
stated against.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from collections import OrderedDict, deque
from typing import Sequence

import numpy as np

from repro.fleet import traffic as tr
from repro.fleet.router import SLO, PrefixAffinityRouter, RouteDecision

# ---------------------------------------------------------------------------
# latency table
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LatencyTable:
    """Per-iteration engine costs keyed by (decode batch, context).

    ``decode_s[i, j]`` is one fused decode-step latency at
    ``batches[i]`` decoding slots and ``contexts[j]`` tokens of context;
    ``prefill_chunk_s`` is the cost of advancing one prefilling request
    by one chunk (per request — the engine batches rows, the table
    prices them linearly).  Lookup clamps + bilinearly interpolates, so
    any (b, ctx) inside or outside the grid resolves.
    """
    batches: tuple
    contexts: tuple
    decode_s: np.ndarray            # (len(batches), len(contexts))
    prefill_chunk_s: float
    prefill_chunk: int
    overhead_s: float = 0.0         # host bookkeeping per iteration

    def __post_init__(self):
        self.decode_s = np.asarray(self.decode_s, np.float64)
        if self.decode_s.shape != (len(self.batches), len(self.contexts)):
            raise ValueError("decode_s grid does not match batches/contexts")

    @staticmethod
    def _frac(grid: Sequence[float], x: float) -> tuple[int, int, float]:
        """Clamped linear-interpolation coordinates of x on a sorted grid."""
        if x <= grid[0] or len(grid) == 1:
            return 0, 0, 0.0
        if x >= grid[-1]:
            return len(grid) - 1, len(grid) - 1, 0.0
        import bisect
        hi = bisect.bisect_right(grid, x)
        lo = hi - 1
        return lo, hi, (x - grid[lo]) / (grid[hi] - grid[lo])

    def decode_step_s(self, batch: float, ctx: float) -> float:
        b0, b1, fb = self._frac(self.batches, batch)
        c0, c1, fc = self._frac(self.contexts, ctx)
        d = self.decode_s
        lo = d[b0, c0] * (1 - fc) + d[b0, c1] * fc
        hi = d[b1, c0] * (1 - fc) + d[b1, c1] * fc
        return float(lo * (1 - fb) + hi * fb)

    def iteration_s(self, n_prefill: int, n_decode: int, ctx: float) -> float:
        """One engine iteration at this composition (see ``step()``)."""
        s = self.overhead_s
        if n_prefill:
            s += self.prefill_chunk_s * n_prefill
        if n_decode:
            s += self.decode_step_s(n_decode, ctx)
        return s

    def as_dict(self) -> dict:
        return {"batches": list(self.batches),
                "contexts": list(self.contexts),
                "decode_s": self.decode_s.tolist(),
                "prefill_chunk_s": self.prefill_chunk_s,
                "prefill_chunk": self.prefill_chunk,
                "overhead_s": self.overhead_s}

    @classmethod
    def from_dict(cls, d: dict) -> "LatencyTable":
        return cls(batches=tuple(d["batches"]),
                   contexts=tuple(d["contexts"]),
                   decode_s=np.asarray(d["decode_s"]),
                   prefill_chunk_s=d["prefill_chunk_s"],
                   prefill_chunk=d["prefill_chunk"],
                   overhead_s=d.get("overhead_s", 0.0))

    def save(self, path: str) -> None:
        """Persist to JSON (``experiments/calibration/`` convention)."""
        import json
        import os
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=2)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "LatencyTable":
        import json
        with open(path) as f:
            return cls.from_dict(json.load(f))

    @classmethod
    def from_roofline(cls, resolved, *, batches=(1, 8, 32),
                      contexts=(64, 512, 2048)) -> "LatencyTable":
        """Analytic table from a ``ResolvedDeployment`` memory roofline.

        A decode step streams the active weights once plus every decoding
        slot's KV up to its context; a prefill chunk is priced at the
        same bandwidth over the chunk's KV writes (prefill is really
        compute-bound — this floor is deliberately optimistic, the
        calibrated path is the accurate one).  The active-weight stream
        is recovered from the deployment's own roofline:
        ``step_seconds = (active + slots*kv*ctx) / bw``.
        """
        bw = resolved.device.decode_bw
        kv = resolved.kv_token_bytes
        act = max(resolved.step_seconds * bw
                  - resolved.num_slots * kv * resolved.mean_context, 0.0)
        grid = np.empty((len(batches), len(contexts)))
        for i, b in enumerate(batches):
            for j, c in enumerate(contexts):
                grid[i, j] = (act + b * kv * c) / bw
        chunk_s = resolved.prefill_chunk * kv / bw
        return cls(batches=tuple(batches), contexts=tuple(contexts),
                   decode_s=grid, prefill_chunk_s=float(chunk_s),
                   prefill_chunk=int(resolved.prefill_chunk))


def calibrate(eng, *, batches=None, contexts=None, n_steps: int = 6,
              seed: int = 0) -> LatencyTable:
    """Time a real ``ContinuousServeEngine`` into a :class:`LatencyTable`.

    For each grid point the engine serves ``b`` fresh prompts of ``ctx``
    tokens: the prefill phase times chunk advancement, then ``n_steps``
    pure decode iterations are timed at that exact composition.  The grid
    is driven twice — the first pass exists only to compile every
    bucketed prefill/decode shape, the second pass is the one measured —
    so compile time never leaks into the table.  The engine is reset
    (not rebuilt) between points and is left reset afterwards.
    """
    from repro.runtime.scheduler import Request
    from repro.runtime.sampling import SamplingParams

    slots = eng.num_slots
    batches = tuple(batches) if batches else tuple(sorted(
        {1, max(1, slots // 2), slots}))
    max_ctx = eng.max_len - n_steps - 2
    contexts = tuple(contexts) if contexts else tuple(sorted(
        {eng.page_size, max(eng.page_size, max_ctx // 2)}))
    rng = np.random.default_rng(seed)
    grid = np.empty((len(batches), len(contexts)))
    chunk_times: list[float] = []

    def mk(b: int, plen: int) -> list[Request]:
        return [Request(rid=i, prompt=rng.integers(
                            0, eng.model.cfg.vocab_size, size=plen,
                            dtype=np.int64).astype(np.int32),
                        max_new_tokens=n_steps + 2,
                        sampling=SamplingParams(max_tokens=n_steps + 2))
                for i in range(b)]

    if eng.has_unfinished():
        raise RuntimeError("calibrate() needs an idle engine")
    for measured in (False, True):
        for i, b in enumerate(batches):
            for j, ctx in enumerate(contexts):
                plen = max(int(ctx) - 1, 2)
                eng.reset()
                for r in mk(b, plen):
                    eng.add_request(r)
                # drive + time the prefill phase: every step advances
                # each prefilling request by one chunk (one bucketed
                # batch), so per-row cost is measured/row-count
                while eng._sched.prefilling() or eng._sched.waiting:
                    npre = len(eng._sched.prefilling()) or b
                    t0 = time.perf_counter()
                    eng.step()
                    if measured:
                        chunk_times.append(
                            (time.perf_counter() - t0) / npre)
                # timed decode steps at exactly (b, ctx)
                ts = []
                for _ in range(n_steps):
                    t0 = time.perf_counter()
                    eng.step()
                    ts.append(time.perf_counter() - t0)
                if measured:
                    grid[i, j] = float(np.median(ts))
                while eng.has_unfinished():   # drain the margin tokens
                    eng.step()
                eng.reset()
    chunk_s = float(np.median(chunk_times)) if chunk_times else 0.0
    return LatencyTable(batches=batches, contexts=contexts, decode_s=grid,
                        prefill_chunk_s=chunk_s,
                        prefill_chunk=eng.prefill_chunk)


# ---------------------------------------------------------------------------
# simulated replica
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """Capacity + service model of one simulated engine replica."""
    latency: LatencyTable
    num_slots: int = 8
    max_queue: int = 16             # admitted-but-unscheduled bound
    page_size: int = 16
    prefix_blocks: int = 64         # prefix-index capacity (LRU, blocks)
    ctx_refresh: int = 64           # max iterations per jump
    admit_poll: int = 4             # jump cap while slots are free
    power_w: float | None = None    # TDP for energy accounting
    energy_j_per_token: float | None = None   # modeled override


class SimRequest:
    """Mutable per-request simulation state."""
    __slots__ = ("req", "chain", "arrival", "admit_t", "first_tok_t",
                 "finish_t", "replica", "hit_tokens", "remaining_prefill",
                 "emitted", "retries", "shed_reason")

    def __init__(self, req: tr.FleetRequest, chain: tuple):
        self.req = req
        self.chain = chain
        self.arrival = req.arrival
        self.admit_t = None
        self.first_tok_t = None
        self.finish_t = None
        self.replica = None
        self.hit_tokens = 0
        self.remaining_prefill = req.prompt_len
        self.emitted = 0
        self.retries = 0
        self.shed_reason = None

    @property
    def ttft(self):
        if self.first_tok_t is None:
            return None
        return self.first_tok_t - self.arrival

    @property
    def tpot(self):
        if self.finish_t is None or self.first_tok_t is None \
                or self.req.output_len <= 1:
            return None
        return (self.finish_t - self.first_tok_t) / (self.req.output_len - 1)


class SimReplica:
    """One engine replica: slots, queue, prefix index, iteration plan."""

    def __init__(self, spec: ReplicaSpec):
        self.spec = spec
        self.queue: deque[SimRequest] = deque()
        self.running: list[SimRequest] = []
        self.prefix: OrderedDict = OrderedDict()    # block hash -> None
        self.t = 0.0                # simulated up to here
        self.plan = None            # (t_end, k, iter_s) when a jump is active
        self.busy_s = 0.0
        self.iterations = 0
        self.tokens_out = 0
        self.draining = False

    # ---- ReplicaView protocol (router-facing) ----
    def queue_depth(self) -> int:
        return len(self.running) + len(self.queue)

    def load(self) -> float:
        return self.queue_depth() / max(self.spec.num_slots, 1)

    def saturated(self) -> bool:
        return self.draining or len(self.queue) >= self.spec.max_queue

    def match_tokens(self, chain: Sequence[bytes]) -> int:
        n = 0
        for h in chain:
            if h not in self.prefix:
                break
            self.prefix.move_to_end(h)
            n += 1
        return n * self.spec.page_size

    def _mean_ctx(self) -> float:
        dec = [r for r in self.running if r.remaining_prefill == 0]
        if not dec:
            return float(self.spec.latency.contexts[0])
        return float(np.mean([r.req.prompt_len + r.emitted for r in dec]))

    def predicted_ttft(self, now: float, prompt_len: int,
                       hit_tokens: int) -> float:
        lt = self.spec.latency
        chunk = lt.prefill_chunk
        own = -(-(max(prompt_len - hit_tokens, 1)) // chunk)
        ahead = sum(-(-r.remaining_prefill // chunk)
                    for r in self.running if r.remaining_prefill > 0)
        ahead += sum(-(-r.req.prompt_len // chunk) for r in self.queue)
        n_dec = sum(1 for r in self.running if r.remaining_prefill == 0)
        iter_est = lt.iteration_s(1, max(n_dec, 1), self._mean_ctx())
        # queue overflow waits for running requests to finish and free slots
        overflow = max(0, self.queue_depth() + 1 - self.spec.num_slots)
        slot_wait = 0.0
        if overflow:
            rem = sorted(max(r.req.output_len - r.emitted, 1)
                         for r in self.running)
            mean_rem = float(np.mean(rem)) if rem else 1.0
            slot_wait = mean_rem * iter_est * \
                (overflow / max(self.spec.num_slots, 1) + 0.5)
        return slot_wait + (own + ahead) * iter_est

    def predicted_tpot(self) -> float:
        lt = self.spec.latency
        b = min(self.spec.num_slots, self.queue_depth() + 1)
        s = lt.decode_step_s(max(b, 1), self._mean_ctx()) + lt.overhead_s
        if any(r.remaining_prefill > 0 for r in self.running) or self.queue:
            s += lt.prefill_chunk_s       # interleaved chunks slow decode
        return s

    # ---- admission into slots (mirrors Scheduler.admit) ----
    def _admit(self, now: float):
        while self.queue and len(self.running) < self.spec.num_slots:
            sr = self.queue.popleft()
            hit = min(self.match_tokens(sr.chain),
                      max(sr.req.prompt_len - 1, 0))
            sr.hit_tokens = hit
            sr.remaining_prefill = sr.req.prompt_len - hit
            sr.admit_t = now
            # the request's own blocks become resident (LRU, bounded)
            for h in sr.chain:
                self.prefix[h] = None
                self.prefix.move_to_end(h)
            while len(self.prefix) > self.spec.prefix_blocks:
                self.prefix.popitem(last=False)
            self.running.append(sr)


# ---------------------------------------------------------------------------
# fleet stats
# ---------------------------------------------------------------------------


def _quantiles(vals: Sequence[float]) -> dict | None:
    ts = sorted(vals)
    if not ts:
        return None
    def pct(q):
        return ts[min(len(ts) - 1, int(len(ts) * q))]
    return {"p50": pct(0.50), "p95": pct(0.95), "p99": pct(0.99),
            "mean": sum(ts) / len(ts)}


@dataclasses.dataclass
class FleetStats:
    """Outcome of one simulated run."""
    served: list                      # finished SimRequests
    shed: list                        # SimRequests rejected at the door
    duration: float
    replicas: int
    busy_s: list
    iterations: int
    retries: int
    energy_j: float | None
    handoffs: int = 0                 # disaggregated runs only
    handoff_bytes: float = 0.0
    handoff_shared_tokens: int = 0
    prefill_replicas: int = 0

    @property
    def total_tokens(self) -> int:
        return sum(s.req.output_len for s in self.served)

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / max(self.duration, 1e-9)

    def ttft_quantiles(self) -> dict | None:
        return _quantiles([s.ttft for s in self.served
                           if s.ttft is not None])

    def tpot_quantiles(self) -> dict | None:
        return _quantiles([s.tpot for s in self.served
                           if s.tpot is not None])

    def slo_attainment(self, slo: SLO) -> float:
        """Fraction of ALL arrivals (served + shed) that met the SLO."""
        n = len(self.served) + len(self.shed)
        if n == 0:
            return 0.0
        met = sum(1 for s in self.served if slo.met(s.ttft, s.tpot))
        return met / n

    def goodput_tokens_per_s(self, slo: SLO) -> float:
        """Output tokens of SLO-met requests per second — the metric the
        router is judged on (shed + SLO-missed tokens don't count)."""
        good = sum(s.req.output_len for s in self.served
                   if slo.met(s.ttft, s.tpot))
        return good / max(self.duration, 1e-9)

    @property
    def utilization(self) -> list:
        return [b / max(self.duration, 1e-9) for b in self.busy_s]

    def energy_j_per_token(self) -> float | None:
        if self.energy_j is None:
            return None
        return self.energy_j / max(self.total_tokens, 1)

    def summary(self, slo: SLO | None = None) -> dict:
        out = {
            "requests": len(self.served) + len(self.shed),
            "served": len(self.served),
            "shed": len(self.shed),
            "retries": self.retries,
            "duration_s": round(self.duration, 4),
            "replicas": self.replicas,
            "tokens_per_s": round(self.tokens_per_s, 2),
            "iterations": self.iterations,
            "mean_utilization": round(float(np.mean(self.utilization)), 4)
            if self.busy_s else 0.0,
            "ttft": self.ttft_quantiles(),
            "tpot": self.tpot_quantiles(),
        }
        if slo is not None:
            out["slo_attainment"] = round(self.slo_attainment(slo), 4)
            out["goodput_tokens_per_s"] = round(
                self.goodput_tokens_per_s(slo), 2)
        if self.prefill_replicas:
            out["prefill_replicas"] = self.prefill_replicas
            out["handoffs"] = self.handoffs
            out["handoff_bytes"] = round(self.handoff_bytes, 1)
            out["handoff_shared_tokens"] = self.handoff_shared_tokens
        if self.energy_j is not None:
            out["energy_j"] = round(self.energy_j, 2)
            out["energy_j_per_token"] = round(self.energy_j_per_token(), 6)
        return out


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

_ARRIVE, _WAKE, _SCALE = 0, 1, 2


class FleetSimulator:
    """Route a trace over simulated replicas and collect fleet stats.

    Events are (time, seq, kind, payload) on one heap; replicas advance
    by composition-constant iteration jumps (module docstring).  An
    optional :class:`~repro.fleet.autoscaler.ReactiveAutoscaler` is
    polled on a fixed interval and may add replicas or drain existing
    ones mid-run.
    """

    def __init__(self, spec: ReplicaSpec, n_replicas: int, router, *,
                 autoscaler=None):
        self.spec = spec
        self.router = router
        self.replicas = [SimReplica(spec) for _ in range(n_replicas)]
        self.autoscaler = autoscaler
        self._heap: list = []
        self._seq = 0
        self._retries = 0

    def _push(self, t: float, kind: int, payload):
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def run(self, trace: tr.Trace) -> FleetStats:
        chains = tr.tenant_chains(trace, self.spec.page_size)
        served: list[SimRequest] = []
        shed: list[SimRequest] = []
        for r in trace.requests:
            self._push(r.arrival, _ARRIVE, SimRequest(r, chains[r.tenant]))
        if self.autoscaler is not None:
            self._push(self.autoscaler.interval_s, _SCALE, None)
        t_end = 0.0
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            t_end = max(t_end, t)
            if kind == _ARRIVE:
                self._route(t, payload, shed)
            elif kind == _WAKE:
                rep = payload
                if rep.plan is not None and rep.plan[0] <= t + 1e-12:
                    self._apply_jump(t, rep, served)
                    self._plan(t, rep)
            else:   # _SCALE
                if any(h[2] != _SCALE for h in self._heap):
                    self._autoscale(t)
                    self._push(t + self.autoscaler.interval_s, _SCALE, None)
        duration = max(t_end, trace.duration)
        active = [r for r in self.replicas]
        return FleetStats(
            served=served, shed=shed, duration=duration,
            replicas=len(active), busy_s=[r.busy_s for r in active],
            iterations=sum(r.iterations for r in active),
            retries=self._retries,
            energy_j=self._energy(duration))

    def _energy(self, duration: float) -> float | None:
        sp = self.spec
        if sp.energy_j_per_token is not None:
            toks = sum(r.tokens_out for r in self.replicas)
            return sp.energy_j_per_token * toks
        if sp.power_w is not None:
            return sp.power_w * sum(r.busy_s for r in self.replicas)
        return None

    # ---- routing ----
    def _route(self, now: float, sr: SimRequest, shed: list):
        cand = [r for r in self.replicas if not r.draining] or self.replicas
        d: RouteDecision = self.router.route(
            now, sr.req.prompt_len, sr.chain, cand, retries=sr.retries)
        if d.action == "admit":
            rep = cand[d.replica]
            sr.replica = self.replicas.index(rep)
            rep.queue.append(sr)
            if rep.plan is None:
                self._plan(now, rep)
        elif d.action == "retry":
            sr.retries += 1
            self._retries += 1
            self._push(now + d.delay_s, _ARRIVE, sr)
        else:
            sr.shed_reason = d.reason
            shed.append(sr)

    # ---- the iteration-jump engine model ----
    def _plan(self, now: float, rep: SimReplica):
        rep.plan = None
        rep.t = max(rep.t, now)
        rep._admit(rep.t)
        if not rep.running:
            return
        lt = rep.spec.latency
        chunk = lt.prefill_chunk
        pre = [r for r in rep.running if r.remaining_prefill > 0]
        dec = [r for r in rep.running if r.remaining_prefill == 0]
        k = rep.spec.ctx_refresh
        if pre:
            k = min(k, min(-(-r.remaining_prefill // chunk) for r in pre))
        if dec:
            k = min(k, min(r.req.output_len - r.emitted for r in dec))
        if len(rep.running) < rep.spec.num_slots:
            k = min(k, rep.spec.admit_poll)
        k = max(k, 1)
        ctx = rep._mean_ctx() + k / 2.0
        iter_s = lt.iteration_s(len(pre), len(dec), ctx)
        rep.plan = (rep.t + k * iter_s, k, iter_s)
        self._push(rep.plan[0], _WAKE, rep)

    def _apply_jump(self, now: float, rep: SimReplica, served: list):
        _, k, iter_s = rep.plan
        rep.plan = None
        rep.t = now
        rep.busy_s += k * iter_s
        rep.iterations += k
        finished = []
        for r in rep.running:
            if r.remaining_prefill > 0:
                chunk = rep.spec.latency.prefill_chunk
                r.remaining_prefill = max(
                    r.remaining_prefill - k * chunk, 0)
                if r.remaining_prefill == 0:
                    # the final chunk's step samples the first token
                    r.first_tok_t = now
                    r.emitted = 1
                    rep.tokens_out += 1
                    if r.emitted >= r.req.output_len:
                        r.finish_t = now
                        finished.append(r)
            else:
                r.emitted += k
                rep.tokens_out += k
                if r.emitted >= r.req.output_len:
                    r.finish_t = now
                    finished.append(r)
        for r in finished:
            rep.running.remove(r)
            served.append(r)

    # ---- autoscaling ----
    def _autoscale(self, now: float):
        desired = self.autoscaler.desired(now, self)
        active = [r for r in self.replicas if not r.draining]
        if desired > len(active):
            for _ in range(desired - len(active)):
                self.replicas.append(SimReplica(self.spec))
        elif desired < len(active):
            # drain the least-loaded replicas; they stop taking traffic
            # and disappear from routing once empty.  A victim's prefix
            # heat is adopted into the router's placement map pointing at
            # the coldest survivor, so tenant affinity survives the
            # scale-down instead of scattering to cold replicas.
            victims = sorted(active, key=lambda r: r.queue_depth())
            n_drop = len(active) - desired
            survivors = [r for r in active if r not in victims[:n_drop]]
            for r in victims[:n_drop]:
                r.draining = True
                if survivors and r.prefix and \
                        hasattr(self.router, "adopt_placement"):
                    target = min(survivors, key=lambda s: s.queue_depth())
                    self.router.adopt_placement(list(r.prefix), target)


# ---------------------------------------------------------------------------
# disaggregated fleet: prefill-class + decode-class replicas
# ---------------------------------------------------------------------------

_HANDOFF = 3


class _DecodeReplica(SimReplica):
    """Decode-class replica: admission installs the transferred chain
    into the prefix index (the handoff moved the pages here) but never
    re-runs prefill — ``remaining_prefill`` arrives already at zero."""

    def _admit(self, now: float):
        while self.queue and len(self.running) < self.spec.num_slots:
            sr = self.queue.popleft()
            if sr.admit_t is None:
                sr.admit_t = now
            for h in sr.chain:
                self.prefix[h] = None
                self.prefix.move_to_end(h)
            while len(self.prefix) > self.spec.prefix_blocks:
                self.prefix.popitem(last=False)
            self.running.append(sr)


def disagg_replica_specs(resolved_prefill, resolved_decode, *,
                         prefix_blocks: int = 64,
                         max_queue: int | None = None
                         ) -> tuple[ReplicaSpec, ReplicaSpec]:
    """Two :class:`ReplicaSpec` classes from phase-resolved deployments.

    The prefill class prices chunk advancement off the prefill-phase
    roofline (one step advances every slot by one chunk, so per-row cost
    is ``step_seconds / num_slots``) and carries a negligible decode
    grid; the decode class is the decode-phase memory roofline with
    ``prefill_chunk_s = 0`` — decode steps never interleave with chunks,
    which is exactly the interference disaggregation removes.
    """
    dt = LatencyTable.from_roofline(resolved_decode)
    dt = dataclasses.replace(dt, prefill_chunk_s=0.0)
    chunk_s = resolved_prefill.step_seconds \
        / max(resolved_prefill.num_slots, 1)
    pt = LatencyTable(
        batches=dt.batches, contexts=dt.contexts,
        decode_s=np.full_like(np.asarray(dt.decode_s), 1e-9),
        prefill_chunk_s=float(chunk_s),
        prefill_chunk=int(resolved_prefill.prefill_chunk))
    mk = lambda lat, res: ReplicaSpec(
        latency=lat, num_slots=res.num_slots,
        max_queue=max_queue if max_queue is not None else 2 * res.num_slots,
        page_size=res.page_size, prefix_blocks=prefix_blocks)
    return mk(pt, resolved_prefill), mk(dt, resolved_decode)


class DisaggFleetSimulator(FleetSimulator):
    """Fleet of prefill-class and decode-class replicas with KV handoff.

    Arrivals route to prefill replicas (prefix affinity applies there —
    a hit skips chunk compute).  When a request's prefill completes, a
    decode replica is chosen **KV-aware** via the router's scoring over
    the decode class: a replica already holding leading blocks of the
    chain (from an earlier handoff of the same tenant) both scores
    higher and shrinks the transfer.  The handoff itself costs
    ``handoff_latency_s + moved_tokens * kv_token_bytes / bandwidth``
    before the request joins the decode replica's queue.  TTFT lands at
    prefill completion (the final chunk samples the first token, as in
    the real engine); TPOT absorbs the transfer delay.

    ``self.replicas`` is the decode class, so the inherited autoscaler
    path (including drain-heat adoption) scales decode capacity.
    """

    def __init__(self, prefill_spec: ReplicaSpec, n_prefill: int,
                 decode_spec: ReplicaSpec, n_decode: int, router, *,
                 kv_token_bytes: float, handoff_gbs: float = 64.0,
                 handoff_latency_s: float = 0.0005, autoscaler=None,
                 prefill_power_w: float | None = None):
        super().__init__(decode_spec, 0, router, autoscaler=autoscaler)
        self.replicas = [_DecodeReplica(decode_spec)
                         for _ in range(n_decode)]
        self.prefill_spec = prefill_spec
        self.prefill_replicas = [SimReplica(prefill_spec)
                                 for _ in range(n_prefill)]
        self.kv_token_bytes = float(kv_token_bytes)
        self.handoff_gbs = float(handoff_gbs)
        self.handoff_latency_s = float(handoff_latency_s)
        self.prefill_power_w = prefill_power_w
        self.handoffs = 0
        self.handoff_bytes = 0.0
        self.handoff_shared_tokens = 0

    def run(self, trace: tr.Trace) -> FleetStats:
        chains = tr.tenant_chains(trace, self.spec.page_size)
        served: list[SimRequest] = []
        shed: list[SimRequest] = []
        for r in trace.requests:
            self._push(r.arrival, _ARRIVE, SimRequest(r, chains[r.tenant]))
        if self.autoscaler is not None:
            self._push(self.autoscaler.interval_s, _SCALE, None)
        t_end = 0.0
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            t_end = max(t_end, t)
            if kind == _ARRIVE:
                self._route(t, payload, shed)
            elif kind == _WAKE:
                rep = payload
                if rep.plan is not None and rep.plan[0] <= t + 1e-12:
                    self._apply_jump(t, rep, served)
                    self._plan(t, rep)
            elif kind == _HANDOFF:
                sr, rep = payload
                rep.queue.append(sr)
                if rep.plan is None:
                    self._plan(t, rep)
            else:   # _SCALE
                if any(h[2] != _SCALE for h in self._heap):
                    self._autoscale(t)
                    self._push(t + self.autoscaler.interval_s, _SCALE, None)
        duration = max(t_end, trace.duration)
        reps = self.prefill_replicas + self.replicas
        return FleetStats(
            served=served, shed=shed, duration=duration,
            replicas=len(reps), busy_s=[r.busy_s for r in reps],
            iterations=sum(r.iterations for r in reps),
            retries=self._retries, energy_j=self._energy(duration),
            handoffs=self.handoffs, handoff_bytes=self.handoff_bytes,
            handoff_shared_tokens=self.handoff_shared_tokens,
            prefill_replicas=len(self.prefill_replicas))

    def _energy(self, duration: float) -> float | None:
        dec = None
        if self.spec.energy_j_per_token is not None:
            dec = self.spec.energy_j_per_token \
                * sum(r.tokens_out for r in self.replicas)
        elif self.spec.power_w is not None:
            dec = self.spec.power_w \
                * sum(r.busy_s for r in self.replicas)
        pre = None
        if self.prefill_power_w is not None:
            pre = self.prefill_power_w \
                * sum(r.busy_s for r in self.prefill_replicas)
        if dec is None and pre is None:
            return None
        return (dec or 0.0) + (pre or 0.0)

    # arrivals go to the prefill class
    def _route(self, now: float, sr: SimRequest, shed: list):
        cand = [r for r in self.prefill_replicas if not r.draining] \
            or self.prefill_replicas
        d: RouteDecision = self.router.route(
            now, sr.req.prompt_len, sr.chain, cand, retries=sr.retries)
        if d.action == "admit":
            rep = cand[d.replica]
            sr.replica = self.prefill_replicas.index(rep)
            rep.queue.append(sr)
            if rep.plan is None:
                self._plan(now, rep)
        elif d.action == "retry":
            sr.retries += 1
            self._retries += 1
            self._push(now + d.delay_s, _ARRIVE, sr)
        else:
            sr.shed_reason = d.reason
            shed.append(sr)

    def _apply_jump(self, now: float, rep, served: list):
        super()._apply_jump(now, rep, served)
        if isinstance(rep, _DecodeReplica):
            return
        # prefill class: completed prompts leave for the decode tier
        # instead of decoding in place (single-token outputs already
        # finished inside the jump, exactly like the real engine)
        done = [r for r in rep.running if r.remaining_prefill == 0]
        for r in done:
            rep.running.remove(r)
            self._dispatch(now, r)

    def _dispatch(self, now: float, sr: SimRequest):
        """KV-aware decode placement at prefill-completion time."""
        cand = [r for r in self.replicas if not r.draining] or self.replicas
        order = self.router.order(now, sr.req.prompt_len, sr.chain, cand)
        pick = next((e for e in order if not cand[e[2]].saturated()),
                    order[0])
        _, hit, i = pick
        rep = cand[i]
        sr.replica = self.replicas.index(rep)
        hit = min(hit, sr.req.prompt_len)
        moved = max(sr.req.prompt_len - hit, 0) * self.kv_token_bytes
        delay = self.handoff_latency_s + moved / (self.handoff_gbs * 1e9)
        self.handoffs += 1
        self.handoff_bytes += moved
        self.handoff_shared_tokens += hit
        self._push(now + delay, _HANDOFF, (sr, rep))


# ---------------------------------------------------------------------------
# cross-check against a real engine
# ---------------------------------------------------------------------------


def cross_check(eng, trace: tr.Trace, *, table: LatencyTable | None = None,
                time_scale: float = 1.0) -> dict:
    """Replay ``trace`` through a real engine AND the simulator; compare.

    The engine serves the trace's materialized prompts with its real
    arrival times (scaled by ``time_scale`` to keep wall time sane);
    the simulator runs one replica whose table was calibrated from that
    same engine.  The engine replay runs twice and the second run is the
    measured one — the trace's ragged prompt lengths hit bucketed
    prefill shapes the calibration grid never compiled, and a mid-replay
    compile would be charged to serving.  Returns measured vs simulated
    throughput and TTFT and their ratio — the number the CI tolerance
    band is asserted on.
    """
    from repro.runtime.scheduler import Request
    from repro.runtime.sampling import SamplingParams

    if table is None:
        table = calibrate(eng)

    def mk_requests() -> list[Request]:
        out = []
        for r in trace.requests:
            toks = tr.materialize_prompt(trace, r)
            out.append(Request(
                rid=r.rid, prompt=toks, max_new_tokens=r.output_len,
                arrival_time=r.arrival * time_scale,
                sampling=SamplingParams(max_tokens=r.output_len)))
        return out

    eng.run(mk_requests())          # warmup: compile every bucket shape
    stats = eng.run(mk_requests())
    real_tps = stats.total_tokens / max(stats.wall, 1e-9)
    real_ttft = stats.latency_quantiles("ttft")

    # the real engine queues without bound and never sheds — mirror that
    spec = ReplicaSpec(
        latency=table, num_slots=eng.num_slots,
        max_queue=1 << 30, page_size=eng.page_size,
        prefix_blocks=eng.num_pages if eng.enable_prefix_cache else 0)
    scaled = dataclasses.replace(trace) if time_scale == 1.0 else None
    if time_scale != 1.0:
        reqs2 = [dataclasses.replace(r, arrival=r.arrival * time_scale)
                 for r in trace.requests]
        scaled = dataclasses.replace(trace, requests=reqs2)
    sim = FleetSimulator(spec, 1, PrefixAffinityRouter())
    fs = sim.run(scaled)
    sim_dur = max((s.finish_t for s in fs.served), default=fs.duration)
    sim_tps = fs.total_tokens / max(sim_dur, 1e-9)
    sim_ttft = fs.ttft_quantiles()
    return {
        "real_tokens_per_s": real_tps,
        "sim_tokens_per_s": sim_tps,
        "throughput_ratio": sim_tps / max(real_tps, 1e-9),
        "real_ttft_p50": real_ttft["p50"] if real_ttft else None,
        "sim_ttft_p50": sim_ttft["p50"] if sim_ttft else None,
        "real_tokens": stats.total_tokens,
        "sim_tokens": fs.total_tokens,
        "table": table.as_dict(),
    }
