"""Pure-jnp oracles for flash-decode GQA attention (dense and paged)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import NEG_INF, decode_attention_ref  # noqa: F401


def gather_pages(pages: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """(P, page, ...) pool + (B, n_blocks) table -> (B, n_blocks*page, ...)
    position-ordered dense view (block i of row b = physical page
    ``page_table[b, i]``)."""
    g = pages[page_table]                     # (B, n_blocks, page, ...)
    b, nb, ps = g.shape[:3]
    return g.reshape((b, nb * ps) + g.shape[3:])


def paged_valid_mask(page_table: jnp.ndarray, page_size: int,
                     pos: jnp.ndarray, *, window=None) -> jnp.ndarray:
    """(B, n_blocks*page) bool mask of logical positions visible to the
    token being decoded at per-row position ``pos`` (inclusive: the new
    token's own k/v has already been scattered at ``pos``)."""
    s = page_table.shape[1] * page_size
    idx = jnp.arange(s)[None, :]
    valid = idx <= pos[:, None]
    if window is not None:
        valid = valid & (idx > pos[:, None] - window)
    return valid


def paged_decode_multi_attention_ref(q, k_pages, v_pages, page_table, start,
                                     *, k_scales=None, v_scales=None,
                                     window=None, scale=None):
    """Multi-token paged decode oracle: C queries per slot at per-row
    offsets (speculative verify, q_len = gamma + 1).

    q: (B, C, H, D); start: (B,) absolute position of q[:, 0]; query j of
    row b sits at position start[b] + j and sees keys <= its own position.

    Op-for-op the same computation as ``paged_decode_attention_ref`` per
    query (gather -> dequant -> matmul -> mask -> softmax -> matmul, f32
    softmax), so each position's logits are bit-identical to what the
    single-token decode path produces for the same pool state — the
    greedy byte-identity contract between the speculative and
    non-speculative continuous engines rests on this.
    """
    b, c, h, d = q.shape
    kvh = k_pages.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    rep = h // kvh
    k = gather_pages(k_pages, page_table)
    v = gather_pages(v_pages, page_table)
    if k_scales is not None:
        k = k.astype(jnp.float32) * gather_pages(k_scales, page_table)[..., None]
        v = v.astype(jnp.float32) * gather_pages(v_scales, page_table)[..., None]
    s_len = k.shape[1]
    pos = start[:, None] + jnp.arange(c)[None, :]          # (B, C)
    idx = jnp.arange(s_len)
    valid = idx[None, None, :] <= pos[:, :, None]          # (B, C, S)
    if window is not None:
        valid = valid & (idx[None, None, :] > pos[:, :, None] - window)
    qf = q.astype(jnp.float32).reshape(b, c, kvh, rep, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bcgrd,bsgd->bcgrs", qf, kf) * scale
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bcgrs,bsgd->bcgrd", p, vf)
    return out.reshape(b, c, h, vf.shape[-1]).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, pos, *,
                               k_scales=None, v_scales=None,
                               window=None, scale=None):
    """Paged single-token decode attention oracle.

    q:          (B, H, D) — one new token per slot
    k_pages:    (P, page, KVH, D) physical page pool
    v_pages:    (P, page, KVH, Dv)
    page_table: (B, n_blocks) int32 — logical block -> physical page
    pos:        (B,) int32 — per-slot position of the new token
    k_scales/v_scales: (P, page, KVH) f32 per-token dequant scales for
                fp8/int8 code pools (None = dense pools)

    Gathers pages into a position-ordered dense view and reuses the dense
    oracle, so paged-vs-dense equivalence is exact by construction.  The
    dequant (f32 cast then one multiply per element) mirrors the fused
    kernel's in-loop dequant op-for-op, keeping the bit-exact contract.
    """
    k = gather_pages(k_pages, page_table)
    v = gather_pages(v_pages, page_table)
    if k_scales is not None:
        k = k.astype(jnp.float32) * gather_pages(k_scales, page_table)[..., None]
        v = v.astype(jnp.float32) * gather_pages(v_scales, page_table)[..., None]
    valid = paged_valid_mask(page_table, k_pages.shape[1], pos, window=window)
    return decode_attention_ref(q, k, v, None, valid=valid, scale=scale)
