"""HBM-CO: Capacity-Optimized High-Bandwidth Memory (paper §III).

Analytical model of the bandwidth / capacity / energy / cost design space of
stacked DRAM, parameterized over the structures the paper identifies as
capacity-driving but bandwidth-neutral (ranks, banks per bank-group,
sub-arrays i.e. bank capacity) and the bandwidth-driving structures
(layers per rank x channels per layer x pseudo-channels).

Energy-per-bit components (paper §III "Modeling Energy and Cost for HBM-CO"):
  1. Row activation  : 0.18  pJ/bit (streaming; conservative HBM3 timing)
  2. Data movement   : 0.2   pJ/bit/mm x intra-die routing distance
  3. TSV traversal   : 0.148 pJ/bit/layer x mean stack depth
  4. I/O interface   : 0.25  pJ/bit (UCIe / HBM3e DQ)

Calibration targets from the paper:
  * HBM3e-like (4 ranks x 4 layers, 4 ch/layer, 4 banks/group, 24MB banks):
    48 GB, 1024 GB/s (32 pCH x 32 GB/s), ~3.44 pJ/bit  [validated §III]
  * Candidate Pareto point (1 rank, 1 ch/layer, 1 bank/group, 24MB banks):
    768 MB, 256 GB/s, BW/Cap = 341, ~1.45 pJ/bit, 2.4x lower energy,
    ~1.8x higher cost per GB, ~35x lower module cost.

All of these are reproduced by this module and asserted in
``tests/test_hbmco.py``; the derived numbers land within a few percent of the
paper's and the deltas are recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import re
from typing import Iterable, Sequence

# --- energy model constants (paper §III) -----------------------------------
ACT_PJ_PER_BIT = 0.18           # row activation, streaming
DM_PJ_PER_BIT_MM = 0.2          # on-die data movement per mm
TSV_PJ_PER_BIT_LAYER = 0.148    # per stacked layer traversed (0.8 pF TSV)
IO_PJ_PER_BIT = 0.25            # interface I/O

# Routing-distance model: mean on-die routing distance grows with the linear
# dimension of the DRAM array region (wire-length scaling from HBM core-die
# floorplans [35],[47],[54]).  distance = DM_BASE + DM_K * sqrt(array_mm2).
# Calibrated so the HBM3e-like point gives 3.44 pJ/bit total.
DM_BASE_MM = 1.2
DM_K_MM = 0.85
DRAM_DENSITY_GBIT_PER_MM2 = 0.3   # ~1z-nm DRAM array density
ARRAY_AREA_FRACTION = 2.0 / 3.0   # TSV/command/periphery occupy ~1/3 of die

# Bandwidth model: each pseudo-channel sustains 32 GB/s (paper §III);
# pCHs = layers_per_rank x channels_per_layer x 2.
PCH_BW_GBS = 32.0

# Cost model, normalized to an HBM3e-like module == 1.0.  Module cost =
# (#dies x die_area x COST_PER_MM2) + FIXED_COST, where FIXED_COST captures
# the non-amortized base-die logic + TSV footprint + packaging floor.
# Calibrated on (HBM3e-like == 1.0, candidate == 1/35) per the paper's
# "35x lower cost overall" for the 768MB candidate.
_COST_PER_MM2 = 5.142e-4
_FIXED_COST = 0.01275


@dataclasses.dataclass(frozen=True)
class HBMCOConfig:
    """One point in the HBM-CO design space.

    The default values give the paper's candidate Pareto-optimal device.
    """

    name: str = "hbmco"
    ranks: int = 1                    # capacity only (shared interface)
    layers_per_rank: int = 4          # bandwidth: separate channels per layer
    channels_per_layer: int = 1       # bandwidth
    banks_per_group: int = 1          # capacity only (1 active bank suffices)
    bank_groups_per_pch: int = 4      # fixed: 4 pipelined BGs saturate a pCH
    bank_mb: float = 24.0             # capacity only (sub-array count knob)

    # ---------------- derived: bandwidth & capacity ----------------
    @property
    def total_layers(self) -> int:
        return self.ranks * self.layers_per_rank

    @property
    def pseudo_channels(self) -> int:
        # 2 pseudo-channels per channel; only one rank's interface is active.
        return self.layers_per_rank * self.channels_per_layer * 2

    @property
    def bandwidth_gbs(self) -> float:
        return self.pseudo_channels * PCH_BW_GBS

    @property
    def banks_per_layer(self) -> int:
        return (self.channels_per_layer * 2 * self.bank_groups_per_pch
                * self.banks_per_group)

    @property
    def capacity_mb(self) -> float:
        return self.total_layers * self.banks_per_layer * self.bank_mb

    @property
    def capacity_gb(self) -> float:
        return self.capacity_mb / 1024.0

    @property
    def capacity_bytes(self) -> float:
        return self.capacity_mb * 2**20

    @property
    def bw_per_cap(self) -> float:
        """GB/s of bandwidth per GB of capacity — the paper's key metric."""
        return self.bandwidth_gbs / self.capacity_gb

    # ---------------- derived: geometry ----------------
    @property
    def capacity_per_die_gbit(self) -> float:
        return self.capacity_gb * 8.0 / self.total_layers

    @property
    def array_area_mm2(self) -> float:
        return self.capacity_per_die_gbit / DRAM_DENSITY_GBIT_PER_MM2

    @property
    def die_area_mm2(self) -> float:
        return self.array_area_mm2 / ARRAY_AREA_FRACTION

    @property
    def shoreline_mm(self) -> float:
        """IO shoreline; bandwidth per shoreline is held constant across the
        family (paper: HBM-CO "retains ... shoreline bandwidth")."""
        return self.bandwidth_gbs / BW_PER_SHORELINE_GBS_MM

    # ---------------- derived: energy ----------------
    @property
    def mean_route_mm(self) -> float:
        return DM_BASE_MM + DM_K_MM * math.sqrt(self.array_area_mm2)

    @property
    def energy_components_pj_bit(self) -> dict:
        tsv = TSV_PJ_PER_BIT_LAYER * (self.total_layers + 1) / 2.0
        dm = DM_PJ_PER_BIT_MM * self.mean_route_mm
        return {
            "activation": ACT_PJ_PER_BIT,
            "data_movement": dm,
            "tsv": tsv,
            "io": IO_PJ_PER_BIT,
        }

    @property
    def energy_pj_per_bit(self) -> float:
        return sum(self.energy_components_pj_bit.values())

    # ---------------- derived: cost ----------------
    @property
    def module_cost(self) -> float:
        """Normalized module cost (HBM3e-like == 1.0)."""
        silicon = self.total_layers * self.die_area_mm2 * _COST_PER_MM2
        return silicon + _FIXED_COST

    @property
    def cost_per_gb(self) -> float:
        return self.module_cost / self.capacity_gb

    @property
    def bandwidth_per_cost(self) -> float:
        """GB/s per normalized cost unit (paper: 'bandwidth per dollar')."""
        return self.bandwidth_gbs / self.module_cost

    # ---------------- derived: system behaviour ----------------
    @property
    def ideal_token_latency_s(self) -> float:
        """Min token latency at 100% capacity utilization = Cap/BW (§III)."""
        return 1.0 / self.bw_per_cap

    def describe(self) -> str:
        e = self.energy_components_pj_bit
        return (f"{self.name}: {self.capacity_mb:.0f}MB @ {self.bandwidth_gbs:.0f}GB/s "
                f"BW/Cap={self.bw_per_cap:.0f} energy={self.energy_pj_per_bit:.2f}pJ/b "
                f"(act={e['activation']:.2f} dm={e['data_movement']:.2f} "
                f"tsv={e['tsv']:.2f} io={e['io']:.2f}) "
                f"cost={self.module_cost:.4f} (${self.cost_per_gb:.4f}/GB)")


# Shoreline constant: HBM3e-like 1024 GB/s over ~11 mm of shoreline.
BW_PER_SHORELINE_GBS_MM = 1024.0 / 11.0

# ---------------------------------------------------------------------------
# Named reference devices
# ---------------------------------------------------------------------------

HBM3E_LIKE = HBMCOConfig(
    name="hbm3e-like",
    ranks=4, layers_per_rank=4, channels_per_layer=4,
    banks_per_group=4, bank_mb=24.0,
)

# The paper's candidate Pareto-optimal device: 768 MB, 256 GB/s, BW/Cap=341.
CANDIDATE_CO = HBMCOConfig(
    name="hbmco-768MB",
    ranks=1, layers_per_rank=4, channels_per_layer=1,
    banks_per_group=1, bank_mb=24.0,
)


def hbmco_by_name(name: str) -> HBMCOConfig:
    """Look up a named HBM-CO device.

    Accepts the two reference devices ("hbm3e-like", "hbmco-768MB") and
    the ``enumerate_design_space`` naming scheme ``co-r{R}c{C}b{B}m{MB}``
    (e.g. ``co-r1c1b1m24`` — the candidate's knobs), so every point of the
    Fig-5 grid is addressable from a CLI flag or a ``DeploymentSpec``.
    """
    named = {HBM3E_LIKE.name: HBM3E_LIKE, CANDIDATE_CO.name: CANDIDATE_CO}
    if name in named:
        return named[name]
    m = re.fullmatch(r"co-r(\d+)c(\d+)b(\d+)m([0-9.]+)", name)
    if not m:
        raise ValueError(
            f"unknown HBM-CO device {name!r}; want one of {sorted(named)} "
            "or a design-space point 'co-r<ranks>c<channels>b<banks>m<MB>'")
    return HBMCOConfig(name=name, ranks=int(m.group(1)),
                       channels_per_layer=int(m.group(2)),
                       banks_per_group=int(m.group(3)),
                       bank_mb=float(m.group(4)))


def enumerate_design_space(
    ranks: Sequence[int] = (1, 2, 4),
    channels: Sequence[int] = (1, 2, 4),
    banks: Sequence[int] = (1, 2, 4),
    bank_mbs: Sequence[float] = (1.5, 3.0, 6.0, 12.0, 24.0),
) -> list[HBMCOConfig]:
    """Enumerate the HBM-CO knob grid (paper Fig 5 design space)."""
    out = []
    for r, c, b, mb in itertools.product(ranks, channels, banks, bank_mbs):
        cfg = HBMCOConfig(
            name=f"co-r{r}c{c}b{b}m{mb:g}",
            ranks=r, channels_per_layer=c, banks_per_group=b, bank_mb=mb,
        )
        out.append(cfg)
    return out


def pareto_frontier(
    configs: Iterable[HBMCOConfig],
    *,
    fixed_bandwidth_gbs: float | None = 256.0,
) -> list[HBMCOConfig]:
    """Pareto-minimal set over (energy/bit, -capacity).

    The RPU composes fixed-bandwidth-interface chiplets (paper Fig 9-10:
    "Each memory chiplet has a fixed bandwidth interface"), so by default
    the frontier is taken within the 256 GB/s interface class; pass ``None``
    to sweep all bandwidths.
    """
    cand = [c for c in configs
            if fixed_bandwidth_gbs is None
            or abs(c.bandwidth_gbs - fixed_bandwidth_gbs) < 1e-6]
    # sort by capacity ascending; keep points with strictly decreasing energy
    # as capacity grows?  No: energy grows with capacity, so the frontier is
    # (capacity asc, energy asc) — keep configs not dominated by another with
    # (capacity >= and energy <=).
    frontier: list[HBMCOConfig] = []
    for c in sorted(cand, key=lambda x: (x.capacity_mb, x.energy_pj_per_bit)):
        dominated = any(
            o.capacity_mb >= c.capacity_mb - 1e-9
            and o.energy_pj_per_bit <= c.energy_pj_per_bit + 1e-12
            and (o.capacity_mb > c.capacity_mb or
                 o.energy_pj_per_bit < c.energy_pj_per_bit)
            for o in cand)
        if not dominated:
            if not frontier or c.capacity_mb > frontier[-1].capacity_mb + 1e-9:
                frontier.append(c)
    return frontier


def select_sku(
    required_bytes_per_device: float,
    frontier: Sequence[HBMCOConfig] | None = None,
) -> HBMCOConfig | None:
    """Pick the highest-BW/Cap (smallest-capacity) SKU that fits the
    per-device capacity requirement (paper Fig 9/10 selection rule:
    "the smallest device capacity that meets the system-level requirement").

    Returns ``None`` when even the largest SKU cannot fit the requirement.
    """
    if frontier is None:
        frontier = pareto_frontier(enumerate_design_space())
    fitting = [c for c in frontier if c.capacity_bytes >= required_bytes_per_device]
    if not fitting:
        return None
    return min(fitting, key=lambda c: c.capacity_bytes)
