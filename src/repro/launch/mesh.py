"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls this.

Mesh layout:
  single pod : (data=16, model=16)            — 256 chips (one v5e pod)
  multi-pod  : (pod=2, data=16, model=16)     — 512 chips

The ``model`` axis carries TP/EP/CP (weights, experts, KV$-context); the
``data`` axis carries DP and the FSDP weight shard; ``pod`` is the slow
(DCN-ish) axis used for DP + gradient-compressed cross-pod reduction.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_small_mesh(n_devices: int | None = None, model_axis: int | None = None):
    """A (data, model) mesh over whatever devices exist (tests/examples)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    model = model_axis or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


def parse_mesh(spec: str):
    """``"DxM"`` -> a (data=D, model=M) mesh over the visible devices.

    The serve launcher's ``--mesh 2x4`` etc.; ``D * M`` must equal the
    device count (use ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    for CPU host devices).
    """
    try:
        d, m = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"--mesh wants DxM (e.g. 2x4), got {spec!r}") from None
    n = len(jax.devices())
    if d * m != n:
        raise ValueError(f"mesh {d}x{m} needs {d * m} devices, "
                         f"have {n} (set "
                         f"XLA_FLAGS=--xla_force_host_platform_device_count)")
    return jax.make_mesh((d, m), ("data", "model"))
