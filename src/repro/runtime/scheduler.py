"""Iteration-level request scheduler for continuous batching.

Request lifecycle:  PENDING --admit--> RUNNING --finish--> FINISHED
                        ^                 |
                        +----preempt------+        (pages exhausted)

The scheduler owns admission policy only; the engine drives the loop
(prefill newly admitted requests, run one fused decode step over every
slot, retire finished slots).  Admission is slot-based: the jitted decode
step has a fixed batch of ``num_slots`` rows, and a request occupies one
slot from prefill to finish.  Freed slots are refilled from the arrival
queue on the **next iteration** without recompiling — page tables and
positions are data, not shapes.

Preemption (when the page pool is exhausted) is restart-style: the victim
loses its pages and generated tokens and re-queues at the front.  With
greedy decoding a restart reproduces the same tokens, so preemption is
invisible in the output stream.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

import numpy as np

from repro.runtime.kv_cache import PagedKVCache

PENDING, RUNNING, FINISHED = "pending", "running", "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (plen,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0          # seconds relative to serve start
    # -- mutable lifecycle state --
    state: str = PENDING
    slot: int = -1
    pos: int = 0                       # next cache write position
    tokens: list[int] = dataclasses.field(default_factory=list)
    admit_time: float | None = None
    finish_time: float | None = None
    preemptions: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new_tokens


class Scheduler:
    """Slot-based admission over a paged KV cache."""

    def __init__(self, cache: PagedKVCache):
        self.cache = cache
        self.num_slots = cache.num_slots
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}
        self._free_slots: list[int] = list(range(self.num_slots))[::-1]

    # -- queries ------------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.running)

    def next_arrival(self) -> float | None:
        return min((r.arrival_time for r in self.waiting), default=None)

    @property
    def num_running(self) -> int:
        return len(self.running)

    # -- lifecycle ----------------------------------------------------------
    def submit(self, requests: Iterable[Request]) -> None:
        reqs = sorted(requests, key=lambda r: r.arrival_time)
        self.waiting.extend(reqs)

    def admit(self, now: float) -> list[Request]:
        """Admit arrived requests into free slots while pages last."""
        admitted: list[Request] = []
        while (self.waiting and self._free_slots
               and self.waiting[0].arrival_time <= now):
            req = self.waiting[0]
            slot = self._free_slots[-1]
            if not self.cache.admit(slot, req.prompt_len):
                break                      # pool exhausted: wait for frees
            self.waiting.popleft()
            self._free_slots.pop()
            req.state, req.slot = RUNNING, slot
            req.pos = req.prompt_len
            req.admit_time = now
            self.running[slot] = req
            admitted.append(req)
        return admitted

    def ensure_capacity(self, req: Request) -> bool:
        """Back ``req``'s next write position with a page, evicting the
        youngest other request if the pool is exhausted.  Returns False if
        ``req`` itself had to be preempted."""
        while not self.cache.ensure(req.slot, req.pos):
            victims = [r for r in self.running.values() if r is not req]
            if not victims:
                self.preempt(req)
                return False
            self.preempt(max(victims, key=lambda r: (r.admit_time, r.rid)))
        return True

    def preempt(self, req: Request) -> None:
        self.cache.release(req.slot)
        self.running.pop(req.slot)
        self._free_slots.append(req.slot)
        req.preemptions += 1
        req.state, req.slot, req.pos = PENDING, -1, 0
        req.tokens.clear()
        self.waiting.appendleft(req)

    def finish(self, req: Request, now: float) -> None:
        self.cache.release(req.slot)
        self.running.pop(req.slot)
        self._free_slots.append(req.slot)
        req.state, req.finish_time = FINISHED, now
        req.slot = -1
