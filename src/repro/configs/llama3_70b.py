"""Llama3-70B (paper simulator baseline)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-70b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672,
    vocab_size=128256, vocab_pad_multiple=512, rope_theta=500000.0,
)
