"""Attention-backend registry: the single seam between block kinds and
attention implementations.

Each backend bundles, for one attention family, everything the model
assembly and the serving runtime need to know:

  * parameter / cache / page-pool constructors (the **cache layout**);
  * the dense apply paths (forward / prefill / decode);
  * the paged serve paths (single-token ``decode_paged`` against the page
    pools, and ``prefill_chunk_paged`` for chunked admission);
  * the **mask families** each path supports (``"prefix"`` — causal over
    the whole cache — and/or ``"sliding"``).

``model.py`` dispatches every block through ``backend_for_kind`` instead of
string-prefix branching, and ``runtime/engine.py`` stays entirely
layout-agnostic (pools are opaque pytrees whose leaves all carry a leading
page axis).  Adding a paged layout for a new family — ring pages for SWA,
SSM state admission — means registering a backend, not editing the engine.

The paged decode kernels behind the GQA backend live in
``kernels/decode_attention`` (gather-fused Pallas kernel on accelerators,
gather-then-dense oracle on CPU); MLA's absorbed-matmul latent decode is
einsum-based and shares the same page pools and tables.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.common import NEG_INF, ModelConfig, blocked_attention
from repro.models.ssm import init_ssm_state
from repro.kernels.decode_attention.ref import gather_pages, paged_valid_mask
from repro.parallel.hints import tp_row_dot
from repro.quant import kv as kvq


# ---------------------------------------------------------------------------
# Backend descriptor + registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionBackend:
    """One attention family's implementations and cache layout."""
    name: str
    paged_leaf_keys: tuple[str, ...]        # pool leaves with a token axis
    mask_families: tuple[str, ...]          # dense paths
    paged_mask_families: tuple[str, ...]    # paged paths
    init: Callable[..., dict]
    init_cache: Callable[..., dict]
    forward: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    init_page_pool: Callable[..., dict] | None = None
    decode_paged: Callable[..., Any] | None = None
    prefill_chunk_paged: Callable[..., Any] | None = None
    # Multi-token decode (speculative verify): C queries per slot at
    # per-row offsets, scatter-then-attend over the paged pools with
    # ``blocked_attention``'s ragged q_offset machinery — the same
    # contract as prefill_chunk_paged (start, valid), and for both
    # built-in families literally the same body: a verify window IS a
    # chunk of already-chosen tokens whose logits we keep at every
    # position instead of just the last one (that difference lives in
    # ``Model.decode_step_paged``, not here).
    decode_multi_paged: Callable[..., Any] | None = None
    # Tensor-parallel partition of the page pools (sharded paged serving):
    # leaf key -> the UNSTACKED pool-leaf dim that shards over the mesh's
    # model axis, or None for a replicated leaf.  GQA pools shard their
    # KV-head axis (each shard streams only its local head slice — the
    # paper's "KV$ sharded across CUs"); MLA's latent pools are shared by
    # every head and stay replicated.  ``parallel.plan.PagedServePlan``
    # turns this into shard_map specs / NamedShardings, so new families
    # (ssm state pools, ring pages) declare their sharding here instead of
    # hard-coding it in the engine.
    paged_partition_spec: dict[str, int | None] | None = None

    @property
    def supports_paged(self) -> bool:
        return self.init_page_pool is not None


_REGISTRY: dict[str, AttentionBackend] = {}

# block kind -> backend name; kinds without attention (ssm) map to None
KIND_BACKEND: dict[str, str | None] = {
    "attn_dense": "gqa",
    "attn_moe": "gqa",
    "hybrid": "gqa",          # the attention half; SSM state is separate
    "mla_dense": "mla",
    "mla_moe": "mla",
    "ssm": None,
}


def register_backend(backend: AttentionBackend) -> AttentionBackend:
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> AttentionBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown attention backend {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def backend_for_kind(kind: str) -> AttentionBackend | None:
    try:
        name = KIND_BACKEND[kind]
    except KeyError:
        raise ValueError(f"unknown block kind {kind!r}") from None
    return get_backend(name) if name else None


# ---------------------------------------------------------------------------
# Cache layouts: what a block kind keeps resident per serving slot
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """Per-slot cache residency contract for one block kind.

    The attention backends above describe *how* a family computes; the
    cache layout describes *what it keeps resident* while serving — the
    axis ``DeploymentSpec.resolve`` budgets and ``runtime.state_cache``
    allocates:

      * ``kv``     — the kind writes token-indexed pages (full-context for
        prefix layers, ring-reclaimed O(window) for sliding-window layers;
        which of the two is a property of the segment's window, not the
        kind, so it lives in ``runtime.state_cache.SegmentCacheLayout``);
      * ``state``  — the kind carries constant-size recurrent state (SSM
        conv tail + SSD state), pooled per slot by the engine and stepped
        via ``ssm_decode_step``;
      * ``init_state_pool`` — constructor for the slot-indexed state
        pytree, ``(cfg, num_slots) -> pytree``, leading axis = slot;
      * ``state_partition_spec`` — leaf key -> UNSTACKED state-leaf dim
        sharded over the mesh's model axis (None = replicated), mirroring
        ``AttentionBackend.paged_partition_spec`` for state pools.
    """
    kv: bool
    state: bool
    init_state_pool: Callable[..., dict] | None = None
    state_partition_spec: dict[str, int | None] | None = None


_SSM_STATE_LAYOUT = dict(
    state=True,
    init_state_pool=lambda cfg, num_slots: init_ssm_state(cfg, num_slots),
    # conv (slot, K-1, conv_dim) and ssm (slot, H, P, N) state replicates
    # across the TP ring today (sharded stateful serving is gated in
    # ``parallel.plan.make_paged_serve_plan``); the seam is declared here
    # so lifting that gate means editing specs, not the engine.
    state_partition_spec={"conv": None, "ssm": None},
)

# block kind -> residency layout.  Attention kinds are pure-KV; ssm is
# pure-state; hybrid blocks own both a KV half and a state half in the
# SAME slot (admission/eviction moves them together).
KIND_LAYOUT: dict[str, CacheLayout] = {
    "attn_dense": CacheLayout(kv=True, state=False),
    "attn_moe": CacheLayout(kv=True, state=False),
    "mla_dense": CacheLayout(kv=True, state=False),
    "mla_moe": CacheLayout(kv=True, state=False),
    "hybrid": CacheLayout(kv=True, **_SSM_STATE_LAYOUT),
    "ssm": CacheLayout(kv=False, **_SSM_STATE_LAYOUT),
}


def layout_for_kind(kind: str) -> CacheLayout:
    try:
        return KIND_LAYOUT[kind]
    except KeyError:
        raise ValueError(f"unknown block kind {kind!r}") from None


# ---------------------------------------------------------------------------
# Paged helpers shared by the backends
# ---------------------------------------------------------------------------


def scatter_token(pool_leaf: jnp.ndarray, vals: jnp.ndarray, page_table,
                  pos) -> jnp.ndarray:
    """Scatter one token per slot: vals (B, ...) at per-slot position pos."""
    b = vals.shape[0]
    page = pool_leaf.shape[1]
    blk, off = pos // page, pos % page
    phys = page_table[jnp.arange(b), blk]
    return pool_leaf.at[phys, off].set(vals.astype(pool_leaf.dtype))


def scatter_chunk(pool_leaf: jnp.ndarray, vals: jnp.ndarray, page_table,
                  positions, ok) -> jnp.ndarray:
    """Scatter a chunk of tokens per slot through the page table.

    vals: (B, C, ...); positions: (B, C) absolute; ok: (B, C) — entries with
    ``ok=False`` (padding rows / the tail of a short last chunk) are
    redirected to the scratch page so live pages are never corrupted."""
    b, c = positions.shape
    page = pool_leaf.shape[1]
    okf = ok.reshape(-1)
    pos_f = jnp.where(okf, positions.reshape(-1), 0)
    bidx = jnp.repeat(jnp.arange(b), c)
    phys = jnp.where(okf, page_table[bidx, pos_f // page], 0)
    off = jnp.where(okf, pos_f % page, 0)
    flat = vals.reshape((b * c,) + vals.shape[2:]).astype(pool_leaf.dtype)
    return pool_leaf.at[phys, off].set(flat)


# ---------------------------------------------------------------------------
# GQA backend: paged decode + chunked paged prefill
# ---------------------------------------------------------------------------


def init_attn_page_pool(cfg: ModelConfig, num_pages: int, page_size: int,
                        dtype=jnp.bfloat16) -> dict:
    """Physical K/V page pool for one layer: ``(P, page, KVH, HD)``.

    ``dtype``: bf16 on TPU; CPU serving wants f32 (XLA:CPU re-converts
    bf16 pools to f32 around every gather, doubling the step time).  The
    string dtypes ``"fp8"`` / ``"int8"`` build quantized pools: narrow
    code leaves plus per-token f32 ``k_scale``/``v_scale`` metadata leaves
    of shape ``(P, page, KVH)`` (see ``quant.kv``)."""
    shape = (num_pages, page_size, cfg.n_kv_heads, cfg.hd)
    if kvq.is_quantized_cache_dtype(dtype):
        store = kvq.cache_storage_dtype(dtype)
        return {"k": jnp.zeros(shape, store), "v": jnp.zeros(shape, store),
                "k_scale": jnp.ones(shape[:3], kvq.SCALE_DTYPE),
                "v_scale": jnp.ones(shape[:3], kvq.SCALE_DTYPE)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _scatter_kv_token(pool: dict, k, v, page_table, pos) -> dict:
    """Scatter one token's k/v per slot, quantizing on write for fp8/int8
    pools (scale = amax of the token's head vector, fixed at write time)."""
    fmt = kvq.pool_cache_format(pool)
    if fmt is None:
        return {"k": scatter_token(pool["k"], k, page_table, pos),
                "v": scatter_token(pool["v"], v, page_table, pos)}
    kc, ks = kvq.kv_quantize(k, fmt)
    vc, vs = kvq.kv_quantize(v, fmt)
    return {"k": scatter_token(pool["k"], kc, page_table, pos),
            "v": scatter_token(pool["v"], vc, page_table, pos),
            "k_scale": scatter_token(pool["k_scale"], ks, page_table, pos),
            "v_scale": scatter_token(pool["v_scale"], vs, page_table, pos)}


def _scatter_kv_chunk(pool: dict, k, v, page_table, positions, ok) -> dict:
    """Chunk analogue of ``_scatter_kv_token`` (k/v: (B, C, KVH, HD))."""
    fmt = kvq.pool_cache_format(pool)
    if fmt is None:
        return {"k": scatter_chunk(pool["k"], k, page_table, positions, ok),
                "v": scatter_chunk(pool["v"], v, page_table, positions, ok)}
    kc, ks = kvq.kv_quantize(k, fmt)
    vc, vs = kvq.kv_quantize(v, fmt)
    return {"k": scatter_chunk(pool["k"], kc, page_table, positions, ok),
            "v": scatter_chunk(pool["v"], vc, page_table, positions, ok),
            "k_scale": scatter_chunk(pool["k_scale"], ks, page_table,
                                     positions, ok),
            "v_scale": scatter_chunk(pool["v_scale"], vs, page_table,
                                     positions, ok)}


def attn_decode_paged(p: dict, x: jnp.ndarray, cfg: ModelConfig, pool: dict,
                      page_table, pos, *, window=None) -> tuple[jnp.ndarray, dict]:
    """One-token step against a paged cache.

    x: (B, D) slot tokens; pos: (B,) int32 per-slot positions (ragged —
    this is the whole point of continuous batching); page_table:
    (B, n_blocks) int32.  The new k/v is scattered into the slot's current
    page before the attention, mirroring the dense write-then-attend order;
    the attention itself streams pages through the gather-fused kernel
    (``impl="auto"``: oracle on CPU, fused Pallas kernel on accelerators).
    Quantized (fp8/int8) pools scatter codes + per-token scales and pass
    the scale pages into the kernel's fused in-loop dequant.
    """
    b, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    positions = pos[:, None]                              # (B, 1) ragged RoPE
    q, k, v = layers._qkv(p, x[:, None, :], cfg, positions)
    new_pool = _scatter_kv_token(pool, k[:, 0], v[:, 0], page_table, pos)
    from repro.kernels.decode_attention.ops import paged_gqa_decode_attention
    out = paged_gqa_decode_attention(
        q[:, 0], new_pool["k"], new_pool["v"], page_table, pos,
        k_scales=new_pool.get("k_scale"), v_scales=new_pool.get("v_scale"),
        window=window)
    out = tp_row_dot(out.reshape(b, h * hd), p["wo"])
    return out, new_pool


def attn_prefill_chunk_paged(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                             pool: dict, page_table, start, valid, *,
                             window=None) -> tuple[jnp.ndarray, dict]:
    """One prefill chunk against the paged cache.

    x: (B, C, D) chunk hidden states; start: (B,) absolute position of
    x[:, 0]; valid: (B,) number of real tokens in the chunk (the rest are
    padding).  The chunk's k/v is scattered into the slot's pages, then the
    chunk queries attend over the gathered view — earlier chunks (and any
    prefix-cache pages shared from another request) are already resident,
    so admission work is proportional to the *unseen* suffix only.
    """
    b, c, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    positions = start[:, None] + jnp.arange(c)[None, :]
    q, k, v = layers._qkv(p, x, cfg, positions)
    ok = jnp.arange(c)[None, :] < valid[:, None]
    new_pool = _scatter_kv_chunk(pool, k, v, page_table, positions, ok)
    from repro.kernels.decode_attention.ops import paged_gqa_multi_attention
    out = paged_gqa_multi_attention(
        q, new_pool["k"], new_pool["v"], page_table, start,
        k_scales=new_pool.get("k_scale"), v_scales=new_pool.get("v_scale"),
        causal=cfg.causal, window=window, impl="blocked")
    out = tp_row_dot(out.reshape(b, c, h * hd), p["wo"])
    return out, new_pool


def attn_decode_multi_paged(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                            pool: dict, page_table, start, valid, *,
                            window=None) -> tuple[jnp.ndarray, dict]:
    """C-token decode step (speculative verify): the tokens are already
    chosen, so this is chunk-shaped scatter-then-attend, but through the
    ``impl="auto"`` multi-query dispatch — bit-matched per position with
    the single-token decode path on CPU (greedy byte-identity), blocked
    online softmax on accelerators."""
    b, c, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    positions = start[:, None] + jnp.arange(c)[None, :]
    q, k, v = layers._qkv(p, x, cfg, positions)
    ok = jnp.arange(c)[None, :] < valid[:, None]
    new_pool = _scatter_kv_chunk(pool, k, v, page_table, positions, ok)
    from repro.kernels.decode_attention.ops import paged_gqa_multi_attention
    out = paged_gqa_multi_attention(
        q, new_pool["k"], new_pool["v"], page_table, start,
        k_scales=new_pool.get("k_scale"), v_scales=new_pool.get("v_scale"),
        window=window)
    out = tp_row_dot(out.reshape(b, c, h * hd), p["wo"])
    return out, new_pool


# ---------------------------------------------------------------------------
# MLA backend: absorbed-matmul latent decode + chunked paged prefill
# ---------------------------------------------------------------------------


def init_mla_page_pool(cfg: ModelConfig, num_pages: int, page_size: int,
                       dtype=jnp.bfloat16) -> dict:
    """Latent page pool for one MLA layer (pages hold c_kv + shared k_rope)."""
    if kvq.is_quantized_cache_dtype(dtype):
        raise NotImplementedError(
            f"cache_dtype={dtype!r} is not implemented for MLA latent page "
            f"pools: the absorbed-matmul decode consumes latent pages "
            f"directly and has no dequant seam yet.  Quantized KV "
            f"({'/'.join(sorted(kvq.KV_FORMATS))}) is only available for "
            f"GQA-family page pools; for MLA models use a dense cache_dtype "
            f"(None, jnp.bfloat16, jnp.float32) instead.")
    return {
        "c_kv": jnp.zeros((num_pages, page_size, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((num_pages, page_size, cfg.rope_head_dim), dtype),
    }


def mla_decode_paged(p, x, cfg: ModelConfig, pool: dict, page_table, pos, *,
                     window=None):
    """Absorbed-matmul MLA decode against a paged latent cache.

    Same math as ``layers.mla_decode`` with the latent/k_rope streams
    gathered through the page table and a per-slot (ragged) position vector.
    """
    assert window is None, "MLA layers are full-attention"
    b, _ = x.shape
    h, hd, rhd, vhd, r = (cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.v_hd,
                          cfg.kv_lora_rank)
    positions = pos[:, None]
    q_nope, q_rope, c_kv, k_rope = layers._mla_qc(p, x[:, None, :], cfg,
                                                  positions)
    page = pool["c_kv"].shape[1]
    new_c = scatter_token(pool["c_kv"], c_kv[:, 0], page_table, pos)
    new_kr = scatter_token(pool["k_rope"], k_rope[:, 0], page_table, pos)

    c_d = gather_pages(new_c, page_table)                  # (B, S, r)
    kr_d = gather_pages(new_kr, page_table)                # (B, S, rhd)
    w_uk = p["w_uk"].reshape(r, h, hd)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    q_eff = jnp.concatenate([q_lat, q_rope[:, 0].astype(jnp.float32)], axis=-1)
    k_eff = jnp.concatenate([c_d.astype(jnp.float32),
                             kr_d.astype(jnp.float32)], axis=-1)
    scale = 1.0 / math.sqrt(hd + rhd)
    s_ = jnp.einsum("bhr,bsr->bhs", q_eff, k_eff) * scale
    valid = paged_valid_mask(page_table, page, pos)        # (B, S)
    s_ = jnp.where(valid[:, None, :], s_, NEG_INF)
    pattn = jax.nn.softmax(s_, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pattn, c_d.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(r, h, vhd)
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
    out = tp_row_dot(out.reshape(b, h * vhd).astype(x.dtype), p["wo"])
    return out, {"c_kv": new_c, "k_rope": new_kr}


def mla_decode_multi_paged(p, x, cfg: ModelConfig, pool: dict, page_table,
                           start, valid, *, window=None):
    """C-token absorbed-matmul MLA decode (speculative verify).

    Deliberately mirrors ``mla_decode_paged``'s ABSORBED path — not the
    per-head expansion ``mla_prefill_chunk_paged`` uses — because the
    two associate the latent matmuls differently and diverge at ulp
    scale; verify logits must match the single-token decode path
    bit-for-bit so greedy speculation stays byte-identical."""
    assert window is None, "MLA layers are full-attention"
    b, c, _ = x.shape
    h, hd, rhd, vhd, r = (cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.v_hd,
                          cfg.kv_lora_rank)
    positions = start[:, None] + jnp.arange(c)[None, :]
    q_nope, q_rope, c_kv, k_rope = layers._mla_qc(p, x, cfg, positions)
    ok = jnp.arange(c)[None, :] < valid[:, None]
    new_c = scatter_chunk(pool["c_kv"], c_kv, page_table, positions, ok)
    new_kr = scatter_chunk(pool["k_rope"], k_rope, page_table, positions, ok)

    c_d = gather_pages(new_c, page_table)                  # (B, S, r)
    kr_d = gather_pages(new_kr, page_table)                # (B, S, rhd)
    s_len = c_d.shape[1]
    w_uk = p["w_uk"].reshape(r, h, hd)
    q_lat = jnp.einsum("bchd,rhd->bchr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    q_eff = jnp.concatenate([q_lat, q_rope.astype(jnp.float32)], axis=-1)
    k_eff = jnp.concatenate([c_d.astype(jnp.float32),
                             kr_d.astype(jnp.float32)], axis=-1)
    scale = 1.0 / math.sqrt(hd + rhd)
    s_ = jnp.einsum("bchr,bsr->bchs", q_eff, k_eff) * scale
    idx = jnp.arange(s_len)
    vmask = idx[None, None, :] <= positions[:, :, None]    # (B, C, S)
    s_ = jnp.where(vmask[:, :, None, :], s_, NEG_INF)
    pattn = jax.nn.softmax(s_, axis=-1)
    ctx = jnp.einsum("bchs,bsr->bchr", pattn, c_d.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(r, h, vhd)
    out = jnp.einsum("bchr,rhv->bchv", ctx, w_uv.astype(jnp.float32))
    out = tp_row_dot(out.reshape(b, c, h * vhd).astype(x.dtype), p["wo"])
    return out, {"c_kv": new_c, "k_rope": new_kr}


def mla_prefill_chunk_paged(p, x, cfg: ModelConfig, pool: dict, page_table,
                            start, valid, *, window=None):
    """One MLA prefill chunk: scatter latents, attend via per-head expansion
    of the gathered latent view (the prefill-style path of ``mla_forward``,
    continued at per-slot offsets)."""
    assert window is None, "MLA layers are full-attention"
    b, c, _ = x.shape
    h, hd, rhd, vhd = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.v_hd
    positions = start[:, None] + jnp.arange(c)[None, :]
    q_nope, q_rope, c_kv, k_rope = layers._mla_qc(p, x, cfg, positions)
    ok = jnp.arange(c)[None, :] < valid[:, None]
    new_c = scatter_chunk(pool["c_kv"], c_kv, page_table, positions, ok)
    new_kr = scatter_chunk(pool["k_rope"], k_rope, page_table, positions, ok)
    c_d = gather_pages(new_c, page_table)                  # (B, S, r)
    kr_d = gather_pages(new_kr, page_table)
    s_len = c_d.shape[1]
    k_nope = (c_d @ p["w_uk"]).reshape(b, s_len, h, hd)
    v_d = (c_d @ p["w_uv"]).reshape(b, s_len, h, vhd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr_d[:, :, None, :],
                                                  (b, s_len, h, rhd))], axis=-1)
    scale = 1.0 / math.sqrt(hd + rhd)
    out = blocked_attention(q, k, v_d, causal=cfg.causal, scale=scale,
                            q_offset=start)
    out = tp_row_dot(out.reshape(b, c, h * vhd), p["wo"])
    return out, {"c_kv": new_c, "k_rope": new_kr}


# ---------------------------------------------------------------------------
# Registration
# ---------------------------------------------------------------------------


GQA = register_backend(AttentionBackend(
    name="gqa",
    paged_leaf_keys=("k", "v"),
    mask_families=("prefix", "sliding"),
    # sliding covers the MASK family only: the fused kernel / oracle skip
    # out-of-window positions, but pages behind the window stay allocated
    # (ring-aware page reclamation is the remaining capacity half).
    paged_mask_families=("prefix", "sliding"),
    init=layers.init_attn,
    init_cache=layers.init_attn_cache,
    forward=layers.attn_forward,
    prefill=layers.attn_prefill,
    decode=layers.attn_decode,
    init_page_pool=init_attn_page_pool,
    decode_paged=attn_decode_paged,
    prefill_chunk_paged=attn_prefill_chunk_paged,
    decode_multi_paged=attn_decode_multi_paged,
    # (P, page, KVH, HD) codes + (P, page, KVH) scale metadata: KV heads
    paged_partition_spec={"k": 2, "v": 2, "k_scale": 2, "v_scale": 2},
))

MLA = register_backend(AttentionBackend(
    name="mla",
    paged_leaf_keys=("c_kv", "k_rope"),
    mask_families=("prefix",),
    paged_mask_families=("prefix",),
    init=layers.init_mla,
    init_cache=lambda cfg, batch, max_len, window=None, dtype=jnp.bfloat16:
        layers.init_mla_cache(cfg, batch, max_len, dtype=dtype),
    forward=lambda p, x, cfg, *, window=None, positions=None:
        layers.mla_forward(p, x, cfg, positions=positions),
    prefill=lambda p, x, cfg, cache, *, window=None:
        layers.mla_prefill(p, x, cfg, cache),
    decode=lambda p, x, cfg, cache, cur_pos, *, window=None:
        layers.mla_decode(p, x, cfg, cache, cur_pos),
    init_page_pool=init_mla_page_pool,
    decode_paged=mla_decode_paged,
    prefill_chunk_paged=mla_prefill_chunk_paged,
    decode_multi_paged=mla_decode_multi_paged,
    # the latent stream is shared by every head: heads shard (w_uk/w_uv
    # columns), the per-token latents replicate across the TP ring
    paged_partition_spec={"c_kv": None, "k_rope": None},
))
