"""Distributed correctness on a REAL multi-device mesh (8 CPU host
devices, spawned in subprocesses so the main test process keeps its
single device): the sharded train step and decode must match the
single-device results bit-for-bit (same math, different partitioning).
"""
import os
import subprocess
import sys
import textwrap

import pytest

# Every test here compiles a model in an 8-device subprocess (minutes of
# wall time) — heavy tier only.
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(code: str, timeout=1200):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True,
                       timeout=timeout, cwd=REPO)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


@pytest.mark.parametrize("arch", ["qwen3-14b", "deepseek-v2-lite-16b",
                                  "mamba2-370m"])
def test_sharded_train_step_matches_single_device(arch):
    """One train step on a (2 data x 4 model) mesh with the production
    ParallelPlan (TP + FSDP + seq-parallel + EP/SSM sharding) == the same
    step on one device."""
    out = _run(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.models.model import build_model
        from repro.parallel.hints import sharding_rules
        from repro.parallel.plan import ParallelPlan, make_plan
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_step import init_train_state, make_train_step

        cfg = reduced_config(get_config({arch!r}))
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        state = init_train_state(model, key)
        batch = {{"tokens": jax.random.randint(key, (8, 32), 0,
                                               cfg.vocab_size)}}
        step = make_train_step(model, AdamWConfig(lr=1e-3))

        # single device
        s1, m1 = jax.jit(step)(state, batch)
        l1 = float(m1["loss"])

        # 2x4 mesh with the production plan
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = make_plan(cfg, mesh, global_batch=8, shape_kind="train")
        state2 = init_train_state(model, key)
        with mesh, sharding_rules(plan.rules()):
            sh_state = type(state2)(
                params=plan.param_shardings(state2.params),
                opt_state=plan.param_shardings(state2.opt_state), err=None)
            s2, m2 = jax.jit(step, in_shardings=(sh_state,
                             plan.batch_shardings(batch)))(state2, batch)
        l2 = float(m2["loss"])
        assert abs(l1 - l2) < 5e-3, (l1, l2)
        # parameters after the update agree
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-2, rtol=2e-2)
        print("ok", l1, l2)
    """)
    assert "ok" in out


def test_sharded_decode_matches_single_device():
    """Greedy decode on the sharded mesh (TP + context-sharded KV$) ==
    single-device decode, token for token."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.models.model import build_model
        from repro.parallel.hints import sharding_rules
        from repro.parallel.plan import make_plan
        from repro.runtime.engine import ServeEngine

        cfg = reduced_config(get_config("qwen3-14b"))
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        toks = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)

        eng = ServeEngine(model, params, max_len=32, donate_cache=False)
        ref = eng.generate({"tokens": toks}, max_new_tokens=8).tokens

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = make_plan(cfg, mesh, global_batch=8, shape_kind="decode")
        with mesh, sharding_rules(plan.rules()):
            eng2 = ServeEngine(model, params, max_len=32,
                               donate_cache=False)
            got = eng2.generate({"tokens": toks}, max_new_tokens=8).tokens
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
        print("ok", np.asarray(got)[0].tolist())
    """)
    assert "ok" in out


def test_sharded_paged_continuous_decode_matches_single_device():
    """Tensor-parallel continuous batching on a (2 data x 4 model) mesh:
    KV page pools sharded per KV head, params Megatron column-sharded,
    the fused paged decode step inside one manual shard_map — byte-
    identical to the single-device engine for a greedy/sampled mix,
    through forced preemption-restarts AND prefix-cache hits, with no
    extra compiles per mesh shape and per-device KV bytes/token at 1/TP."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.models.model import build_model
        from repro.runtime.engine import ContinuousServeEngine
        from repro.runtime.sampling import SamplingParams
        from repro.runtime.scheduler import Request

        cfg = dataclasses.replace(reduced_config(get_config("qwen3-14b")),
                                  n_heads=8, n_kv_heads=4)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        base = np.asarray(jax.random.randint(jax.random.PRNGKey(1),
                                             (2, 12), 0, cfg.vocab_size))
        prompts = base[np.array([0, 1, 0, 1, 0, 0])]   # 2 distinct -> hits
        SP = [SamplingParams() if i % 2 == 0 else
              SamplingParams(temperature=0.9, top_k=8, top_p=0.95,
                             seed=100 + i) for i in range(6)]
        mk = lambda: [Request(rid=i, prompt=prompts[i], max_new_tokens=8,
                              sampling=SP[i], arrival_time=0.02 * i)
                      for i in range(6)]

        def engine(mesh=None, num_pages=64, tp_reduce="auto"):
            return ContinuousServeEngine(
                model, params, num_slots=3, page_size=4,
                num_pages=num_pages, max_len=21, prefill_chunk=5, mesh=mesh,
                tp_reduce=tp_reduce)

        ref = engine().run(mk())
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # roomy pool (prefix hits) + tight pool (forced preemptions)
        seng = engine(mesh)
        got = seng.run(mk())
        tight = engine(mesh, num_pages=12)
        tgot = tight.run(mk())
        tref = engine(num_pages=12).run(mk())
        assert got.prefix_hit_tokens > 0, "no prefix sharing exercised"
        assert tgot.preemptions > 0, "no preemption pressure"
        for i in range(6):
            np.testing.assert_array_equal(ref.results[i], got.results[i])
            np.testing.assert_array_equal(tref.results[i], tgot.results[i])
        # one compiled decode step for the whole greedy/sampled mix
        assert seng._step_fn._cache_size() == 1, \\
            seng._step_fn._cache_size()
        # pools physically shard the KV-head axis 4-way
        leaf = jax.tree.leaves(seng._pools)[0]
        assert (leaf.addressable_shards[0].data.shape[-2]
                == leaf.shape[-2] // 4), leaf.sharding
        assert (seng.kv_token_bytes_per_device() * 4
                == engine().kv_token_bytes_per_device())
        # psum production mode: execution coverage (row-sharded weights,
        # one f32 psum per block).  Tokens match single-device only up to
        # f32 reassociation — at this toy scale streams can diverge, so
        # assert the run itself: every request completes its full budget
        # through one compiled step, on the same sharded pools.
        peng = engine(mesh, tp_reduce="psum")
        pgot = peng.run(mk())
        assert all(pgot.results[i].shape == (8,) for i in range(6))
        assert all(o.finish_reason == "length"
                   for o in pgot.outputs.values())
        assert peng._step_fn._cache_size() == 1
        print("ok", ref.results[5].tolist())
    """)
    assert "ok" in out


def test_kv_head_replicated_paged_decode_matches_single_device():
    """KV-head replication (n_kv_heads < TP): a 2-KV-head model served on
    a 4-way model axis — each shard holds 2 q heads and ONE replicated KV
    head — stays byte-identical to the single-device engine, and the
    per-device KV bytes/token bottom out at one head (full/kvh) instead
    of shrinking 1/TP."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.models.model import build_model
        from repro.runtime.engine import ContinuousServeEngine
        from repro.runtime.sampling import SamplingParams
        from repro.runtime.scheduler import Request

        cfg = dataclasses.replace(reduced_config(get_config("qwen3-14b")),
                                  n_heads=8, n_kv_heads=2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1),
                                                (4, 12), 0, cfg.vocab_size))
        SP = [SamplingParams() if i % 2 == 0 else
              SamplingParams(temperature=0.9, top_k=8, top_p=0.95,
                             seed=100 + i) for i in range(4)]
        mk = lambda: [Request(rid=i, prompt=prompts[i], max_new_tokens=8,
                              sampling=SP[i]) for i in range(4)]

        def engine(mesh=None):
            return ContinuousServeEngine(
                model, params, num_slots=3, page_size=4, num_pages=64,
                max_len=21, prefill_chunk=5, mesh=mesh)

        ref = engine().run(mk())
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        seng = engine(mesh)
        assert seng.serve_plan.kv_repl == 2, seng.serve_plan
        got = seng.run(mk())
        for i in range(4):
            np.testing.assert_array_equal(ref.results[i], got.results[i])
        assert seng._step_fn._cache_size() == 1
        # pools widened to 4 KV heads, sharded 4-way -> 1 head per shard
        leaf = jax.tree.leaves(seng._pools)[0]
        assert leaf.shape[-2] == 4, leaf.shape
        assert leaf.addressable_shards[0].data.shape[-2] == 1, leaf.sharding
        # accounting: per-device bytes = full / kvh (one head), NOT full/tp
        full = engine().kv_token_bytes_per_device()
        assert seng.kv_token_bytes_per_device() == full // 2
        print("ok", ref.results[1].tolist())
    """)
    assert "ok" in out


def test_sharded_speculative_continuous_matches_single_device():
    """Scheduler-integrated speculation on a (2 data x 4 model) mesh with
    a SEPARATE draft model: the draft gets its own plan and its page
    pools shard per KV head over the model axis (same page-id space as
    the target's), and both greedy and sampled streams stay byte-
    identical to the single-device speculative engine — greedy also to
    the non-speculative engine — with one compiled draft scan and one
    compiled verify step."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.models.model import build_model
        from repro.runtime.engine import ContinuousServeEngine
        from repro.runtime.sampling import SamplingParams
        from repro.runtime.scheduler import Request
        from repro.runtime.speculative import SpeculativeConfig

        cfg = dataclasses.replace(reduced_config(get_config("qwen3-14b")),
                                  n_heads=8, n_kv_heads=4)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft",
                                   n_layers=1)
        dm = build_model(dcfg)
        dp = dm.init(jax.random.PRNGKey(3))
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1),
                                             (3, 12), 0, cfg.vocab_size))
        SP = [SamplingParams(),
              SamplingParams(temperature=0.9, top_k=8, seed=7),
              SamplingParams()]
        mk = lambda: [Request(rid=i, prompt=toks[i], max_new_tokens=8,
                              sampling=SP[i]) for i in range(3)]
        sc = SpeculativeConfig(draft_model=dm, draft_params=dp, gamma=3)

        def engine(mesh=None, spec=None):
            return ContinuousServeEngine(
                model, params, num_slots=3, page_size=4, num_pages=32,
                max_len=24, prefill_chunk=5, mesh=mesh, speculative=spec)

        ref = engine().run(mk())            # non-spec single-device
        sref = engine(spec=sc).run(mk())    # spec single-device
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        seng = engine(mesh, sc)
        got = seng.run(mk())
        for i in range(3):
            np.testing.assert_array_equal(sref.results[i], got.results[i])
            if SP[i].is_greedy:
                np.testing.assert_array_equal(ref.results[i],
                                              got.results[i])
        assert seng._spec_draft._cache_size() == 1
        assert seng._spec_verify._cache_size() == 1
        # draft pools physically shard their KV-head axis over the mesh
        leaf = jax.tree.leaves(seng._draft_pools)[0]
        assert (leaf.addressable_shards[0].data.shape[-2]
                == leaf.shape[-2] // 4), leaf.sharding
        print("ok", got.spec_windows, round(got.accepted_per_window, 3))
    """)
    assert "ok" in out


def test_elastic_checkpoint_restore_across_meshes():
    """Checkpoint written from a (2,4) mesh restores onto a (4,2) mesh
    (elastic re-shard on restart) and training continues."""
    out = _run("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.models.model import build_model
        from repro.parallel.hints import sharding_rules
        from repro.parallel.plan import make_plan
        from repro.train import checkpoint as ckpt_lib
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_step import init_train_state, make_train_step

        cfg = reduced_config(get_config("qwen3-14b"))
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        step = make_train_step(model, AdamWConfig(lr=1e-3))
        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size)}
        ckpt_dir = tempfile.mkdtemp()

        mesh_a = jax.make_mesh((2, 4), ("data", "model"))
        plan_a = make_plan(cfg, mesh_a, global_batch=8, shape_kind="train")
        state = init_train_state(model, key)
        with mesh_a, sharding_rules(plan_a.rules()):
            state, _ = jax.jit(step)(state, batch)
        ckpt_lib.save_checkpoint(ckpt_dir, 1, state)

        # "restart" on a different topology
        mesh_b = jax.make_mesh((4, 2), ("data", "model"))
        plan_b = make_plan(cfg, mesh_b, global_batch=8, shape_kind="train")
        template = init_train_state(model, key)
        sh = type(template)(params=plan_b.param_shardings(template.params),
                            opt_state=plan_b.param_shardings(template.opt_state),
                            err=None)
        restored, step_no = ckpt_lib.restore_latest(ckpt_dir, template,
                                                    shardings=sh)
        assert step_no == 1
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(restored.params)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        with mesh_b, sharding_rules(plan_b.rules()):
            restored, m = jax.jit(step)(restored, batch)
        assert np.isfinite(float(m["loss"]))
        print("ok step", int(restored.step))
    """)
    assert "ok step 2" in out
