"""Mixture-of-Experts layer (Llama4-Maverick, DeepSeek-V2 style).

Two execution strategies, selected by token count:

  * ``dense`` — every expert processes every token, combined with routing
    weights.  O(E x T) compute: only sane for tiny smoke configs, but it is
    the bit-exact reference for the property tests.
  * ``capacity`` — production path: tokens are sorted by expert id and
    gathered into an (E, C, D) buffer (capacity C with drop/pad semantics),
    processed with a single batched einsum whose expert axis shards over the
    mesh's ``model`` axis (expert parallelism), and scattered back.

The paper's Fig 10/11 treat MoE layers as the canonical memory-bound,
query-unique streaming phase; the capacity path preserves that structure
(each expert's weights are streamed once per step regardless of batch).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import common
from repro.parallel.compat import shard_map
from repro.models.common import ModelConfig, dense_init, split_keys


def init_moe(key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d, f))(
            jax.random.split(ks[1], e)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, f))(
            jax.random.split(ks[2], e)),
        "w_down": jax.vmap(lambda k: dense_init(k, f, d))(
            jax.random.split(ks[3], e)),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        ks2 = split_keys(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(ks2[0], d, fs),
            "w_up": dense_init(ks2[1], d, fs),
            "w_down": dense_init(ks2[2], fs, d),
        }
    return p


def _routing(x2d: jnp.ndarray, router: jnp.ndarray, k: int):
    """Top-k softmax routing.  Returns (weights (T,k) f32, ids (T,k) i32)."""
    logits = (x2d.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, ids


def _expert_ffn(xe: jnp.ndarray, p: dict) -> jnp.ndarray:
    """(E, C, D) -> (E, C, D) batched SwiGLU over the expert axis."""
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_dense(x: jnp.ndarray, p: dict, cfg: ModelConfig) -> jnp.ndarray:
    """Reference: all experts on all tokens (tiny configs only)."""
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    w, ids = _routing(x2d, p["router"], cfg.n_experts_per_token)
    g = jnp.einsum("td,edf->tef", x2d, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x2d, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down"])           # (T, E, D)
    onehot = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)  # (T,k,E)
    comb = jnp.einsum("tk,tke->te", w, onehot)                   # (T, E)
    y = jnp.einsum("te,ted->td", comb.astype(x.dtype), y_all)
    return y.reshape(b, s, d)


def _capacity_core(x2d: jnp.ndarray, w: jnp.ndarray, ids: jnp.ndarray,
                   n_buckets: int, cap: int, wp: dict) -> jnp.ndarray:
    """Sort-by-expert + capacity buffer + batched einsum over ``n_buckets``
    experts (ids >= n_buckets are drop buckets).  Returns (T, D).

    Deterministic drop policy: per expert, earliest-sorted tokens win a slot.
    """
    t, d = x2d.shape
    k = ids.shape[1]
    flat_ids = ids.reshape(-1)                                    # (T*k,)
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(flat_ids, stable=True)
    sid = flat_ids[order]
    stok = flat_tok[order]
    sw = flat_w[order]

    # slot within expert = rank among same-expert entries (sorted order)
    first_idx = jnp.searchsorted(sid, jnp.arange(n_buckets), side="left")
    slot = jnp.arange(t * k) - first_idx[jnp.clip(sid, 0, n_buckets - 1)]
    keep = (slot < cap) & (sid < n_buckets)

    # scatter tokens into (E, C, D).  The (T*k, D) dispatch/return streams
    # and the capacity buffers' C axis are constrained over the DATA axes
    # (hints are no-ops outside a sharded launch): without them GSPMD
    # materializes ~25 GB unsharded gather temps per MoE layer.
    from repro.parallel.hints import shard_hint
    buf = shard_hint(jnp.zeros((n_buckets, cap, d), x2d.dtype), "moe_ecd")
    src = jnp.where(keep, stok, 0)
    gath = shard_hint(jnp.where(keep[:, None], x2d[src], 0).astype(x2d.dtype),
                      "moe_tkd")
    xe = buf.at[jnp.clip(sid, 0, n_buckets - 1),
                jnp.clip(slot, 0, cap - 1)].add(gath)
    xe = shard_hint(xe, "moe_ecd")

    ye = shard_hint(_expert_ffn(xe, wp), "moe_ecd")                # (E, C, D)

    # gather back with combine weights
    y_tok = shard_hint(
        ye[jnp.clip(sid, 0, n_buckets - 1), jnp.clip(slot, 0, cap - 1)],
        "moe_tkd")
    contrib = jnp.where(keep[:, None],
                        y_tok * sw[:, None].astype(y_tok.dtype), 0)
    return jnp.zeros((t, d), x2d.dtype).at[stok].add(contrib)


def moe_capacity(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                 capacity_factor: float = 1.25) -> jnp.ndarray:
    """Production path: sort-by-expert dispatch into (E, C, D) buffers.

    The buffers and expert batched-einsums carry ``moe_ecd`` sharding
    hints (expert axis over the model dim), so GSPMD partitions the
    expert compute (EP) instead of replicating 30 GB dispatch buffers and
    all-reducing them (§Perf iteration 3: 25 GB/device/layer of
    all-reduce traffic on deepseek-v2-lite prefill without the hints).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_token
    t = b * s
    x2d = x.reshape(t, d)
    w, ids = _routing(x2d, p["router"], k)                        # (T,k)
    cap = max(int(math.ceil(t * k / e * capacity_factor)), 1)
    y = _capacity_core(x2d, w, ids, e, cap, p)
    return y.reshape(b, s, d)


def moe_ep(x: jnp.ndarray, p: dict, cfg: ModelConfig, mesh, axis: str,
           capacity_factor: float = 1.25) -> jnp.ndarray:
    """Expert-parallel MoE: experts shard over ``axis`` (the mesh's model
    dimension), tokens stay sharded over the data axes, and dispatch runs
    fully locally inside a ``shard_map``:

      * each (data, model) shard routes its LOCAL tokens against the full
        router, keeps the assignments that land on its local E/n experts
        (others fall in a drop bucket), and runs the capacity path with
        per-data-shard capacity;
      * each shard's (T_local, D) contribution is stacked over the model
        axis and summed outside (one bf16 all-reduce per layer).

    This replaces the global argsort + unconstrained scatter/gather whose
    GSPMD lowering materializes (T*k, D) f32 tensors and all-reduces
    ~50 GB/device/layer on deepseek-v2-lite prefill (§Perf iteration 3).
    (A psum+replicated-out variant trips an XLA:CPU partitioner CHECK when
    nested in the layer scan; the stacked-partial form avoids it.)
    """
    from jax.sharding import PartitionSpec as P

    e, k = cfg.n_experts, cfg.n_experts_per_token
    n_shards = mesh.shape[axis]
    el = e // n_shards
    # NOTE: dispatching data-locally too (manual over the dp axes, per-shard
    # capacity) is numerically validated on a standalone 2x4 mesh, but JAX
    # 0.8.2 + XLA:CPU rejects dp-manual shard_map nested inside the layer
    # scan ("vma axes must be Manual") and hard-crashes the partitioner on
    # the psum variant — so this stays manual over the MODEL axis only;
    # tokens remain auto-sharded over dp.  See EXPERIMENTS.md §Perf iter 3.

    def body(xl, router, wg, wu, wd):
        b, s, d = xl.shape
        t = b * s
        x2d = xl.reshape(t, d)
        w, ids = _routing(x2d, router, k)
        j = jax.lax.axis_index(axis)
        lo = j * el
        local = (ids >= lo) & (ids < lo + el)
        ids_l = jnp.where(local, ids - lo, el)          # bucket el = drop
        w_l = jnp.where(local, w, 0.0)
        cap = max(int(math.ceil(t * k / e * capacity_factor)), 1)
        # NOTE: the dp-axis hints inside _capacity_core stay ACTIVE here —
        # the data axis is auto inside this partial-manual region, and the
        # hints cut the dispatch bound ~30% (22s -> 15s memory+collective
        # on deepseek prefill).  They are only invalid under AD, and the
        # train path uses moe_impl="capacity" (no shard_map) instead.
        y = _capacity_core(x2d, w_l, ids_l, el, cap,
                           {"w_gate": wg, "w_up": wu, "w_down": wd})
        return y.astype(x.dtype).reshape(1, b, s, d)

    parts = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis)),
        out_specs=P(axis),
        axis_names={axis}, check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return jnp.sum(parts, axis=0)


# Token budget per dispatch chunk: bounds the (T*k, D) gather streams and
# (E, C, D) capacity buffers at prefill/train scale (1M global tokens would
# need ~100 GiB of dispatch temps).  Chunks re-stream expert weights, so
# keep them large.
MOE_CHUNK_TOKENS = 65536


def _chunked(fn, x: jnp.ndarray) -> jnp.ndarray:
    """Apply ``fn`` over sequence chunks of ~MOE_CHUNK_TOKENS tokens."""
    b, s, d = x.shape
    if b * s <= MOE_CHUNK_TOKENS:
        return fn(x)
    per_chunk = max(1, MOE_CHUNK_TOKENS // b)
    n = max(1, s // per_chunk)
    while s % n:
        n -= 1
    if n <= 1:
        return fn(x)
    cl = s // n
    xs = jnp.moveaxis(x.reshape(b, n, cl, d), 1, 0)      # (n, B, cl, D)
    # checkpoint per chunk: the backward otherwise stacks every chunk's
    # dispatch intermediates ((T_c*k, D) gathers x n chunks)
    ys = jax.lax.map(jax.checkpoint(fn), xs)
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, d)


def moe_forward(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                impl: str = "auto") -> jnp.ndarray:
    from repro.parallel import hints

    b, s, d = x.shape
    if impl == "auto":
        ep = hints.ep_context()
        if (ep is not None and ep[0].shape[ep[1]] > 1
                and cfg.n_experts % ep[0].shape[ep[1]] == 0
                and cfg.n_experts >= ep[0].shape[ep[1]]):
            impl = "ep"
        elif b * s <= 4096 and cfg.n_experts <= 16:
            impl = "dense"
        else:
            impl = "capacity"
    if impl == "ep":
        ep = hints.ep_context()
        if ep is None:
            raise ValueError(
                "moe_impl='ep' needs an expert-parallel context "
                "(sharding_rules with a >1 model axis) and cannot nest "
                "inside an already-manual region (e.g. the TP serve "
                "shard_map, where experts run replicated); use impl='auto'")
        mesh, axis = ep
        y = _chunked(lambda xc: moe_ep(xc, p, cfg, mesh, axis), x)
    elif impl == "dense":
        y = moe_dense(x, p, cfg)
    else:
        y = _chunked(lambda xc: moe_capacity(xc, p, cfg), x)
    if cfg.n_shared_experts:
        y = y + common.swiglu(x, p["shared"]["w_gate"], p["shared"]["w_up"],
                              p["shared"]["w_down"])
    return y
