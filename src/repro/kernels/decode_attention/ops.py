"""Public op wrappers for the decode-attention kernel (dense and paged)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, gather_pages, paged_decode_attention_ref,
)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def gqa_decode_attention(q, k_cache, v_cache, cur_len, *, block_s: int = 512):
    """(B,H,D) x (B,S,KVH,D) cache -> (B,H,D); kernel when tiles fit,
    jnp oracle otherwise (tiny smoke shapes / ragged S)."""
    s = k_cache.shape[1]
    bs = min(block_s, s)
    if s % bs != 0 or q.shape[1] % k_cache.shape[2] != 0:
        return decode_attention_ref(q, k_cache, v_cache, cur_len)
    return decode_attention(q, k_cache, v_cache, cur_len, block_s=bs,
                            interpret=_on_cpu())


def paged_gqa_decode_attention(q, k_pages, v_pages, page_table, pos, *,
                               window=None, block_s: int = 512):
    """Paged decode attention: gather K/V through the page table into a
    position-ordered dense view, then run the flash-decode kernel over it.

    The gather is the HBM-stream half of the paper's decode SDPA (page
    granularity keeps the stream contiguous per block); the kernel half is
    unchanged, so the paged path inherits the dense kernel's tiling.  With
    ``window=None`` validity is a per-row prefix (``pos + 1`` entries), the
    layout the kernel's ``cur_len`` masking expects; windowed callers fall
    back to the masked oracle.
    """
    if window is not None or _on_cpu():
        # windowed masks need the oracle; on CPU the kernel would run in
        # (slow) interpret mode and the oracle is also the bit-exact
        # counterpart of the dense serve path
        return paged_decode_attention_ref(q, k_pages, v_pages, page_table,
                                          pos, window=window)
    k = gather_pages(k_pages, page_table)
    v = gather_pages(v_pages, page_table)
    cur_len = (pos + 1).astype(jnp.int32)
    return gqa_decode_attention(q, k, v, cur_len, block_s=block_s)
