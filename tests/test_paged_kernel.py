"""Gather-fused paged decode kernel vs the gather-then-dense oracle.

Runs the Pallas kernel in interpret mode on CPU (fast tier), so the fused
path — page-table-driven grid, GQA head packing, prefix and sliding-window
masks — is exercised in CI even though the serve engine takes the oracle on
CPU.  ``accum="exact"`` must match ``paged_decode_attention_ref``
bit-for-bit; ``accum="online"`` (the production flash-decode accumulator)
is held to a few-ulp tolerance against the same oracle.
"""
import numpy as np
import pytest

import repro.models  # noqa: F401  (import order: models before kernels.ref)
import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.paged_kernel import paged_decode_attention
from repro.kernels.decode_attention.ops import paged_gqa_decode_attention
from repro.kernels.decode_attention.ref import paged_decode_attention_ref


def _paged_case(seed, B, H, KVH, D, page, n_blocks, dtype=jnp.float32,
                permute=True, extra_pages=0):
    """Random pool + per-row permuted page tables + ragged positions."""
    key = jax.random.PRNGKey(seed)
    S = page * n_blocks
    P = 1 + B * n_blocks + extra_pages
    rng = np.random.default_rng(seed)
    ids = rng.permutation(np.arange(1, P)) if permute else np.arange(1, P)
    table = jnp.asarray(ids[:B * n_blocks].reshape(B, n_blocks), jnp.int32)
    q = jax.random.normal(key, (B, H, D), dtype)
    k_pages = jax.random.normal(jax.random.fold_in(key, 1),
                                (P, page, KVH, D), dtype)
    v_pages = jax.random.normal(jax.random.fold_in(key, 2),
                                (P, page, KVH, D), dtype)
    pos = jnp.asarray(rng.integers(0, S, B), jnp.int32)
    return q, k_pages, v_pages, table, pos


@pytest.mark.parametrize("B,H,KVH,D,page,n_blocks,dtype", [
    (3, 8, 2, 32, 8, 5, jnp.float32),     # GQA 4:1
    (2, 16, 2, 64, 16, 3, jnp.float32),   # GQA 8:1
    (1, 4, 4, 16, 4, 7, jnp.float32),     # MHA, many small pages
    (2, 8, 2, 32, 8, 4, jnp.bfloat16),    # serve dtype
])
def test_fused_exact_matches_oracle_bitwise(B, H, KVH, D, page, n_blocks,
                                            dtype):
    q, kp, vp, table, pos = _paged_case(0, B, H, KVH, D, page, n_blocks,
                                        dtype=dtype)
    ref = paged_decode_attention_ref(q, kp, vp, table, pos)
    out = paged_decode_attention(q, kp, vp, table, pos, accum="exact",
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("window", [1, 3, 11])
def test_fused_exact_sliding_window_bitwise(window):
    q, kp, vp, table, pos = _paged_case(window, 2, 8, 2, 32, 8, 5)
    ref = paged_decode_attention_ref(q, kp, vp, table, pos, window=window)
    out = paged_decode_attention(q, kp, vp, table, pos, window=window,
                                 accum="exact", interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("window", [None, 5])
def test_fused_online_close_to_oracle(window):
    """The O(1)-scratch flash-decode accumulator: same mask/gather logic as
    the exact mode, rescaling differences bounded to a few ulps."""
    q, kp, vp, table, pos = _paged_case(3, 3, 8, 2, 32, 8, 5)
    ref = np.asarray(paged_decode_attention_ref(q, kp, vp, table, pos,
                                                window=window), np.float32)
    out = np.asarray(paged_decode_attention(q, kp, vp, table, pos,
                                            window=window, accum="online",
                                            interpret=True), np.float32)
    np.testing.assert_allclose(out, ref, rtol=2e-6, atol=2e-6)


def test_fused_ignores_scratch_page_tail():
    """Unallocated table entries point at the scratch page (id 0); whatever
    garbage lives there must not leak into the output."""
    q, kp, vp, table, pos = _paged_case(5, 2, 8, 2, 32, 8, 4, extra_pages=1)
    # positions confined to the first two blocks; tail blocks -> scratch
    pos = jnp.asarray([7, 12], jnp.int32)
    table_scratch = jnp.asarray(np.where(np.arange(4)[None, :] < 2,
                                         np.asarray(table), 0), jnp.int32)
    kp = kp.at[0].set(1e4)                       # poison the scratch page
    vp = vp.at[0].set(-1e4)
    ref = paged_decode_attention_ref(q, kp, vp, table_scratch, pos)
    for accum in ("exact", "online"):
        out = paged_decode_attention(q, kp, vp, table_scratch, pos,
                                     accum=accum, interpret=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-6, atol=2e-6)


def test_op_wrapper_impl_routing():
    q, kp, vp, table, pos = _paged_case(7, 2, 4, 2, 16, 4, 3)
    ref = paged_gqa_decode_attention(q, kp, vp, table, pos, impl="reference")
    auto = paged_gqa_decode_attention(q, kp, vp, table, pos)   # CPU -> oracle
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))
    fused = paged_gqa_decode_attention(q, kp, vp, table, pos, impl="fused")
    np.testing.assert_allclose(np.asarray(fused, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-6, atol=2e-6)
    with pytest.raises(ValueError):
        paged_gqa_decode_attention(q, kp, vp, table, pos, impl="nope")
