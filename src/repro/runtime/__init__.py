"""Serving runtime: engine, sampling, speculative decoding."""
from repro.runtime.engine import ServeEngine, serve_step_fn, prefill_step_fn
from repro.runtime.sampling import greedy, sample, probs
from repro.runtime.speculative import speculative_generate, SpecStats, make_speculative_window
