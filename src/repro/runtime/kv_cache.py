"""Block-paged KV cache for continuous-batching serve.

Layout (vLLM-style): every attention layer owns a **page pool** — an array
``(num_pages, page_size, ...)`` — and all layers share ONE logical page id
space, so a single host-side allocator manages the whole model.  A request's
token at absolute position ``t`` lives at
``pool[page_table[slot, t // page_size], t % page_size]`` in every layer.

The host side is split in two:

  * ``PageAllocator`` — a pure-python free-list allocator with per-owner
    page lists.  Physical page 0 is **reserved as a scratch page**: every
    unallocated page-table entry (and every inactive decode slot) points at
    it, so the jitted decode step can scatter/gather unconditionally — dead
    slots write garbage into scratch instead of corrupting live pages.
  * ``PagedKVCache`` — the per-slot page tables over that allocator, plus
    admission / growth / release / defrag bookkeeping.

Device pools themselves live in the engine (they are model-shaped pytrees
built by ``Model.init_paged_cache``); this module is deliberately
JAX-light so the allocator invariants are testable without compiles.
"""
from __future__ import annotations

import dataclasses

import numpy as np

SCRATCH_PAGE = 0


class PageAllocator:
    """Free-list page allocator with exclusive per-owner ownership.

    Invariants (asserted by ``check()`` and tests/test_kv_cache.py):
      * page 0 is never handed out (scratch);
      * no page is owned by two live owners;
      * ``len(free) + sum(owned) + 1 == num_pages`` (conservation).
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is scratch)")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list: low page ids handed out first (helps locality)
        self._free: list[int] = list(range(num_pages - 1, 0, -1))
        self._owned: dict[object, list[int]] = {}

    # -- queries ------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return sum(len(v) for v in self._owned.values())

    def pages_of(self, owner) -> list[int]:
        return list(self._owned.get(owner, ()))

    # -- alloc / free -------------------------------------------------------
    def alloc(self, owner, n: int = 1) -> list[int] | None:
        """Allocate ``n`` pages for ``owner`` (all-or-nothing)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(owner, []).extend(pages)
        return pages

    def free_owner(self, owner) -> int:
        """Release every page of ``owner``; returns how many were freed."""
        pages = self._owned.pop(owner, [])
        self._free.extend(pages)
        return len(pages)

    # -- defrag -------------------------------------------------------------
    def defrag(self) -> dict[int, int]:
        """Compact live pages into the lowest physical ids.

        Returns the ``{old_page: new_page}`` mapping for moved pages (empty
        when already compact).  Owners' logical order is preserved, so the
        caller only has to (a) permute the device pools with the mapping and
        (b) rewrite its page tables through it.
        """
        live = [(owner, p) for owner, pages in sorted(
            self._owned.items(), key=lambda kv: str(kv[0]))
            for p in pages]
        mapping: dict[int, int] = {}
        target = 1                                  # page 0 stays scratch
        for _, p in live:
            if p != target:
                mapping[p] = target
            target += 1
        if mapping:
            for owner, pages in self._owned.items():
                self._owned[owner] = [mapping.get(p, p) for p in pages]
            self._free = list(range(self.num_pages - 1, target - 1, -1))
        return mapping

    # -- invariants ---------------------------------------------------------
    def check(self) -> None:
        seen: set[int] = set()
        for owner, pages in self._owned.items():
            for p in pages:
                assert p != SCRATCH_PAGE, f"{owner} owns the scratch page"
                assert p not in seen, f"page {p} owned twice"
                seen.add(p)
        assert not (seen & set(self._free)), "page both free and owned"
        assert len(self._free) + len(seen) + 1 == self.num_pages, \
            "free-list conservation violated"


@dataclasses.dataclass
class SlotView:
    """Host view of one decode slot's cache occupancy."""
    owner: object
    num_tokens: int = 0        # absolute positions written so far


class PagedKVCache:
    """Per-slot page tables over a ``PageAllocator``.

    ``table()`` materializes the ``(num_slots, max_blocks)`` int32 page
    table the jitted decode step consumes; rows of inactive slots (and the
    unallocated tail of active rows) point at the scratch page.
    """

    def __init__(self, *, num_slots: int, num_pages: int, page_size: int,
                 max_blocks: int):
        self.num_slots = num_slots
        self.max_blocks = max_blocks
        self.page_size = page_size
        self.allocator = PageAllocator(num_pages, page_size)
        self._table = np.zeros((num_slots, max_blocks), np.int32)
        self._slots: dict[int, SlotView] = {}

    # -- queries ------------------------------------------------------------
    def table(self) -> np.ndarray:
        return self._table

    def blocks_of(self, slot: int) -> int:
        return len(self.allocator.pages_of(("slot", slot)))

    @property
    def occupancy(self) -> float:
        """Fraction of non-scratch pages currently live."""
        return self.allocator.num_live / (self.allocator.num_pages - 1)

    def _needed_blocks(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    # -- lifecycle ----------------------------------------------------------
    def admit(self, slot: int, n_tokens: int) -> bool:
        """Allocate pages covering ``n_tokens`` positions for ``slot``."""
        assert slot not in self._slots, f"slot {slot} already live"
        n_blocks = self._needed_blocks(n_tokens)
        if n_blocks > self.max_blocks:
            raise ValueError(
                f"request needs {n_blocks} blocks > max_blocks={self.max_blocks}")
        pages = self.allocator.alloc(("slot", slot), n_blocks)
        if pages is None:
            return False
        self._slots[slot] = SlotView(owner=("slot", slot), num_tokens=n_tokens)
        self._table[slot, :n_blocks] = pages
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Grow ``slot`` so position ``pos`` has a backing page."""
        view = self._slots[slot]
        have = self.blocks_of(slot)
        need = self._needed_blocks(pos + 1)
        if need > self.max_blocks:
            return False
        if need > have:
            pages = self.allocator.alloc(view.owner, need - have)
            if pages is None:
                return False
            self._table[slot, have:need] = pages
        view.num_tokens = max(view.num_tokens, pos + 1)
        return True

    def release(self, slot: int) -> int:
        """Free every page of ``slot`` (finish or eviction)."""
        self._slots.pop(slot, None)
        freed = self.allocator.free_owner(("slot", slot))
        self._table[slot, :] = SCRATCH_PAGE
        return freed

    # -- defrag -------------------------------------------------------------
    def defrag(self) -> np.ndarray | None:
        """Compact live pages; returns the pool gather index or None.

        The gather index ``g`` satisfies ``new_pool[i] = old_pool[g[i]]``
        for every page pool; page tables are rewritten in place.
        """
        mapping = self.allocator.defrag()
        if not mapping:
            return None
        lut = np.arange(self.allocator.num_pages, dtype=np.int32)
        for old, new in mapping.items():
            lut[old] = new
        self._table = lut[self._table]
        gather = np.arange(self.allocator.num_pages, dtype=np.int32)
        for old, new in mapping.items():
            gather[new] = old
        return gather
