"""Serving engines: static batch and continuous batching.

``ServeEngine`` mirrors the paper's deployment model (§VI "Deployment"):
prefill and decode are separate entry points (Splitwise/Dynamo-style phase
splitting, the paper's prerequisite architecture), and the decode loop runs
as ONE jitted ``lax.scan`` over steps — no host round-trip per token, the
JAX analogue of the RPU's host-free autonomous execution ("eliminating the
host-driven offload model used by GPUs").

``ContinuousServeEngine`` is the throughput path the paper's ISO-TDP claim
rests on: decode is bandwidth-bound, so sustained tokens/s is proportional
to slot occupancy.  Requests arrive raggedly; iteration-level batching
admits each one into a freed decode slot the moment both a slot and KV
pages are available.  Admission runs **chunked prefill straight into the
page pools**: each iteration advances every admitted-but-unfilled request
by one fixed-size chunk (one jitted shape, batched across slots at ragged
offsets) interleaved with the fused decode step, so a long prompt never
stalls the running batch.  With prefix caching on, admission shares a
matching prompt's leading pages read-only and prefill starts at the first
unseen token — lower TTFT and fewer prefill FLOPs for shared-prefix
traffic.

Request-level generation API (see ``runtime.sampling``): every request
carries its own ``SamplingParams``; the batched per-slot sampler is fused
into the jitted decode step, with per-slot temperature / top-k / top-p /
min-p / seed as ``(num_slots,)`` DATA arrays — changing the request mix
never recompiles.  Stop-token and max-tokens finish reasons are applied
on-host between steps, and progress is emitted as structured
``RequestOutput`` deltas through the incremental ``add_request()`` /
``step()`` interface (or the ``run(..., on_output=)`` streaming callback).
``runtime.llm.LLMEngine`` is the one front-end over both engines plus
speculative decoding.

Both engines are mesh-agnostic: pass shardings built by ``parallel.plan``
to run the same code distributed; CPU tests run them single-device.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.model import Model
from repro.parallel import hints
from repro.parallel.compat import shard_map
from repro.quant import kv as kvq
from repro.quant.linear import quantize_params
from repro.runtime import sampling
from repro.runtime.sampling import SamplingParams
from repro.runtime.kv_cache import PagedKVCache
from repro.runtime.scheduler import HANDOFF, RUNNING, Request, Scheduler
from repro.runtime.speculative import SpeculativeConfig, _check_rewindable
from repro.runtime.state_cache import (RingPageSpace, model_cache_layout,
                                       ring_pages_needed)


@dataclasses.dataclass
class RequestOutput:
    """One structured progress/result record for a request.

    Streaming emits one per request per engine iteration that produced
    tokens (``new_token_ids`` is the delta — across a preemption-restart
    the re-derived tokens are NOT re-emitted); the final record has
    ``finished=True`` with a ``finish_reason`` of "stop" or "length".
    The cumulative fields (``token_ids``, ``logprobs``) are populated on
    finished records only — intermediate deltas leave them empty so the
    host loop stays O(tokens), not O(tokens^2), per request.  Contract
    across backends: concatenating ``new_token_ids`` over every emitted
    record yields the full stream (static/speculative emit one record
    carrying everything; continuous spreads it over deltas), and the
    finished record's ``token_ids`` always holds the complete result —
    one-shot callers read ``token_ids``, streaming callers accumulate
    ``new_token_ids``."""
    rid: int
    new_token_ids: list[int]
    token_ids: list[int]               # cumulative; finished records only
    finished: bool = False
    finish_reason: str | None = None
    logprobs: list[float] | None = None    # cumulative, iff requested
    prompt_logprobs: list[float] | None = None   # finished records, iff asked
    metrics: dict = dataclasses.field(default_factory=dict)


def _seed_from_key(key) -> int:
    """Legacy ``key=`` arguments map onto the seeded-stream scheme."""
    return int(np.asarray(jax.random.key_data(key)).ravel()[-1]) & 0x7FFFFFFF


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # (B, n_new) int32
    logprobs: jnp.ndarray | None
    steps: int


class ServeEngine:
    """Batched request serving for one model (static batch)."""

    def __init__(self, model: Model, params: Any, *, max_len: int | None = None,
                 spec=None, sampling_params: SamplingParams | None = None,
                 donate_cache: bool = True, cache_dtype=None,
                 weight_format: str | None = None,
                 max_top_k: int = sampling.MAX_TOP_K):
        self.model = model
        self.params = params
        self.deployment = None
        if spec is not None:        # DeploymentSpec (runtime.deployment)
            dep = spec.resolve(model, params=params)
            self.deployment = dep
            max_len = dep.max_len if max_len is None else max_len
            cache_dtype = dep.cache_dtype if cache_dtype is None \
                else cache_dtype
            weight_format = spec.weight_format if weight_format is None \
                else weight_format
        if max_len is None:
            raise ValueError("pass max_len= or a DeploymentSpec via spec=")
        if kvq.is_quantized_cache_dtype(cache_dtype):
            raise NotImplementedError(
                "quantized cache_dtype (fp8/int8) needs the paged pools of "
                "the continuous engine; the static engine's dense cache "
                "stays a plain dtype")
        self.weight_format = weight_format
        if weight_format is not None:
            self.params = quantize_params(self.params, weight_format)
        self.max_len = max_len
        self.default_sampling = sampling_params or sampling.GREEDY
        self.max_top_k = int(max_top_k)
        self.cache_dtype = cache_dtype
        self._decode_loop = jax.jit(
            self._decode_loop_impl,
            static_argnames=("n_steps",),
            donate_argnums=(1,) if donate_cache else (),
        )
        self._prefill = jax.jit(self.model.prefill)

    # -- phase 1: prefill ---------------------------------------------------
    def prefill(self, batch: dict):
        """Run the prompt; returns (first_token_logits, cache, prompt_len)."""
        b = (batch["features"] if "features" in batch else batch["tokens"]).shape[0]
        cache = self.model.init_cache(b, self.max_len, dtype=self.cache_dtype)
        logits, cache = self._prefill(self.params, batch, cache)
        plen = batch["tokens"].shape[1]
        if "image_embeds" in batch:
            plen += batch["image_embeds"].shape[1]
        return logits, cache, plen

    # -- phase 2: autonomous decode loop -------------------------------------
    def _decode_loop_impl(self, first_tokens, cache, start_pos, temp, topk,
                          topp, minp, seed, rep, bias_ids, bias_vals,
                          presence, *, n_steps: int):
        rows = jnp.arange(first_tokens.shape[0])

        def step(carry, _):
            tokens, cache, pos, pres = carry
            # the incoming token joins the stream before the next draw —
            # the repetition penalty sees prompt + every generated token
            pres = pres.at[rows, tokens].set(True)
            logits, cache = self.model.decode_step(self.params, tokens, cache,
                                                   pos)
            # the token being generated sits at sequence index pos + 1
            nxt, lp = sampling.sample_slots(
                logits, temp, topk, topp, minp, seed, pos + 1,
                max_top_k=self.max_top_k, rep_penalty=rep,
                bias_ids=bias_ids, bias_vals=bias_vals, presence=pres)
            return (nxt, cache, pos + 1, pres), (nxt, lp)

        (_, cache, _, _), (toks, lps) = jax.lax.scan(
            step, (first_tokens, cache, start_pos, presence), length=n_steps)
        return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(lps, 0, 1), cache

    def _resolve_params(self, b: int, sampling_params, key) -> list[SamplingParams]:
        if sampling_params is None:
            sp = self.default_sampling
            if key is not None and not sp.is_greedy and sp.seed == 0:
                sp = dataclasses.replace(sp, seed=_seed_from_key(key))
            sps = [sp] * b
        elif isinstance(sampling_params, SamplingParams):
            sps = [sampling_params] * b
        else:
            sps = list(sampling_params)
            if len(sps) != b:
                raise ValueError(f"{len(sps)} SamplingParams for batch {b}")
        for sp in sps:
            if sp.top_k > self.max_top_k:
                raise ValueError(f"top_k={sp.top_k} exceeds the engine's "
                                 f"static max_top_k={self.max_top_k}")
        return sps

    def generate(self, batch: dict, *, max_new_tokens: int,
                 sampling_params=None, key=None) -> GenerationResult:
        """prefill + decode max_new_tokens; returns all generated tokens.

        ``sampling_params``: one ``SamplingParams`` for the whole batch or a
        per-row list — data, not shapes, so any mix shares the compiled
        loop.  Stop-token truncation is the caller's concern (the scan has
        a fixed trip count); ``LLMEngine`` applies it."""
        b = (batch["features"] if "features" in batch
             else batch["tokens"]).shape[0]
        sps = self._resolve_params(b, sampling_params, key)
        temp, topk, topp, minp, seed = (
            jnp.asarray(a) for a in sampling.stack_params(sps))
        rep, bias_ids, bias_vals = (
            jnp.asarray(a) for a in sampling.stack_extras(sps))
        # token-presence rows seed the repetition penalty with the prompt
        pres0 = np.zeros((b, self.model.cfg.padded_vocab), np.bool_)
        if "tokens" in batch:
            pres0[np.arange(b)[:, None], np.asarray(batch["tokens"])] = True
        pres0 = jnp.asarray(pres0)
        logits, cache, plen = self.prefill(batch)
        first, lp0 = sampling.sample_slots(
            logits, temp, topk, topp, minp, seed,
            jnp.full((b,), plen, jnp.int32), max_top_k=self.max_top_k,
            rep_penalty=rep, bias_ids=bias_ids, bias_vals=bias_vals,
            presence=pres0)
        toks, lps, cache = self._decode_loop(
            first, cache, jnp.int32(plen), temp, topk, topp, minp, seed,
            rep, bias_ids, bias_vals, pres0,
            n_steps=max_new_tokens - 1)
        all_toks = jnp.concatenate([first[:, None], toks], axis=1)
        all_lps = (jnp.concatenate([lp0[:, None], lps], axis=1)
                   if any(sp.logprobs for sp in sps) else None)
        return GenerationResult(tokens=all_toks, logprobs=all_lps,
                                steps=max_new_tokens)


@dataclasses.dataclass
class ContinuousStats:
    """Outcome of one ``ContinuousServeEngine.run``."""
    results: dict                 # rid -> np.ndarray (n_new,) int32
    steps: int                    # fused decode iterations executed
    occupancy: float              # mean fraction of decoding slots per step
    wall: float                   # seconds, admission of first request -> done
    preemptions: int
    chunks: int = 0               # prefill chunk rows executed
    prefill_tokens: int = 0       # prompt tokens actually computed
    prompt_tokens: int = 0        # prompt tokens across all admissions
    prefix_hit_tokens: int = 0    # prompt tokens served from shared pages
    cow_events: int = 0
    # -- speculative decoding (all zero when speculation is off) --
    spec_windows: int = 0         # draft/verify windows across all requests
    spec_drafted: int = 0         # draft proposals made (gamma per window)
    spec_accepted: int = 0        # draft proposals accepted
    # -- disaggregated serving (all zero on a colocated engine) --
    handoffs: int = 0             # chains transferred prefill -> decode
    handoff_pages: int = 0        # pages physically moved
    handoff_bytes: int = 0        # pool bytes moved (all leaves, both sets)
    handoff_shared_tokens: int = 0  # transfer skipped via decode-side prefix
    per_request: dict = dataclasses.field(default_factory=dict)
    # per_request[rid] = {"preemptions", "chunks", "shared_tokens", "ttft",
    #                     "tpot", "finish_time", "spec_windows",
    #                     "spec_accepted"}
    outputs: dict = dataclasses.field(default_factory=dict)
    # outputs[rid] = final RequestOutput (finish_reason, logprobs, timing)

    @property
    def total_tokens(self) -> int:
        return int(sum(t.shape[0] for t in self.results.values()))

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hit_tokens / max(self.prompt_tokens, 1)

    @property
    def accepted_per_window(self) -> float:
        """Mean draft proposals accepted per window (0..gamma); each window
        also emits one corrected/bonus token on top."""
        return self.spec_accepted / max(self.spec_windows, 1)

    @property
    def spec_wasted(self) -> int:
        """Draft tokens proposed but rejected — the speculation overhead."""
        return self.spec_drafted - self.spec_accepted

    def latency_quantiles(self, metric: str = "ttft") -> dict | None:
        """p50/p95/p99/mean of a per-request latency metric, or None.

        metric is a key of the per_request records — "ttft" (arrival ->
        first token) or "tpot" (mean inter-token seconds after the first).
        Requests where the metric is unset (e.g. single-token outputs have
        no TPOT) are skipped.
        """
        ts = sorted(r[metric] for r in self.per_request.values()
                    if r.get(metric) is not None)
        if not ts:
            return None
        def pct(q: float) -> float:
            return ts[min(len(ts) - 1, int(len(ts) * q))]
        return {"p50": pct(0.50), "p95": pct(0.95), "p99": pct(0.99),
                "mean": sum(ts) / len(ts)}

    def ttft_quantiles(self) -> tuple[float, float, float] | None:
        """(p50, p99, mean) time-to-first-token in seconds, or None."""
        q = self.latency_quantiles("ttft")
        if q is None:
            return None
        return q["p50"], q["p99"], q["mean"]


class ContinuousServeEngine:
    """Iteration-level continuous batching over a block-paged KV cache.

    The jitted decode step has a fixed slot batch; per-slot page tables and
    ragged positions route each slot's K/V stream through the physical page
    pools (``Model.decode_step_paged`` — on accelerators the gather-fused
    Pallas kernel, no dense intermediate), and the batched per-slot sampler
    draws each slot's next token inside the same jitted step.  Admission
    (chunked prefill into the pools via ``Model.prefill_chunk_paged``),
    growth, eviction, copy-on-write, finish-reason checks, and output
    emission are host-side bookkeeping between steps — no recompiles: the
    only jitted shapes are the decode step and one ``(bucket,
    prefill_chunk)`` prefill chunk per power-of-two bucket, and every
    sampling control is data.

    Drive it incrementally (``add_request`` then ``step`` until
    ``has_unfinished()`` is False, collecting ``RequestOutput`` deltas) or
    in batch via ``run(requests, on_output=...)``.

    Sizing: pass a ``DeploymentSpec`` via ``spec=`` and the pool/slot
    knobs (``num_pages``/``num_slots``/``page_size``/``max_len``/
    ``prefill_chunk``/``cache_dtype``/``mesh`` and the scheduler's
    ``max_decode_slots`` admission hint) derive from the hardware point's
    memory budget and bandwidth roofline (``runtime.deployment``);
    explicit kwargs override individual values.  The resolved budget is
    kept on ``self.deployment``.
    """

    def __init__(self, model: Model, params: Any, *,
                 num_slots: int | None = None, page_size: int | None = None,
                 num_pages: int | None = None, max_len: int | None = None,
                 spec=None,
                 sampling_params: SamplingParams | None = None,
                 cache_dtype=None, weight_format: str | None = None,
                 prefill_chunk: int | None = None,
                 enable_prefix_cache: bool = True,
                 max_top_k: int = sampling.MAX_TOP_K,
                 mesh=None, tp_reduce: str = "auto",
                 max_decode_slots: int | None = None,
                 speculative: SpeculativeConfig | None = None,
                 phase: str = "colocated"):
        if model.cfg.frontend is not None:
            raise NotImplementedError(
                "continuous batching serves token frontends only")
        if phase not in ("colocated", "prefill", "decode"):
            raise ValueError(f"phase={phase!r}: expected 'colocated', "
                             f"'prefill', or 'decode'")
        self.phase = phase
        self.model = model
        self.params = params
        # -- DeploymentSpec resolution: pool/slot knobs derived from the
        # hardware point; explicit kwargs override individual values --
        self.deployment = None
        if spec is not None:
            rkw = {}
            if speculative is not None:
                # price the draft into the budget: weights join the
                # capacity split, and every logical KV page carries both
                # pool sets' bytes (self-draft duplicates the target's)
                rkw = dict(draft=speculative.draft_model or model,
                           draft_params=(speculative.draft_params
                                         if speculative.draft_model
                                         is not None else params),
                           gamma=speculative.gamma)
            dep = spec.resolve(model, params=params, mesh=mesh, phase=phase,
                               **rkw)
            self.deployment = dep
            mesh = dep.mesh
            num_slots = dep.num_slots if num_slots is None else num_slots
            page_size = dep.page_size if page_size is None else page_size
            num_pages = dep.num_pages if num_pages is None else num_pages
            max_len = dep.max_len if max_len is None else max_len
            prefill_chunk = dep.prefill_chunk if prefill_chunk is None \
                else prefill_chunk
            cache_dtype = dep.cache_dtype if cache_dtype is None \
                else cache_dtype
            weight_format = spec.weight_format if weight_format is None \
                else weight_format
            max_decode_slots = dep.max_decode_slots \
                if max_decode_slots is None else max_decode_slots
            if tp_reduce == "auto":
                tp_reduce = dep.tp_reduce
        missing = [k for k, v in (("num_slots", num_slots),
                                  ("page_size", page_size),
                                  ("num_pages", num_pages),
                                  ("max_len", max_len)) if v is None]
        if missing:
            raise ValueError(
                f"pass a DeploymentSpec via spec= or the explicit knobs "
                f"{missing}")
        prefill_chunk = 64 if prefill_chunk is None else prefill_chunk
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_len = max_len
        self.max_decode_slots = max_decode_slots
        self.max_blocks = -(-max_len // page_size)
        if num_pages - 1 < self.max_blocks:   # page 0 is scratch
            raise ValueError(
                f"num_pages={num_pages} cannot back even one max-length "
                f"request ({self.max_blocks} blocks + scratch)")
        self.default_sampling = sampling_params or sampling.GREEDY
        self.max_top_k = int(max_top_k)
        kvq.validate_cache_dtype(cache_dtype)
        self.cache_dtype = cache_dtype
        self.weight_format = weight_format
        if int(prefill_chunk) < 1:
            raise ValueError(f"prefill_chunk={prefill_chunk} must be >= 1")
        self.prefill_chunk = int(prefill_chunk)
        self.enable_prefix_cache = enable_prefix_cache
        self.defrag_every = 0
        self._vocab = model.cfg.padded_vocab
        # -- stateful cache layouts (runtime.state_cache): SSM/hybrid state
        # pools and ring-page reclamation for sliding-window layers --
        lay = model_cache_layout(model.plan)
        self._layout = lay
        if not lay.has_full:
            # no full-KV segment -> no shareable, CoW-protected chains;
            # the prefix index must never hand out ring or state "hits"
            self.enable_prefix_cache = False
        if lay.stateful:
            arch = model.cfg.name
            if speculative is not None:
                raise NotImplementedError(
                    f"speculative decoding is unsupported for {arch!r}: "
                    f"draft/verify rewinds token-indexed KV pages, but "
                    f"recurrent SSM state and reclaimed ring pages cannot "
                    f"rewind (recorded follow-on)")
            if phase != "colocated":
                raise NotImplementedError(
                    f"disaggregated serving is unsupported for {arch!r}: "
                    f"the KV handoff moves page chains only — recurrent "
                    f"state and ring residency need their own transfer "
                    f"(recorded follow-on)")
        if lay.has_state:
            if kvq.is_quantized_cache_dtype(cache_dtype):
                raise NotImplementedError(
                    f"cache_dtype={cache_dtype!r} is unsupported for the "
                    f"state-carrying arch {model.cfg.name!r}: SSM state "
                    f"pools stay bf16/f32 — quantized state is a recorded "
                    f"follow-on")
            if mesh is not None:
                raise NotImplementedError(
                    f"tensor-parallel serving of the state-carrying arch "
                    f"{model.cfg.name!r} needs sharded state pools "
                    f"(recorded follow-on); run it single-device")
        self.ring_pages = 0
        if lay.has_ring:
            # size the ring pool so ensure() can never fail: every slot at
            # its transient (mid-prefill-chunk) residency peak at once
            self.ring_pages = ring_pages_needed(
                num_slots=num_slots, window=lay.ring_window,
                page_size=page_size, max_blocks=self.max_blocks,
                prefill_chunk=self.prefill_chunk)
        # -- mesh execution (tensor-parallel paged serving) --
        self.mesh = mesh
        self.serve_plan = None
        self._pool_model = model
        if mesh is not None:
            from repro.parallel.plan import make_paged_serve_plan
            self.serve_plan = make_paged_serve_plan(model.cfg, mesh,
                                                    reduce=tp_reduce)
            self._local_model = Model(
                self.serve_plan.local_config(model.cfg),
                moe_impl=model.moe_impl)
            if self.serve_plan.kv_repl > 1:
                # kvh < tp: KV projections physically replicate per head
                # group, and the pools widen to tp KV heads (one per shard)
                params = self.serve_plan.prepare_params(params, model.cfg)
                self._pool_model = Model(
                    self.serve_plan.pool_config(model.cfg),
                    moe_impl=model.moe_impl)
            if weight_format is not None:
                # pack AFTER the kv_repl expansion (packing operates on the
                # physical column layout each shard slices) and BEFORE
                # device_put, so codes/scales shard through the same
                # partition specs as the weights they replace
                params = quantize_params(params, weight_format)
            self.params = jax.device_put(
                params, self.serve_plan.param_shardings(params))
            self._param_specs = self.serve_plan.param_specs(params)
            self._pool_specs = self.serve_plan.pool_specs(
                self._pool_model, cache_dtype=self.cache_dtype)
            self._paged_decode = self._shard_paged(
                self._local_model.decode_step_paged, n_extra=1)   # pos
            self._paged_chunk = self._shard_paged(
                self._local_model.prefill_chunk_paged, n_extra=2)  # start, valid
            self._paged_chunk_scored = self._shard_paged(
                self._local_model.prefill_chunk_scored_paged, n_extra=2,
                n_out=2)
        else:
            if weight_format is not None:
                self.params = quantize_params(params, weight_format)
            self._paged_decode = model.decode_step_paged
            self._paged_chunk = model.prefill_chunk_paged
            self._paged_chunk_scored = model.prefill_chunk_scored_paged
        # ring/state entry points: same model fns with the extra operands
        # threaded (ring tables are replicated data like page tables, so
        # the TP path wraps them as plain extras; state pools are
        # single-device only — guarded above)
        if lay.has_ring:
            if mesh is not None:
                lm = self._local_model
                self._paged_decode_ring = self._shard_paged(
                    lambda p, t, pl, tab, pos, ring:
                        lm.decode_step_paged(p, t, pl, tab, pos,
                                             ring_table=ring),
                    n_extra=2)
                self._paged_chunk_ring = self._shard_paged(
                    lambda p, t, pl, tab, s, v, ring:
                        lm.prefill_chunk_paged(p, t, pl, tab, s, v,
                                               ring_table=ring),
                    n_extra=3)
                self._paged_chunk_scored_ring = self._shard_paged(
                    lambda p, t, pl, tab, s, v, ring:
                        lm.prefill_chunk_scored_paged(p, t, pl, tab, s, v,
                                                      ring_table=ring),
                    n_extra=3, n_out=2)
            else:
                self._paged_decode_ring = (
                    lambda p, t, pl, tab, pos, ring:
                        model.decode_step_paged(p, t, pl, tab, pos,
                                                ring_table=ring))
                self._paged_chunk_ring = (
                    lambda p, t, pl, tab, s, v, ring:
                        model.prefill_chunk_paged(p, t, pl, tab, s, v,
                                                  ring_table=ring))
                self._paged_chunk_scored_ring = (
                    lambda p, t, pl, tab, s, v, ring:
                        model.prefill_chunk_scored_paged(p, t, pl, tab, s, v,
                                                         ring_table=ring))
        if lay.has_state:
            self._paged_decode_state = (
                lambda p, t, pl, tab, pos, st, ring, ok:
                    model.decode_step_paged(p, t, pl, tab, pos, states=st,
                                            ring_table=ring, state_ok=ok))
            self._paged_chunk_state = (
                lambda p, t, pl, tab, s, v, st, ring, sl:
                    model.prefill_chunk_paged(p, t, pl, tab, s, v, states=st,
                                              ring_table=ring, slot_idx=sl))
            self._paged_chunk_scored_state = (
                lambda p, t, pl, tab, s, v, st, ring, sl:
                    model.prefill_chunk_scored_paged(
                        p, t, pl, tab, s, v, states=st, ring_table=ring,
                        slot_idx=sl))
        # -- speculative decoding: per-slot draft state is a SECOND set of
        # pool leaves over the SAME logical page-id space (one allocator,
        # one set of page tables), so prefix sharing, copy-on-write,
        # preemption, and defrag act on target and draft in lockstep --
        self.spec = speculative
        self._gamma = int(speculative.gamma) if speculative is not None else 0
        self._draft_plan = None
        if speculative is not None:
            _check_rewindable(model)
            dm = speculative.draft_model
            if dm is None:
                # self-draft: same weights propose and verify (acceptance
                # ~1; tests and smoke runs).  The draft still keeps its own
                # pool leaves — its scan-ahead KV writes must not clobber
                # the target's verified entries.
                self._draft_params = self.params
                self._draft_pool_model = self._pool_model
                self._draft_plan = self.serve_plan
                self._paged_draft_decode = self._paged_decode
                self._paged_draft_chunk = self._paged_chunk
            else:
                if dm.cfg.padded_vocab != model.cfg.padded_vocab:
                    raise ValueError(
                        "draft and target must share a vocabulary: "
                        f"{dm.cfg.padded_vocab} vs {model.cfg.padded_vocab}")
                dparams = speculative.draft_params
                if dparams is None:
                    raise ValueError("SpeculativeConfig.draft_params is "
                                     "required when draft_model is set")
                self._draft_pool_model = dm
                if mesh is not None:
                    from repro.parallel.plan import make_paged_serve_plan
                    self._draft_plan = make_paged_serve_plan(
                        dm.cfg, mesh, reduce=tp_reduce)
                    dlocal = Model(self._draft_plan.local_config(dm.cfg),
                                   moe_impl=dm.moe_impl)
                    if self._draft_plan.kv_repl > 1:
                        dparams = self._draft_plan.prepare_params(dparams,
                                                                  dm.cfg)
                        self._draft_pool_model = Model(
                            self._draft_plan.pool_config(dm.cfg),
                            moe_impl=dm.moe_impl)
                    if weight_format is not None:
                        dparams = quantize_params(dparams, weight_format)
                    self._draft_params = jax.device_put(
                        dparams, self._draft_plan.param_shardings(dparams))
                    dspecs = self._draft_plan.param_specs(dparams)
                    dpool = self._draft_plan.pool_specs(
                        self._draft_pool_model, cache_dtype=self.cache_dtype)
                    self._paged_draft_decode = self._shard_paged(
                        dlocal.decode_step_paged, n_extra=1,
                        plan=self._draft_plan, param_specs=dspecs,
                        pool_specs=dpool)
                    self._paged_draft_chunk = self._shard_paged(
                        dlocal.prefill_chunk_paged, n_extra=2,
                        plan=self._draft_plan, param_specs=dspecs,
                        pool_specs=dpool)
                else:
                    if weight_format is not None:
                        dparams = quantize_params(dparams, weight_format)
                    self._draft_params = dparams
                    self._paged_draft_decode = dm.decode_step_paged
                    self._paged_draft_chunk = dm.prefill_chunk_paged
            # multi-token verify runs through the TARGET's paged decode
            # path with q_len = gamma + 1 (same dispatch, not a new kernel)
            self._paged_multi = (
                self._shard_paged(self._local_model.decode_step_paged,
                                  n_extra=2)                 # pos, valid
                if mesh is not None else model.decode_step_paged)
            self._spec_draft = jax.jit(self._spec_draft_impl,
                                       donate_argnums=(1,))
            self._spec_verify = jax.jit(self._spec_verify_impl,
                                        donate_argnums=(1, 2))
            self._draft_chunk = jax.jit(self._draft_chunk_impl,
                                        donate_argnums=(1,))
            self._copy_page_draft = jax.jit(
                functools.partial(self._copy_page_impl,
                                  self._draft_pool_model.plan),
                donate_argnums=(0,))
        self._step_fn = jax.jit(self._step_impl, donate_argnums=(1, 2, 3))
        self._chunk = jax.jit(self._chunk_impl, donate_argnums=(1, 2))
        self._chunk_scored = jax.jit(self._chunk_scored_impl,
                                     donate_argnums=(1, 2))
        self._copy_page = jax.jit(
            functools.partial(self._copy_page_impl, self._pool_model.plan),
            donate_argnums=(0,))
        # KV-handoff seam: gather page rows to host / scatter staged rows
        # into the pools.  One compile per pow-2 chain-length bucket.
        self._gather_pages = jax.jit(
            functools.partial(self._gather_pages_impl, self._pool_model.plan))
        self._scatter_pages = jax.jit(
            functools.partial(self._scatter_pages_impl, self._pool_model.plan),
            donate_argnums=(0,))
        if speculative is not None:
            self._gather_pages_draft = jax.jit(functools.partial(
                self._gather_pages_impl, self._draft_pool_model.plan))
            self._scatter_pages_draft = jax.jit(functools.partial(
                self._scatter_pages_impl, self._draft_pool_model.plan),
                donate_argnums=(0,))
        self._sched: Scheduler | None = None

    # -- sharded execution --------------------------------------------------
    def _shard_paged(self, fn, *, n_extra: int, n_out: int = 1, plan=None,
                     param_specs=None, pool_specs=None):
        """Wrap a paged model fn (params, tokens, pools, table, *extras) ->
        (*n_out replicated outputs, pools) in one manual shard_map over the
        serve plan's TP axis: params/pools enter pre-sliced per their
        specs, the body runs the LOCAL-geometry model (its ``tp_psum``
        marks close each column/row pair), and logits come back
        replicated.  Page tables, positions, and every sampling tensor
        stay replicated data, so the jit signature is identical to the
        single-device path — no extra compiles per mesh shape.  The
        speculative draft model passes its own plan/specs; the target's
        are the default."""
        sp = plan if plan is not None else self.serve_plan
        param_specs = self._param_specs if param_specs is None else param_specs
        pool_specs = self._pool_specs if pool_specs is None else pool_specs

        def body(params, tokens, pools, table, *extras):
            with hints.suspend_hints(), hints.manual_tp_axis(sp.axis,
                                                             sp.reduce):
                return fn(params, tokens, pools, table, *extras)

        rep = P()
        return shard_map(
            body, mesh=sp.mesh,
            in_specs=(param_specs, rep, pool_specs, rep)
            + (rep,) * n_extra,
            out_specs=(rep,) * n_out + (pool_specs,),
            axis_names={sp.axis}, check_vma=False)

    # -- jitted pieces ------------------------------------------------------
    def _step_impl(self, params, pools, states, presence, tokens, pos,
                   page_table, ring_table, state_ok, temp, topk, topp, minp,
                   seed, rep, bias_ids, bias_vals):
        lay = self._layout
        if lay.has_state:
            logits, pools, states = self._paged_decode_state(
                params, tokens, pools, page_table, pos, states, ring_table,
                state_ok)
        elif lay.has_ring:
            logits, pools = self._paged_decode_ring(
                params, tokens, pools, page_table, pos, ring_table)
        else:
            logits, pools = self._paged_decode(params, tokens, pools,
                                               page_table, pos)
        # the incoming token sits at index pos; the one being generated at
        # pos + 1 — its PRNG key is fold_in(seed, pos + 1)
        nxt, lp = sampling.sample_slots(logits, temp, topk, topp, minp, seed,
                                        pos + 1, max_top_k=self.max_top_k,
                                        rep_penalty=rep, bias_ids=bias_ids,
                                        bias_vals=bias_vals,
                                        presence=presence)
        # the sampled token joins its slot's presence row for the next
        # step's repetition penalty (rows of inactive slots accumulate
        # garbage harmlessly — admission re-uploads the host mirror)
        presence = presence.at[jnp.arange(nxt.shape[0]), nxt].set(True)
        return nxt, lp, pools, states, presence

    def _chunk_impl(self, params, pools, states, presence, tokens, page_table,
                    ring_table, slot_idx, start, valid, temp, topk, topp,
                    minp, seed, rep, bias_ids, bias_vals):
        lay = self._layout
        if lay.has_state:
            logits, pools, states = self._paged_chunk_state(
                params, tokens, pools, page_table, start, valid, states,
                ring_table, slot_idx)
        elif lay.has_ring:
            logits, pools = self._paged_chunk_ring(
                params, tokens, pools, page_table, start, valid, ring_table)
        else:
            logits, pools = self._paged_chunk(
                params, tokens, pools, page_table, start, valid)
        # a request's first token is generated at index prompt_len ==
        # start + valid of its final chunk (other rows' draws are ignored);
        # presence rows carry the slot's full prompt already
        first, lp = sampling.sample_slots(logits, temp, topk, topp, minp,
                                          seed, start + valid,
                                          max_top_k=self.max_top_k,
                                          rep_penalty=rep, bias_ids=bias_ids,
                                          bias_vals=bias_vals,
                                          presence=presence)
        return first, lp, pools, states

    def _chunk_scored_impl(self, params, pools, states, presence, tokens,
                           page_table, ring_table, slot_idx, start, valid,
                           tgt, temp, topk, topp, minp, seed, rep, bias_ids,
                           bias_vals):
        """The prompt-logprobs variant of ``_chunk_impl``: the chunk's full
        (B, C, V) logits additionally score the NEXT prompt token at every
        chunk position (``tgt[i, j] = prompt[start + j + 1]``, host-built).
        The first-token draw still goes through the last-position head
        logits, so scored admissions sample the identical first token."""
        lay = self._layout
        if lay.has_state:
            last_logits, full, pools, states = self._paged_chunk_scored_state(
                params, tokens, pools, page_table, start, valid, states,
                ring_table, slot_idx)
        elif lay.has_ring:
            last_logits, full, pools = self._paged_chunk_scored_ring(
                params, tokens, pools, page_table, start, valid, ring_table)
        else:
            last_logits, full, pools = self._paged_chunk_scored(
                params, tokens, pools, page_table, start, valid)
        first, lp = sampling.sample_slots(last_logits, temp, topk, topp, minp,
                                          seed, start + valid,
                                          max_top_k=self.max_top_k,
                                          rep_penalty=rep, bias_ids=bias_ids,
                                          bias_vals=bias_vals,
                                          presence=presence)
        lf = full.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        plp = jnp.take_along_axis(lf, tgt[..., None], axis=-1)[..., 0] - lse
        return first, lp, plp, pools, states

    def _draft_chunk_impl(self, dparams, dpools, tokens, page_table, start,
                          valid):
        """Mirror one prefill chunk into the draft pools (logits dropped):
        after admission both pool sets hold the prompt's KV, so the first
        draft window attends over the full history."""
        _, dpools = self._paged_draft_chunk(dparams, tokens, dpools,
                                            page_table, start, valid)
        return dpools

    def _spec_draft_impl(self, dparams, dpools, presence, tokens, pos,
                         page_table, temp, topk, topp, minp, seed, rep,
                         bias_ids, bias_vals):
        """One draft pass: gamma chained single-token decode steps through
        the draft pools, each drawing its proposal from the SAME
        processed/filtered distribution the target verifies against
        (recorded as q), from the request's tagged TAG_PROPOSE stream —
        window randomness is keyed by absolute token index, so a
        preemption restart replays identical windows.

        The trailing KV-only step backfills the draft cache for the last
        proposal (position pos + gamma): on a full accept the next
        window's draft must see the whole history or it attends over a
        hole and diverges from the target even when the models are
        identical.  Presence mutations stay draft-local (the carry is
        dropped): proposals are not emissions until the verify step
        accepts them."""
        g = self._gamma
        rows = jnp.arange(tokens.shape[0])

        def dstep(carry, j):
            tok, pools, pres = carry
            pres = pres.at[rows, tok].set(True)
            logits, pools = self._paged_draft_decode(dparams, tok, pools,
                                                     page_table, pos + j)
            lg = sampling.apply_processors(logits, rep_penalty=rep,
                                           bias_ids=bias_ids,
                                           bias_vals=bias_vals, presence=pres)
            q = sampling.slot_dist(lg, temp, topk, topp, minp,
                                   max_top_k=self.max_top_k)
            u = sampling.spec_uniform(seed, pos + j + 1, sampling.TAG_PROPOSE)
            nxt = sampling.slot_draw(q, u)
            return (nxt, pools, pres), (nxt, q)

        (last, dpools, _), (prop, q_dists) = jax.lax.scan(
            dstep, (tokens, dpools, presence), jnp.arange(g))
        _, dpools = self._paged_draft_decode(dparams, last, dpools,
                                             page_table, pos + g)
        return jnp.moveaxis(prop, 0, 1), q_dists, dpools

    def _spec_verify_impl(self, params, pools, presence, tokens, prop,
                          q_dists, pos, page_table, temp, topk, topp, minp,
                          seed, rep, bias_ids, bias_vals):
        """One verify pass: the target scores [last_emitted, prop_1..g] as
        a single multi-token paged decode (q_len = gamma + 1 through
        ``decode_step_paged``'s 2-D form — bit-identical per-position
        logits to sequential decode on CPU), then applies the stochastic
        acceptance rule of Leviathan et al. per slot:

          accept prop_j while u_j < min(1, p(prop_j) / q(prop_j)); at the
          first rejection resample from max(p - q, 0) normalized; on a
          full accept draw the bonus token from p at the extra position.

        p and q are both ``apply_processors`` + ``slot_dist`` outputs with
        the RUNNING presence threaded position by position, so acceptance
        is correct under per-slot repetition penalty / logit bias /
        filtering.  Greedy slots (temperature <= 0) get exact one-hots on
        both sides: proposals accept iff they equal the target argmax and
        the correction IS the target argmax — byte-identical to the
        non-speculative engine.  Rejected positions need no KV rollback:
        their pool writes sit at slot positions > the new ``pos`` and are
        masked (then overwritten) by the next window.

        Returns (tokens (B, gamma+1), n_emit (B,), logprobs (B, gamma+1),
        pools, presence); entries past n_emit are padding."""
        g = self._gamma
        b = tokens.shape[0]
        rows = jnp.arange(b)
        t_in = jnp.concatenate([tokens[:, None], prop], axis=1)  # (B, g+1)
        logits, pools = self._paged_multi(
            params, t_in, pools, page_table, pos,
            jnp.full((b,), g + 1, jnp.int32))

        def pstep(pres, j):
            # token j joins the stream before position j's draw — the
            # penalty sees prompt + everything emitted through pos + j
            pres = pres.at[rows, t_in[:, j]].set(True)
            lg = sampling.apply_processors(logits[:, j], rep_penalty=rep,
                                           bias_ids=bias_ids,
                                           bias_vals=bias_vals, presence=pres)
            p = sampling.slot_dist(lg, temp, topk, topp, minp,
                                   max_top_k=self.max_top_k)
            glp = jnp.max(lg, axis=-1) - jax.nn.logsumexp(lg, axis=-1)
            return pres, (p, glp)

        _, (p_dists, glps) = jax.lax.scan(pstep, presence, jnp.arange(g + 1))
        jdx = jnp.arange(g)
        p_prop = p_dists[jdx[:, None], rows[None, :], prop.T]    # (g, B)
        q_prop = q_dists[jdx[:, None], rows[None, :], prop.T]
        u = sampling.spec_uniform(seed[None, :],
                                  pos[None, :] + jdx[:, None] + 1,
                                  sampling.TAG_ACCEPT)
        accept = u < jnp.minimum(1.0, p_prop / jnp.maximum(q_prop, 1e-20))
        n_acc = jnp.where(jnp.any(~accept, axis=0),
                          jnp.argmax(~accept, axis=0), g)        # (B,)
        # correction (first rejection) / bonus (full accept) distribution
        q_pad = jnp.concatenate([q_dists, jnp.zeros_like(q_dists[:1])],
                                axis=0)
        p_at = p_dists[n_acc, rows]                              # (B, V)
        resid = jnp.maximum(p_at - q_pad[n_acc, rows], 0.0)
        rs = jnp.sum(resid, axis=-1, keepdims=True)
        corr = jnp.where((n_acc[:, None] == g) | (rs <= 1e-20), p_at,
                         resid / jnp.maximum(rs, 1e-20))
        uc = sampling.spec_uniform(seed, pos + n_acc + 1,
                                   sampling.TAG_CORRECT)
        corrected = sampling.slot_draw(corr, uc)
        jcols = jnp.arange(g + 1)
        out = jnp.where(jcols[None, :] < n_acc[:, None],
                        jnp.concatenate([prop, prop[:, :1]], axis=1), 0)
        out = jnp.where(jcols[None, :] == n_acc[:, None],
                        corrected[:, None], out)
        # logprobs under the target's filtered per-position distribution;
        # greedy rows report the exact max-logit logprob ``sample_slots``
        # would (same floats: max == top_k[0], same logsumexp)
        pd = jnp.moveaxis(p_dists, 0, 1)                         # (B, g+1, V)
        lp_dist = jnp.log(jnp.maximum(
            jnp.take_along_axis(pd, out[..., None], axis=-1)[..., 0], 1e-38))
        lp = jnp.where((temp <= 0.0)[:, None], jnp.moveaxis(glps, 0, 1),
                       lp_dist)
        # presence gains the EMITTED tokens only (rejected proposals were
        # never part of the stream); masked columns re-scatter the first
        # emitted token — a harmless duplicate
        emit_ok = jcols[None, :] <= n_acc[:, None]
        scat = jnp.where(emit_ok, out, out[:, :1])
        presence = presence.at[rows[:, None], scat].set(True)
        return out, n_acc + 1, lp, pools, presence

    @staticmethod
    def _copy_page_impl(plan, pools, dst, src):
        """pools[dst] = pools[src] on every pool leaf (copy-on-write).
        ``plan`` is bound per pool set (functools.partial): the target and
        the speculative draft pools each get a copy jit over their own
        segment layout.  Ring segments (``seg.window``) live in their own
        page-id space and are never shared, so full-space copy-on-write
        ids must not touch them; SSM segments carry empty pools and fall
        through the dict comprehension untouched."""
        new_pools = []
        for si, seg in enumerate(plan):
            if seg.window is not None:
                new_pools.append(pools[si])
                continue
            copy = ((lambda a: a.at[dst].set(a[src])) if seg.reps == 1
                    else (lambda a: a.at[:, dst].set(a[:, src])))
            new_pools.append(tuple(
                {k: copy(v) for k, v in pool.items()} for pool in pools[si]))
        return new_pools

    @staticmethod
    def _gather_pages_impl(plan, pools, ids):
        """Pull page rows ``ids`` out of every pool leaf (KV handoff read
        side).  Per-token quantization scale leaves ride in the pools, so
        they travel with the codes for free.  Ring segments are excluded
        (stateful layouts reject phase splitting at construction)."""
        out = []
        for si, seg in enumerate(plan):
            if seg.window is not None:
                out.append(tuple({} for _ in pools[si]))
                continue
            axis = 0 if seg.reps == 1 else 1
            out.append(tuple(
                {k: jnp.take(v, ids, axis=axis) for k, v in pool.items()}
                for pool in pools[si]))
        return out

    @staticmethod
    def _scatter_pages_impl(plan, pools, staged, ids):
        """Write staged page rows into pool pages ``ids`` (KV handoff write
        side; ``pools`` donated)."""
        new_pools = []
        for si, seg in enumerate(plan):
            if seg.window is not None:
                new_pools.append(pools[si])
                continue
            if seg.reps == 1:
                put = lambda a, vals: a.at[ids].set(vals)
            else:
                put = lambda a, vals: a.at[:, ids].set(vals)
            new_pools.append(tuple(
                {k: put(v, staged[si][pi][k]) for k, v in pool.items()}
                for pi, pool in enumerate(pools[si])))
        return new_pools

    @staticmethod
    def _permute_pools(plan, pools, gather):
        """Apply a defrag page permutation to every full-space pool leaf
        (defrag compacts the full allocator only; ring pages are exclusive
        and short-lived, so the ring space never fragments across owners
        in a way compaction could improve)."""
        gather = jnp.asarray(gather)
        new_pools = []
        for si, seg in enumerate(plan):
            if seg.window is not None:
                new_pools.append(pools[si])
                continue
            axis = 0 if seg.reps == 1 else 1
            new_pools.append(tuple(
                {k: jnp.take(v, gather, axis=axis) for k, v in pool.items()}
                for pool in pools[si]))
        return new_pools

    # -- serving state ------------------------------------------------------
    def reset(self) -> None:
        """Drop all serving state and start an empty session (jitted
        functions and their compile caches survive across sessions)."""
        lay = self._layout
        ring = None
        if lay.has_ring:
            ring = RingPageSpace(num_slots=self.num_slots,
                                 num_pages=self.ring_pages,
                                 page_size=self.page_size,
                                 max_blocks=self.max_blocks,
                                 window=lay.ring_window)
        self.cache = PagedKVCache(num_slots=self.num_slots,
                                  num_pages=self.num_pages,
                                  page_size=self.page_size,
                                  max_blocks=self.max_blocks,
                                  enable_prefix_cache=self.enable_prefix_cache,
                                  has_full=lay.has_full, ring=ring,
                                  recompute_shared=(lay.has_state
                                                    and lay.has_full))
        self._sched = Scheduler(self.cache, on_release=self._on_release,
                                max_running=self.max_decode_slots)
        self._slots = sampling.SlotSampling(self.num_slots)
        # token-presence rows (repetition penalty): host mirror + device
        # copy threaded through the jitted step
        self._presence_np = np.zeros((self.num_slots, self._vocab), np.bool_)
        self._presence = self._presence_to_device(self._presence_np)
        self._presence_dirty = False
        self._pools = self._pool_model.init_paged_cache(
            self.num_pages, self.page_size, dtype=self.cache_dtype,
            ring_pages=self.ring_pages if lay.has_ring else None)
        self._states = (self._pool_model.init_state_pools(self.num_slots)
                        if lay.has_state else None)
        if self.serve_plan is not None:
            # per-shard pools: each device holds its model-axis slice of
            # every physical page (shared logical page-id space)
            self._pools = jax.device_put(
                self._pools,
                self.serve_plan.pool_shardings(self._pool_model,
                                               cache_dtype=self.cache_dtype))
        if self.spec is not None:
            self._draft_pools = self._draft_pool_model.init_paged_cache(
                self.num_pages, self.page_size, dtype=self.cache_dtype)
            if self._draft_plan is not None:
                self._draft_pools = jax.device_put(
                    self._draft_pools,
                    self._draft_plan.pool_shardings(
                        self._draft_pool_model,
                        cache_dtype=self.cache_dtype))
        self._t0 = time.monotonic()
        self._steps, self._occ_sum = 0, 0.0
        self._n_chunks, self._prefill_tokens = 0, 0
        self._spec_windows, self._spec_drafted, self._spec_accepted = 0, 0, 0
        self._requests: list[Request] = []
        self.defrag_every = 0      # run-scoped; run() re-applies its arg

    def _presence_to_device(self, arr):
        """Host mirror -> device, placement-stable across steps: on a mesh
        the threaded presence comes back replicated over every device, so
        fresh uploads must match that sharding or the second step would
        recompile (the jit cache keys on committed shardings)."""
        if self.serve_plan is not None:
            return jax.device_put(
                arr, jax.sharding.NamedSharding(self.serve_plan.mesh, P()))
        return jnp.asarray(arr)

    def _on_release(self, slot: int) -> None:
        self._slots.clear(slot)
        self._presence_np[slot] = False
        self._presence_dirty = True

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def has_unfinished(self) -> bool:
        return self._sched is not None and self._sched.has_work()

    def kv_token_bytes_per_device(self) -> int:
        """Physical pool bytes one cached token costs per device (the
        strong-scaling observable: sharded leaves divide by TP).  Measured
        from the actual pool dtype, so quantized fp8/int8 pools report
        packed codes + scale-metadata bytes."""
        from repro.parallel.plan import paged_kv_token_bytes
        return paged_kv_token_bytes(
            self.model, tp=self.serve_plan.tp if self.serve_plan else 1,
            kv_repl=self.serve_plan.kv_repl if self.serve_plan else 1,
            cache_dtype=self.cache_dtype or jnp.bfloat16)

    def add_request(self, req: Request,
                    sampling_params: SamplingParams | None = None) -> None:
        """Submit one request; it enters the slot batch on a later
        ``step()`` once a slot and pages free up (honoring arrival_time)."""
        if self.phase == "decode":
            raise RuntimeError(
                "a decode-phase engine only accepts requests through the "
                "KV handoff; submit to the prefill engine (or the "
                "DisaggServeEngine front)")
        if self._sched is None:
            self.reset()
        if req.sampling is None:
            req.sampling = sampling_params or self.default_sampling
        if req.sampling.max_tokens is not None:
            req.max_new_tokens = min(req.max_new_tokens,
                                     req.sampling.max_tokens)
        if req.sampling.top_k > self.max_top_k:
            raise ValueError(f"request {req.rid}: top_k={req.sampling.top_k} "
                             f"exceeds the engine's static "
                             f"max_top_k={self.max_top_k}")
        # speculative windows scatter KV up to gamma positions past the
        # last emitted token, so a request needs that much page slack on
        # top of its own length
        if (req.prompt_len + req.max_new_tokens + self._gamma
                > self.max_blocks * self.page_size):
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + "
                f"{req.max_new_tokens} new tokens"
                + (f" + gamma {self._gamma}" if self._gamma else "")
                + f" exceeds max_len {self.max_blocks * self.page_size}")
        self._requests.append(req)
        self._sched.submit([req])

    # -- disaggregated handoff seam (prefill phase <-> decode phase) --------
    def handoff_ready(self) -> list[Request]:
        """Requests whose chains are complete and parked for transfer
        (prefill-phase engines only; deterministic rid order)."""
        return self._sched.handoff_ready()

    def admit_handoff(self, req: Request, now: float) -> int | None:
        """Decode-phase admission of a transferred request: binds a slot
        and allocates/shares its page chain (HANDOFF -> RUNNING).  Returns
        the shared-token count — decode-side prefix hits shrink the
        transfer — or None when no slot or pages are free (the chain stays
        parked on the prefill side: backpressure, not an error)."""
        return self._sched.admit_handoff(req, now)

    def extract_pages(self, ids: list[int]) -> tuple[list, int]:
        """Gather the bytes of pool pages ``ids`` to host staging buffers.

        Returns (staged, nbytes): a list of one numpy pool-pytree per pool
        set (target, then draft when speculative) and the exact payload
        byte count.  Page-id lists are padded to a pow-2 bucket (scratch
        page 0) for stable jit shapes; padding bytes are excluded from the
        accounting."""
        n = self._bucket(max(len(ids), 1))
        padded = np.zeros((n,), np.int32)
        padded[:len(ids)] = ids
        idx = jnp.asarray(padded)
        staged = [jax.device_get(self._gather_pages(self._pools, idx))]
        if self.spec is not None:
            staged.append(jax.device_get(
                self._gather_pages_draft(self._draft_pools, idx)))
        nbytes = sum(int(l.nbytes) for l in jax.tree_util.tree_leaves(staged))
        return staged, (nbytes * len(ids)) // n

    def install_pages(self, staged: list, ids: list[int]) -> None:
        """Scatter staged page bytes into this engine's pool pages ``ids``
        (decode-phase write side of the handoff).  The caller guarantees
        ``staged`` came from an engine with identical pool geometry and an
        id list of the same length."""
        n = self._bucket(max(len(ids), 1))
        padded = np.zeros((n,), np.int32)
        padded[:len(ids)] = ids
        idx = jnp.asarray(padded)
        self._pools = self._scatter_pages(self._pools, staged[0], idx)
        if self.spec is not None:
            self._draft_pools = self._scatter_pages_draft(
                self._draft_pools, staged[1], idx)

    def finish_handoff(self, req: Request) -> None:
        """Complete decode-side adoption once the page bytes landed: slot
        sampling state, the presence row (prompt + already-emitted tokens,
        exactly what a colocated engine holds at this point), and the
        decode-side prefix index — transferred chains keep their hashes,
        so they are shareable and CoW-protected like local ones."""
        slot = req.slot
        self._slots.set(slot, req.sampling)
        self._presence_np[slot] = False
        self._presence_np[slot][np.asarray(req.prompt)] = True
        for t in req.tokens:
            self._presence_np[slot, t] = True
        self._presence_dirty = True
        self.cache.index_prompt(slot, req.prompt)

    def release_handoff(self, slot: int) -> None:
        """Free a transferred chain's prefill-side slot (pages shared into
        the prefix index keep their refs)."""
        self._sched.release_handoff(slot)

    # -- host loop ----------------------------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        b = 1
        while b < n:
            b *= 2
        return b

    def _make_output(self, req: Request, new: list[int],
                     finished: bool) -> RequestOutput:
        metrics = {"ttft": req.ttft, "preemptions": req.preemptions,
                   "chunks": req.chunks, "shared_tokens": req.shared_tokens}
        if finished:
            metrics["finish_time"] = req.finish_time
            metrics["tpot"] = req.tpot
        if self.spec is not None:
            metrics["spec_windows"] = req.spec_windows
            metrics["spec_accepted"] = req.spec_accepted
        return RequestOutput(
            rid=req.rid, new_token_ids=list(new),
            token_ids=list(req.tokens) if finished else [],
            finished=finished,
            finish_reason=req.finish_reason if finished else None,
            logprobs=(list(req.logprobs)
                      if finished and req.sampling.logprobs else None),
            prompt_logprobs=(list(req.prompt_logprobs)
                             if finished and req.sampling.prompt_logprobs
                             else None),
            metrics=metrics)

    def _progress(self, req: Request, outs: list[RequestOutput]) -> None:
        """Apply finish reasons on-host and emit the unstreamed delta."""
        reason = req.check_finish()
        if reason is not None:
            req.finish_reason = reason
            self._sched.finish(req, self._now())
        if len(req.tokens) > req.emitted or reason is not None:
            new = req.tokens[req.emitted:]
            req.emitted = len(req.tokens)
            outs.append(self._make_output(req, new,
                                          finished=reason is not None))

    def _run_prefill_chunks(self, outs: list[RequestOutput]) -> None:
        """Advance every PREFILL request by one chunk (one jitted call,
        batched across slots at ragged offsets).

        The chunk width is static (``prefill_chunk``) — size it to the
        workload: around the typical prompt length for low-latency
        admission, smaller to bound the per-iteration prefill slice
        interleaved with decode.  The page-table view is sliced to the
        pow-2 cover of the blocks actually resident after this chunk, so a
        short prompt's chunk never gathers (or attends over) the full
        ``max_blocks`` view; jitted shapes stay bounded by
        O(log2(num_slots) * log2(max_blocks))."""
        sched = self._sched
        pre = sched.prefilling()
        c = self.prefill_chunk
        if self.cache.ring is not None:
            # ring pages back lazily (admission sizes the full space only);
            # grow each slot's ring to this chunk's frontier BEFORE the
            # table snapshot.  ``ring_pages_needed`` sizing makes the
            # all-or-nothing alloc infallible.
            for r in pre:
                n = min(c, r.prompt_len - r.pos)
                if not self.cache.ensure(r.slot, r.pos + n - 1):
                    raise RuntimeError(
                        "ring page pool exhausted during prefill — the "
                        "engine sizes it via ring_pages_needed(), so this "
                        "is an allocator invariant violation")
        bucket = self._bucket(len(pre))
        need = max(-(-(r.pos + min(c, r.prompt_len - r.pos)) // self.page_size)
                   for r in pre)
        nb = min(self._bucket(need), self.max_blocks)
        tokens = np.zeros((bucket, c), np.int32)
        tables = np.zeros((bucket, nb), np.int32)      # pad rows -> scratch
        start = np.zeros((bucket,), np.int32)
        valid = np.zeros((bucket,), np.int32)
        table = self.cache.table()
        rtab = self.cache.ring_table()
        rtables = (np.zeros((bucket, nb), np.int32)
                   if rtab is not None else None)
        slots_ix = (np.zeros((bucket,), np.int32)
                    if self._layout.has_state else None)
        for i, r in enumerate(pre):
            n = min(c, r.prompt_len - r.pos)
            tokens[i, :n] = r.prompt[r.pos:r.pos + n]
            tables[i] = table[r.slot, :nb]
            start[i] = r.pos
            valid[i] = n
            if rtables is not None:
                rtables[i] = rtab[r.slot, :nb]
            if slots_ix is not None:
                slots_ix[i] = r.slot
        samp = sampling.stack_params([r.sampling for r in pre], bucket)
        extras = sampling.stack_extras([r.sampling for r in pre], bucket)
        pres = np.zeros((bucket, self._vocab), np.bool_)
        for i, r in enumerate(pre):
            pres[i] = self._presence_np[r.slot]
        sargs = (jnp.asarray(pres), jnp.asarray(tokens), jnp.asarray(tables),
                 None if rtables is None else jnp.asarray(rtables),
                 None if slots_ix is None else jnp.asarray(slots_ix),
                 jnp.asarray(start), jnp.asarray(valid))
        pargs = (*(jnp.asarray(a) for a in samp),
                 *(jnp.asarray(a) for a in extras))
        scored = any(r.sampling.prompt_logprobs for r in pre)
        plp = None
        if scored:
            # tgt[i, j] = the prompt token position start+j predicts (0-pad
            # past the prompt — those scores are dropped below)
            tgt = np.zeros((bucket, c), np.int32)
            for i, r in enumerate(pre):
                nxt = r.prompt[int(start[i]) + 1:int(start[i]) + int(valid[i]) + 1]
                tgt[i, :len(nxt)] = nxt
            first, lp, plp, self._pools, self._states = self._chunk_scored(
                self.params, self._pools, self._states, *sargs,
                jnp.asarray(tgt), *pargs)
            plp = np.asarray(plp)
        else:
            first, lp, self._pools, self._states = self._chunk(
                self.params, self._pools, self._states, *sargs, *pargs)
        if self.spec is not None:
            # the draft pools take the same chunk (same tables/offsets);
            # speculation is rejected for ring/state layouts, so the ring
            # and slot operands of sargs never reach this path
            self._draft_pools = self._draft_chunk(
                self._draft_params, self._draft_pools, sargs[1], sargs[2],
                sargs[5], sargs[6])
        first = np.asarray(first)                      # device sync
        lp = np.asarray(lp)
        for i, r in enumerate(pre):
            r.chunks += 1
            self._n_chunks += 1
            self._prefill_tokens += int(valid[i])
            if plp is not None and r.sampling.prompt_logprobs:
                # position start+j scores prompt[start+j+1]; the final
                # chunk's last position predicts the FIRST GENERATED token,
                # which is not a prompt logprob — drop it
                n = int(valid[i])
                keep = n - 1 if int(start[i]) + n == r.prompt_len else n
                r.prompt_logprobs.extend(float(x) for x in plp[i, :keep])
            r.pos += int(valid[i])
            # the window slid past whole blocks during this chunk: return
            # their ring pages now (between dispatches, never mid-graph)
            self.cache.reclaim(r.slot, r.pos)
            if r.pos == r.prompt_len:                  # prefill complete
                r.state = RUNNING
                r.tokens.append(int(first[i]))
                self._presence_np[r.slot, int(first[i])] = True
                self._presence_dirty = True
                if r.sampling.logprobs:
                    r.logprobs.append(float(lp[i]))
                if r.first_token_time is None:
                    # a restart re-emits the tokens the client already has
                    # (seeded streams), so a preempted request keeps its
                    # original TTFT
                    r.first_token_time = self._now()
                self.cache.index_prompt(r.slot, r.prompt)
                self._progress(r, outs)
                if self.phase == "prefill" and r.state == RUNNING:
                    # disaggregated: park the finished chain for transfer;
                    # the slot (and its pages) stays held until the decode
                    # engine adopts it
                    r.state = HANDOFF

    def step(self) -> list[RequestOutput]:
        """One scheduler iteration: admit arrived requests, advance every
        prefilling request by one chunk, run one fused decode step over the
        decoding slots.  Returns the ``RequestOutput`` deltas produced this
        iteration (may be empty — e.g. a chunk that completed no prompt).
        Never sleeps; with no work due yet it returns immediately."""
        if self._sched is None:
            return []
        sched = self._sched
        outs: list[RequestOutput] = []
        if self.phase != "decode":
            # a decode-phase engine admits only through admit_handoff();
            # preemption victims drain back to the prefill engine instead
            # of re-entering here
            for r in sched.admit(self._now()):
                self._slots.set(r.slot, r.sampling)
                self._presence_np[r.slot] = False
                self._presence_np[r.slot][np.asarray(r.prompt)] = True
                self._presence_dirty = True
        # -- chunked prefill, interleaved with the decode iterations --
        if sched.prefilling():
            self._run_prefill_chunks(outs)
        if not sched.decoding():
            return outs
        # -- capacity + copy-on-write barrier for the decode writes; a
        # speculative window scatters KV at pos..pos+gamma, so the whole
        # window's pages are backed (and un-shared) before it starts —
        # windows never preempt or allocate midway --
        for req in sched.decoding():
            if sched.running.get(req.slot) is req:  # not yet preempted
                upto = req.pos + self._gamma if self.spec is not None else None
                if sched.ensure_capacity(req, upto=upto):
                    for blk in range(req.pos // self.page_size,
                                     (req.pos + self._gamma)
                                     // self.page_size + 1):
                        moved = self.cache.cow(req.slot, blk)
                        if moved is not None:
                            self._pools = self._copy_page(
                                self._pools, moved[1], moved[0])
                            if self.spec is not None:
                                self._draft_pools = self._copy_page_draft(
                                    self._draft_pools, moved[1], moved[0])
        decoding = sched.decoding()
        if not decoding:
            return outs
        if self.defrag_every and (self._steps + 1) % self.defrag_every == 0:
            gather = self.cache.defrag()
            if gather is not None:
                self._pools = self._permute_pools(self._pool_model.plan,
                                                  self._pools, gather)
                if self.spec is not None:
                    self._draft_pools = self._permute_pools(
                        self._draft_pool_model.plan, self._draft_pools,
                        gather)

        tokens = np.zeros((self.num_slots,), np.int32)
        pos = np.zeros((self.num_slots,), np.int32)
        # slots still prefilling (or free) must not touch live pages:
        # their rows are routed to the scratch page for this step
        step_table = np.zeros_like(self.cache.table())
        rtab = self.cache.ring_table()
        ring_step = None if rtab is None else np.zeros_like(rtab)
        state_ok = (np.zeros((self.num_slots,), np.bool_)
                    if self._layout.has_state else None)
        for req in decoding:
            tokens[req.slot] = req.tokens[-1]
            pos[req.slot] = req.pos
            step_table[req.slot] = self.cache.table()[req.slot]
            if ring_step is not None:
                ring_step[req.slot] = rtab[req.slot]
            if state_ok is not None:
                # non-decoding slots run the step too (fixed batch) but
                # must not commit their garbage recurrent-state update
                state_ok[req.slot] = True
        if self._presence_dirty:       # admissions/releases since last step
            self._presence = self._presence_to_device(self._presence_np)
            self._presence_dirty = False
        if self.spec is not None:
            return self._spec_window(decoding, tokens, pos, step_table, outs)
        nxt, lp, self._pools, self._states, self._presence = self._step_fn(
            self.params, self._pools, self._states, self._presence,
            jnp.asarray(tokens), jnp.asarray(pos), jnp.asarray(step_table),
            None if ring_step is None else jnp.asarray(ring_step),
            None if state_ok is None else jnp.asarray(state_ok),
            *self._slots.arrays())
        nxt = np.asarray(nxt)                          # device sync
        lp = np.asarray(lp)
        self._occ_sum += len(decoding) / self.num_slots
        self._steps += 1
        for req in decoding:
            if sched.running.get(req.slot) is not req:
                continue
            req.tokens.append(int(nxt[req.slot]))
            # mirror the in-step presence update (device already has it)
            self._presence_np[req.slot, int(nxt[req.slot])] = True
            if req.sampling.logprobs:
                req.logprobs.append(float(lp[req.slot]))
            req.pos += 1
            self.cache.reclaim(req.slot, req.pos)
            self._progress(req, outs)
        return outs

    def _spec_window(self, decoding, tokens, pos, step_table,
                     outs: list[RequestOutput]) -> list[RequestOutput]:
        """One draft/verify window over the decoding slots: gamma jitted
        draft steps (one scan) + one jitted multi-token verify, emitting
        1..gamma+1 tokens per slot.  Two compiled programs total — slot
        mix, gamma-window restarts after preemption, and admissions in
        between never retrace."""
        sched = self._sched
        tok_j, pos_j = jnp.asarray(tokens), jnp.asarray(pos)
        tab_j = jnp.asarray(step_table)
        sargs = self._slots.arrays()
        prop, q_dists, self._draft_pools = self._spec_draft(
            self._draft_params, self._draft_pools, self._presence,
            tok_j, pos_j, tab_j, *sargs)
        out, n_emit, lp, self._pools, self._presence = self._spec_verify(
            self.params, self._pools, self._presence, tok_j, prop, q_dists,
            pos_j, tab_j, *sargs)
        out = np.asarray(out)                          # device sync
        n_emit = np.asarray(n_emit)
        lp = np.asarray(lp)
        self._occ_sum += len(decoding) / self.num_slots
        self._steps += 1
        for req in decoding:
            if sched.running.get(req.slot) is not req:
                continue
            n = int(n_emit[req.slot])
            req.spec_windows += 1
            req.spec_accepted += n - 1
            self._spec_windows += 1
            self._spec_drafted += self._gamma
            self._spec_accepted += n - 1
            took = 0
            for j in range(n):
                t = int(out[req.slot, j])
                req.tokens.append(t)
                self._presence_np[req.slot, t] = True
                if req.sampling.logprobs:
                    req.logprobs.append(float(lp[req.slot, j]))
                took += 1
                # stop/length can land mid-window: the tail tokens are
                # never emitted, and the finished slot's presence row
                # resets on release, so the device copy stays consistent
                if req.check_finish() is not None:
                    break
            req.pos += took
            self._progress(req, outs)
        return outs

    def run(self, requests: Iterable[Request], *, key=None,
            defrag_every: int = 0,
            on_output: Callable[[RequestOutput], None] | None = None
            ) -> ContinuousStats:
        """Serve ``requests`` to completion; honors ``arrival_time``.

        ``on_output`` streams every ``RequestOutput`` delta as it is
        produced.  ``key`` is the legacy entropy argument: it only seeds
        requests that carry no ``SamplingParams`` of their own when the
        engine default is stochastic."""
        if self.phase != "colocated":
            raise RuntimeError(
                "phase-split engines are driven by DisaggServeEngine.run(), "
                "not directly")
        if self._sched is not None and self._sched.has_work():
            raise RuntimeError(
                "run() would reset the engine while incrementally-submitted "
                "requests are unfinished; drive step() to completion first")
        self.reset()
        self.defrag_every = defrag_every
        default = None
        if (key is not None and not self.default_sampling.is_greedy
                and self.default_sampling.seed == 0):
            default = dataclasses.replace(self.default_sampling,
                                          seed=_seed_from_key(key))
        requests = list(requests)
        for r in requests:
            self.add_request(r, sampling_params=default)

        sched = self._sched
        while sched.has_work():
            if not sched.running:
                nxt_t = sched.next_arrival()
                if nxt_t is None:
                    break
                time.sleep(max(nxt_t - self._now(), 0.0))
            for o in self.step():
                if on_output is not None:
                    on_output(o)

        results = {r.rid: np.asarray(r.tokens[:r.max_new_tokens], np.int32)
                   for r in requests}
        per_request = {r.rid: {"preemptions": r.preemptions,
                               "chunks": r.chunks,
                               "shared_tokens": r.shared_tokens,
                               "ttft": r.ttft,
                               "tpot": r.tpot,
                               "finish_time": r.finish_time,
                               "spec_windows": r.spec_windows,
                               "spec_accepted": r.spec_accepted}
                       for r in requests}
        outputs = {r.rid: self._make_output(r, [], finished=True)
                   for r in requests}
        return ContinuousStats(
            results=results, steps=self._steps,
            occupancy=self._occ_sum / max(self._steps, 1),
            wall=self._now(),
            preemptions=sum(r.preemptions for r in requests),
            chunks=self._n_chunks,
            prefill_tokens=self._prefill_tokens,
            prompt_tokens=self.cache.lookup_tokens,
            prefix_hit_tokens=self.cache.hit_tokens,
            cow_events=self.cache.cow_events,
            spec_windows=self._spec_windows,
            spec_drafted=self._spec_drafted,
            spec_accepted=self._spec_accepted,
            per_request=per_request,
            outputs=outputs)


class KVHandoff:
    """KV-page transfer channel between a prefill-phase and a decode-phase
    engine.

    ``transfer`` moves one finished chain: admit on the decode side (slot +
    fresh/shared pages in ITS allocator's id space), gather the
    non-shared source pages to host staging, scatter them into the decode
    pools (all pool leaves — quantized-KV scale leaves and speculative
    draft pools travel with the chain), then release the prefill slot.
    Decode-side prefix hits skip the matched leading pages entirely —
    the same chained hashes index both sides, so a transferred chain lands
    in the decode prefix index and later requests with the same prefix
    transfer only their tail.  Byte accounting is exact (padding pages for
    the pow-2 jit buckets are excluded).

    Single-host staging (device -> host -> device); a multi-host transport
    and transfer/decode overlap are recorded follow-ons (ROADMAP).
    """

    def __init__(self, src: "ContinuousServeEngine",
                 dst: "ContinuousServeEngine"):
        for attr in ("page_size", "max_blocks", "cache_dtype"):
            a, b = getattr(src, attr), getattr(dst, attr)
            if a != b:
                raise ValueError(
                    f"handoff geometry mismatch: {attr}={a!r} on the "
                    f"prefill side vs {b!r} on the decode side")
        if (src.spec is None) != (dst.spec is None):
            raise ValueError(
                "speculative decoding must be on for both sides of a "
                "handoff (draft pools travel with the chain) or neither")
        src_repl = src.serve_plan.kv_repl if src.serve_plan else 1
        dst_repl = dst.serve_plan.kv_repl if dst.serve_plan else 1
        if src_repl != dst_repl:
            raise ValueError(
                f"handoff across kv_repl {src_repl} vs {dst_repl} meshes "
                f"needs a head-regrouping repack (recorded follow-on)")
        self.src = src
        self.dst = dst
        self.reset_counters()

    def reset_counters(self) -> None:
        self.transfers = 0
        self.pages_moved = 0
        self.bytes_moved = 0
        self.shared_tokens = 0
        self.deferrals = 0

    def transfer(self, req: Request, now: float) -> bool:
        """Move ``req``'s chain into the decode engine; False when the
        decode side has no capacity yet (the chain stays parked)."""
        src, dst = self.src, self.dst
        src_slot = req.slot
        src_chain = src.cache.chain(src_slot, req.prompt_len)
        shared = dst.admit_handoff(req, now)
        if shared is None:
            self.deferrals += 1
            return False
        dst_chain = dst.cache.chain(req.slot, req.prompt_len)
        skip = shared // src.page_size   # matched prefix pages: no copy
        ids_src, ids_dst = src_chain[skip:], dst_chain[skip:]
        self.shared_tokens += shared
        if ids_src:
            staged, nbytes = src.extract_pages(ids_src)
            dst.install_pages(staged, ids_dst)
            self.pages_moved += len(ids_src)
            self.bytes_moved += nbytes
        dst.finish_handoff(req)
        src.release_handoff(src_slot)
        self.transfers += 1
        return True


class DisaggServeEngine:
    """Disaggregated serving: a prefill-phase and a decode-phase
    ``ContinuousServeEngine`` joined by a :class:`KVHandoff`.

    Prompts are chunk-prefilled on the prefill engine (its own mesh or
    mesh slice, its own pool budget), then the finished page chain moves
    through the handoff into the decode engine, which runs pure fused
    decode steps — no prefill chunks stealing decode iterations, so TPOT
    is flat under prompt bursts and TTFT never queues behind a full decode
    batch (the paper's compute-bound/bandwidth-bound phase split made
    structural).  Greedy outputs are byte-identical to a colocated engine:
    seeded per-request sampling streams are keyed by absolute position,
    the transferred bytes are exact, and decode-side preemption drains
    back to the prefill engine for a seeded re-prefill restart.

    Same incremental surface as ``ContinuousServeEngine`` —
    ``add_request()`` / ``step()`` / ``run()`` — with one merged
    ``ContinuousStats`` (handoff counters filled in).
    """

    def __init__(self, model: Model, params: Any, *, spec=None,
                 prefill_mesh=None, decode_mesh=None,
                 num_slots: int | None = None, page_size: int | None = None,
                 num_pages: int | None = None, max_len: int | None = None,
                 prefill_slots: int | None = None,
                 prefill_pages: int | None = None,
                 prefill_chunk: int | None = None,
                 sampling_params: SamplingParams | None = None,
                 cache_dtype=None, weight_format: str | None = None,
                 enable_prefix_cache: bool = True,
                 max_top_k: int = sampling.MAX_TOP_K,
                 tp_reduce: str = "auto",
                 max_decode_slots: int | None = None,
                 speculative: SpeculativeConfig | None = None):
        common = dict(spec=spec, page_size=page_size, max_len=max_len,
                      sampling_params=sampling_params,
                      cache_dtype=cache_dtype, weight_format=weight_format,
                      enable_prefix_cache=enable_prefix_cache,
                      max_top_k=max_top_k, tp_reduce=tp_reduce,
                      speculative=speculative)
        # each phase resolves its own deployment budget (phase=) — the
        # prefill side may size fewer slots and pages than decode, and a
        # different mesh (TP degree) per phase is allowed as long as the
        # pool geometry matches (KVHandoff checks)
        self.prefill = ContinuousServeEngine(
            model, params, phase="prefill", mesh=prefill_mesh,
            num_slots=prefill_slots if prefill_slots is not None
            else num_slots,
            num_pages=prefill_pages if prefill_pages is not None
            else num_pages,
            prefill_chunk=prefill_chunk, **common)
        self.decode = ContinuousServeEngine(
            model, params, phase="decode", mesh=decode_mesh,
            num_slots=num_slots, num_pages=num_pages,
            max_decode_slots=max_decode_slots, **common)
        self.handoff = KVHandoff(self.prefill, self.decode)
        self.model = model
        self.default_sampling = self.decode.default_sampling
        self._requests: list[Request] = []

    # the decode side is the steady-state resident (the LLM facade's
    # introspection points: budget, plan, per-token pool bytes)
    @property
    def deployment(self):
        return self.decode.deployment

    @property
    def serve_plan(self):
        return self.decode.serve_plan

    @property
    def num_slots(self) -> int:
        return self.decode.num_slots

    def kv_token_bytes_per_device(self) -> int:
        return self.decode.kv_token_bytes_per_device()

    def reset(self) -> None:
        self.prefill.reset()
        self.decode.reset()
        # one clock across both phases: TTFT stamps on the prefill side
        # and finish stamps on the decode side share an origin
        self.decode._t0 = self.prefill._t0
        self.handoff.reset_counters()
        self._requests = []

    def has_unfinished(self) -> bool:
        return self.prefill.has_unfinished() or self.decode.has_unfinished()

    def add_request(self, req: Request,
                    sampling_params: SamplingParams | None = None) -> None:
        if self.prefill._sched is None or self.decode._sched is None:
            self.reset()
        self.prefill.add_request(req, sampling_params)
        self._requests.append(req)

    def step(self) -> list[RequestOutput]:
        """One disaggregated iteration: prefill chunks, then chain
        transfers (in rid order, stopping at decode backpressure), then
        one fused decode step, then decode-side preemption drain back to
        the prefill queue."""
        outs = self.prefill.step()
        now = self.prefill._now()
        for r in self.prefill.handoff_ready():
            if not self.handoff.transfer(r, now):
                break               # decode side full; chain stays parked
        outs += self.decode.step()
        for r in self.decode._sched.drain_preempted():
            # a decode-side eviction restarts on the PREFILL engine — the
            # chain is recomputed there and handed off again; seeded
            # streams and the emitted watermark make the restart invisible
            self.prefill._sched.requeue(r)
        return outs

    def run(self, requests: Iterable[Request], *, key=None,
            defrag_every: int = 0,
            on_output: Callable[[RequestOutput], None] | None = None
            ) -> ContinuousStats:
        """Serve ``requests`` to completion across both engines; same
        contract as ``ContinuousServeEngine.run``."""
        if self.has_unfinished():
            raise RuntimeError(
                "run() would reset the engines while incrementally-"
                "submitted requests are unfinished; drive step() to "
                "completion first")
        self.reset()
        self.decode.defrag_every = defrag_every
        default = None
        if (key is not None and not self.default_sampling.is_greedy
                and self.default_sampling.seed == 0):
            default = dataclasses.replace(self.default_sampling,
                                          seed=_seed_from_key(key))
        requests = list(requests)
        for r in requests:
            self.add_request(r, sampling_params=default)
        pe, de = self.prefill._sched, self.decode._sched
        while pe.has_work() or de.has_work():
            if not pe.running and not de.running:
                nxt_t = pe.next_arrival()
                if nxt_t is None:
                    break
                time.sleep(max(nxt_t - self.prefill._now(), 0.0))
            for o in self.step():
                if on_output is not None:
                    on_output(o)

        results = {r.rid: np.asarray(r.tokens[:r.max_new_tokens], np.int32)
                   for r in requests}
        per_request = {r.rid: {"preemptions": r.preemptions,
                               "chunks": r.chunks,
                               "shared_tokens": r.shared_tokens,
                               "ttft": r.ttft,
                               "tpot": r.tpot,
                               "finish_time": r.finish_time,
                               "spec_windows": r.spec_windows,
                               "spec_accepted": r.spec_accepted}
                       for r in requests}
        outputs = {r.rid: self.decode._make_output(r, [], finished=True)
                   for r in requests}
        pf, dc, ho = self.prefill, self.decode, self.handoff
        return ContinuousStats(
            results=results, steps=dc._steps,
            occupancy=dc._occ_sum / max(dc._steps, 1),
            wall=pf._now(),
            preemptions=sum(r.preemptions for r in requests),
            chunks=pf._n_chunks,
            prefill_tokens=pf._prefill_tokens,
            prompt_tokens=pf.cache.lookup_tokens,
            prefix_hit_tokens=pf.cache.hit_tokens,
            cow_events=pf.cache.cow_events + dc.cache.cow_events,
            spec_windows=dc._spec_windows,
            spec_drafted=dc._spec_drafted,
            spec_accepted=dc._spec_accepted,
            handoffs=ho.transfers,
            handoff_pages=ho.pages_moved,
            handoff_bytes=ho.bytes_moved,
            handoff_shared_tokens=ho.shared_tokens,
            per_request=per_request,
            outputs=outputs)


def serve_step_fn(model: Model):
    """The bare decode step (one token, KV cache) — the function the
    dry-run lowers for ``decode_*`` / ``long_*`` shapes."""

    def serve_step(params, tokens, cache, cur_pos):
        logits, new_cache = model.decode_step(params, tokens, cache, cur_pos)
        return sampling.greedy(logits), new_cache

    return serve_step


def prefill_step_fn(model: Model):
    """Forward over the full prompt — lowered for ``prefill_*`` shapes."""

    def prefill_step(params, batch):
        return model.forward(params, batch)

    return prefill_step
