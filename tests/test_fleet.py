"""Fleet subsystem tests: traffic generators, prefix-affinity routing,
the discrete-event simulator, autoscaler planning — plus the satellite
gates (latency quantiles, MLA quantized-KV rejection up front, bursty
MMPP scheduler invariants, sim-vs-real cross-check)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.fleet import traffic as tr
from repro.fleet.autoscaler import (ReactiveAutoscaler, TrafficEnvelope,
                                    default_candidates, plan_candidate,
                                    plan_fleet)
from repro.fleet.router import (SLO, PrefixAffinityRouter, RoundRobinRouter)
from repro.fleet.simulator import (FleetSimulator, LatencyTable, ReplicaSpec,
                                   cross_check)
from repro.launch.fleet import gate_table, gate_workload
from repro.models.model import build_model
from repro.runtime.deployment import DeploymentError, DeploymentSpec
from repro.runtime.engine import ContinuousServeEngine, ContinuousStats
from repro.runtime.scheduler import Request


# ---------------------------------------------------------------------------
# traffic
# ---------------------------------------------------------------------------


def test_trace_deterministic_and_arrival_kinds():
    for kind in tr.ARRIVAL_KINDS:
        a = tr.make_trace(200, 5, kind=kind, rate=50.0)
        b = tr.make_trace(200, 5, kind=kind, rate=50.0)
        assert a.requests == b.requests            # frozen dataclass equality
        arr = np.asarray([r.arrival for r in a.requests])
        assert np.all(np.diff(arr) >= 0) and arr[0] > 0
        # every prompt leaves at least one unique token past the prefix
        assert all(r.prompt_len > r.prefix_len for r in a.requests)
    with pytest.raises(ValueError, match="unknown arrival kind"):
        tr.make_trace(4, 0, kind="lunar")


def test_mmpp_is_burstier_than_poisson():
    """The MMPP trace's windowed peak-to-mean ratio dominates Poisson's —
    the property that makes it the adversarial admission workload."""
    def peak_over_mean(kind, **kw):
        t = tr.make_trace(2000, 9, kind=kind, rate=100.0, **kw)
        env = TrafficEnvelope.from_trace(t, window_s=1.0)
        return env.peak_rate / env.mean_rate
    assert peak_over_mean("mmpp", burst_ratio=10.0, mean_dwell_s=1.0) \
        > peak_over_mean("poisson") * 1.5


def test_materialized_prompts_share_tenant_prefix():
    trace = tr.make_trace(40, 11, kind="poisson", rate=20.0,
                          tenants=tr.TenantMix(n_tenants=3, prefix_len=32))
    by_tenant = {}
    for r in trace.requests:
        toks = tr.materialize_prompt(trace, r)
        assert toks.shape == (r.prompt_len,)
        by_tenant.setdefault(r.tenant, []).append(toks)
    seen = {}
    for t, prompts in by_tenant.items():
        for p in prompts:
            np.testing.assert_array_equal(p[:32], prompts[0][:32])
        seen[t] = prompts[0][:32]
    ts = list(seen)
    if len(ts) >= 2:       # tenants own distinct prefixes
        assert not np.array_equal(seen[ts[0]], seen[ts[1]])


def test_prefix_chain_matches_tenant_chain():
    """A request's leading full-block hashes equal its tenant's shared
    chain (same ``_chain_key`` chaining the paged KV cache indexes by)."""
    trace = tr.make_trace(8, 3, kind="poisson", rate=10.0,
                          tenants=tr.TenantMix(n_tenants=2, prefix_len=32))
    chains = tr.tenant_chains(trace, page_size=16)
    assert all(len(c) == 2 for c in chains.values())
    for r in trace.requests:
        full = tr.prefix_chain(tr.materialize_prompt(trace, r), 16)
        assert full[:2] == chains[r.tenant]


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------


class FakeReplica:
    def __init__(self, *, hit=0, load_=0.0, sat=False, ttft=0.01,
                 tpot=0.001):
        self._hit, self._load, self._sat = hit, load_, sat
        self._ttft, self._tpot = ttft, tpot

    def queue_depth(self):
        return int(self._load * 8)

    def load(self):
        return self._load

    def saturated(self):
        return self._sat

    def match_tokens(self, chain):
        return self._hit

    def predicted_ttft(self, now, prompt_len, hit_tokens):
        return self._ttft

    def predicted_tpot(self):
        return self._tpot


def test_router_prefers_affinity_then_load():
    r = PrefixAffinityRouter()
    reps = [FakeReplica(hit=0, load_=0.1), FakeReplica(hit=96, load_=0.5)]
    d = r.route(0.0, 128, (), reps)
    assert d.action == "admit" and d.replica == 1 and d.hit_tokens == 96
    # load dominates when the hit advantage is small
    reps = [FakeReplica(hit=0, load_=0.0), FakeReplica(hit=16, load_=1.0)]
    assert r.route(0.0, 128, (), reps).replica == 0


def test_router_sheds_on_predicted_slo_violation():
    r = PrefixAffinityRouter(slo=SLO(ttft_s=0.01, tpot_s=0.001))
    d = r.route(0.0, 128, (), [FakeReplica(ttft=0.5), FakeReplica(ttft=0.9)])
    assert d.action == "shed" and "SLO" in d.reason
    assert r.shed == 1 and r.admitted == 0


def test_router_retries_then_sheds_when_saturated():
    r = PrefixAffinityRouter(max_retries=2, retry_backoff_s=0.05)
    reps = [FakeReplica(sat=True)]
    d0 = r.route(0.0, 64, (), reps, retries=0)
    d1 = r.route(0.0, 64, (), reps, retries=1)
    assert d0.action == d1.action == "retry"
    assert d1.delay_s == pytest.approx(2 * d0.delay_s)     # exponential
    assert r.route(0.0, 64, (), reps, retries=2).action == "shed"


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------


def _sim(router, n_replicas=4, seed=7, n=600):
    trace = gate_workload(n, seed, "diurnal", 100.0)
    spec = ReplicaSpec(latency=gate_table(), num_slots=8, max_queue=16,
                       page_size=16, prefix_blocks=24)
    return FleetSimulator(spec, n_replicas, router).run(trace), trace


def test_simulator_conservation_and_determinism():
    slo = SLO(ttft_s=0.025, tpot_s=0.012)
    fs, trace = _sim(PrefixAffinityRouter(slo=slo))
    assert len(fs.served) + len(fs.shed) == len(trace.requests)
    # token conservation: every served request emitted its full output
    assert all(sr.emitted == sr.req.output_len for sr in fs.served)
    assert all(sr.first_tok_t is not None and sr.finish_t >= sr.first_tok_t
               for sr in fs.served)
    fs2, _ = _sim(PrefixAffinityRouter(slo=slo))
    assert [(s.req.rid, s.first_tok_t, s.finish_t) for s in fs.served] \
        == [(s.req.rid, s.first_tok_t, s.finish_t) for s in fs2.served]


def test_affinity_beats_round_robin_on_shared_prefix_workload():
    """The tentpole acceptance gate, small edition: affinity wins BOTH
    goodput and p95 TTFT when replica prefix capacity is scarce."""
    slo = SLO(ttft_s=0.025, tpot_s=0.012)
    aff, _ = _sim(PrefixAffinityRouter(slo=slo))
    rr, _ = _sim(RoundRobinRouter(slo=slo))
    assert aff.goodput_tokens_per_s(slo) > rr.goodput_tokens_per_s(slo)
    assert aff.ttft_quantiles()["p95"] < rr.ttft_quantiles()["p95"]
    assert aff.slo_attainment(slo) > rr.slo_attainment(slo)


def test_reactive_autoscaler_adds_replicas_under_load():
    trace = gate_workload(600, 7, "mmpp", 150.0)
    spec = ReplicaSpec(latency=gate_table(), num_slots=8, max_queue=8,
                       page_size=16, prefix_blocks=24)
    scaler = ReactiveAutoscaler(min_replicas=1, max_replicas=8,
                                interval_s=0.2)
    sim = FleetSimulator(spec, 1, PrefixAffinityRouter(), autoscaler=scaler)
    fs = sim.run(trace)
    assert scaler.decisions, "autoscaler never reacted to the burst"
    assert max(n for _, n in scaler.decisions) > 1
    assert len(fs.served) + len(fs.shed) == 600


# ---------------------------------------------------------------------------
# autoscaler planning
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen_full():
    return build_model(get_config("qwen3-14b"))


def _envelope():
    lengths = tr.LengthMix(prompt_mean=512.0, prompt_min=64, prompt_max=1024,
                           output_mean=256.0, output_min=32, output_max=512)
    t = tr.make_trace(400, 0, kind="diurnal", rate=200.0, lengths=lengths)
    return TrafficEnvelope.from_trace(t)


def test_envelope_peak_dominates_mean():
    env = _envelope()
    assert env.peak_rate >= env.mean_rate > 0
    assert env.peak_decode_tokens_per_s == \
        pytest.approx(env.peak_rate * env.mean_output)


def test_plan_fleet_rpu_beats_fixed_gpu_baseline(qwen_full):
    """The autoscaler acceptance gate: the chosen (SKU, replicas) meets
    the SLO at lower modeled die cost AND J/token than a fixed h200
    fleet sized for the same envelope."""
    env = _envelope()
    slo = SLO(ttft_s=2.0, tpot_s=0.05)
    base = DeploymentSpec(max_len=2048, weight_format="mxfp4",
                          cache_dtype="fp8", max_slots=32)
    best, plans = plan_fleet(qwen_full, env, slo,
                             default_candidates(qwen_full, base))
    assert best.feasible and best.replicas >= 1
    assert best.tpot_est_s <= slo.tpot_s and best.ttft_est_s <= slo.ttft_s
    assert best.fleet_tokens_per_s >= env.peak_decode_tokens_per_s
    baseline = plan_candidate(
        qwen_full, dataclasses.replace(base, sku="h200", hbmco=None),
        env, slo)
    assert baseline.feasible
    assert best.die_mm2 < baseline.die_mm2
    assert best.energy_j_per_token < baseline.energy_j_per_token
    # energy objective picks something no worse on J/token
    e_best, _ = plan_fleet(qwen_full, env, slo,
                           default_candidates(qwen_full, base),
                           objective="energy")
    assert e_best.energy_j_per_token <= best.energy_j_per_token


def test_plan_fleet_raises_when_no_candidate_meets_slo(qwen_full):
    env = _envelope()
    impossible = SLO(ttft_s=1e-6, tpot_s=1e-9)
    base = DeploymentSpec(max_len=2048, weight_format="mxfp4",
                          cache_dtype="fp8", max_slots=32)
    with pytest.raises(DeploymentError, match="no candidate meets the SLO"):
        plan_fleet(qwen_full, env, impossible,
                   default_candidates(qwen_full, base))


# ---------------------------------------------------------------------------
# satellite: TTFT/TPOT quantiles on ContinuousStats
# ---------------------------------------------------------------------------


def test_latency_quantiles_ttft_and_tpot():
    per = {i: {"ttft": 0.01 * (i + 1), "tpot": 0.002} for i in range(10)}
    per[10] = {"ttft": 0.5, "tpot": None}          # single-token: no TPOT
    st = ContinuousStats(results={}, steps=0, occupancy=0.0, wall=1.0,
                         preemptions=0, per_request=per)
    q = st.latency_quantiles("ttft")
    assert q["p50"] == pytest.approx(0.06)
    assert q["p99"] == pytest.approx(0.5)
    t = st.latency_quantiles("tpot")               # None entries skipped
    assert t["p50"] == t["p99"] == pytest.approx(0.002)
    empty = ContinuousStats(results={}, steps=0, occupancy=0.0, wall=1.0,
                            preemptions=0)
    assert empty.latency_quantiles("ttft") is None


def test_request_tpot_property():
    r = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=8)
    assert r.tpot is None
    r.first_token_time, r.finish_time = 1.0, 2.0
    r.tokens = [5, 6, 7, 8, 9]
    assert r.tpot == pytest.approx(0.25)
    r.tokens = [5]                                 # single token: undefined
    assert r.tpot is None


# ---------------------------------------------------------------------------
# satellite: MLA + quantized KV rejected up front
# ---------------------------------------------------------------------------


def test_mla_page_pool_rejects_quantized_cache_dtype():
    from repro.models.attention_backends import init_mla_page_pool
    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    with pytest.raises(NotImplementedError) as ei:
        init_mla_page_pool(cfg, num_pages=4, page_size=8, dtype="fp8")
    msg = str(ei.value)
    assert "fp8" in msg and "GQA" in msg and "bfloat16" in msg


def test_deployment_resolve_rejects_mla_with_quantized_kv():
    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    assert cfg.mla
    model = build_model(cfg)
    for fmt in ("fp8", "int8"):
        spec = DeploymentSpec(max_len=128, max_slots=2, cache_dtype=fmt)
        with pytest.raises(DeploymentError) as ei:
            spec.resolve(model)
        assert fmt in str(ei.value) and "MLA" in str(ei.value)
    # dense cache dtypes still resolve for the same model
    DeploymentSpec(max_len=128, max_slots=2,
                   cache_dtype=jnp.float32).resolve(model)


# ---------------------------------------------------------------------------
# satellite: scheduler under bursty MMPP arrivals
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small():
    cfg = reduced_config(get_config("qwen3-14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _fleet_requests(trace, arrival=True):
    return [Request(rid=r.rid, prompt=tr.materialize_prompt(trace, r),
                    max_new_tokens=r.output_len,
                    arrival_time=r.arrival if arrival else 0.0)
            for r in trace.requests]


def test_bursty_mmpp_arrivals_scheduler_invariants(small):
    """Satellite 3: an MMPP arrival storm against a tight page pool with
    a ``max_running`` admission hint — the engine must not livelock, the
    allocator's ref-count invariants must hold across the preemption
    churn, and greedy outputs must be byte-identical to a quiet run of
    the same requests (arrivals and preemption are invisible in the
    output stream)."""
    cfg, model, params = small
    lengths = tr.LengthMix(prompt_mean=10.0, prompt_sigma=0.3, prompt_min=6,
                           prompt_max=14, output_mean=6.0, output_min=3,
                           output_max=8)
    # compressed-timescale storm: ~300 req/s bursts over ~60ms
    trace = tr.make_trace(12, 3, kind="mmpp", rate=300.0,
                          vocab=cfg.vocab_size, lengths=lengths,
                          tenants=tr.TenantMix(n_tenants=2, prefix_len=4),
                          burst_ratio=10.0, mean_dwell_s=0.02)
    eng = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                num_pages=14, max_len=24,
                                max_decode_slots=2)       # max_running hint
    for r in _fleet_requests(trace):
        eng.add_request(r)
    assert eng._sched.max_running == 2
    finished, steps = {}, 0
    while eng.has_unfinished():
        outs = eng.step()
        eng.cache.allocator.check()           # ref-count invariants hold
        # the admission hint is respected every iteration
        assert len(eng._sched.running) <= 2
        for o in outs:
            if o.finished:
                finished[o.rid] = np.asarray(o.token_ids, np.int32)
        steps += 1
        assert steps < 2000, "livelock: storm never drains"
    assert sorted(finished) == [r.rid for r in trace.requests]

    quiet = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                  num_pages=14, max_len=24,
                                  max_decode_slots=2)
    ref = quiet.run(_fleet_requests(trace, arrival=False))
    for rid, toks in ref.results.items():
        np.testing.assert_array_equal(finished[rid], toks)


# ---------------------------------------------------------------------------
# sim vs real cross-check
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_cross_check_sim_matches_real_engine_throughput():
    """Calibrate the simulator from a real engine's measured step
    latencies, replay one trace through both, and assert end-to-end
    throughput agrees within the stated +-40% tolerance."""
    from repro.models.common import ModelConfig
    cfg = ModelConfig(name="fleet-xcheck", family="dense", n_layers=4,
                      d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
                      d_ff=512, vocab_size=1024)
    model = build_model(cfg)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)))
    max_len = 160
    eng = ContinuousServeEngine(
        model, params, num_slots=8, page_size=16,
        num_pages=1 + 8 * 2 * (max_len // 16), max_len=max_len,
        cache_dtype=jnp.float32, prefill_chunk=32,
        enable_prefix_cache=False)
    lengths = tr.LengthMix(prompt_mean=48.0, prompt_min=16, prompt_max=96,
                           output_mean=16.0, output_min=4, output_max=32)
    trace = tr.make_trace(30, 0, kind="poisson", rate=30.0,
                          vocab=cfg.vocab_size, lengths=lengths,
                          tenants=tr.TenantMix(n_tenants=1, prefix_len=0))
    res = cross_check(eng, trace)
    assert res["real_tokens"] == res["sim_tokens"]
    assert 0.7 <= res["throughput_ratio"] <= 1.4, res
