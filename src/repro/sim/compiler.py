"""Compiler: ModelConfig -> RPU instruction streams (paper §VI).

Lowers one **decode step** (the latency-critical path the paper optimizes)
into per-layer phase streams, following the paper's Fig 8 layer anatomy:

  wQKV VMM   — weight streaming, gated by the activation ring-broadcast
  SDPA       — KV$ streaming (query-unique => batch-scaled), gated by the
               Q/KV head gather + softmax max/expsum reductions
  wO VMM     — output projection (column-sharded: fragments stay distributed)
  MLP / MoE  — wUp/wGate (+ routed experts), gated by activation broadcast
  SSM        — state update (mamba/hybrid): weights + state read/write

All quantities are **per CU** under the paper's fine-grained sharding
(weights column-sharded across all CUs; KV$ sharded across CUs).
Deployment dtypes follow the paper: MXFP4 weights (4.25 b/elem incl.
scales), FP8 KV$, BF16 activations.
"""
from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig
from repro.models.footprint import (
    _attn_params, _mla_params, _mlp_params, _moe_params, _ssm_params,
)
from repro.models.model import build_plan
from repro.sim.isa import LayerProgram, Phase, Program

WEIGHT_BYTES = 4.25 / 8.0      # MXFP4 + E8M0 scales
KV_BYTES = 1.0                 # FP8 KV$
ACT_BYTES = 2.0                # BF16 activations


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    n_cus: int = 64
    batch: int = 1
    seq_len: int = 8192
    weight_bytes: float = WEIGHT_BYTES
    kv_bytes: float = KV_BYTES
    act_bytes: float = ACT_BYTES


def _unique_experts(e: int, k: int, tokens: int) -> float:
    """Expected number of distinct experts activated by ``tokens`` top-k
    draws (uniform routing assumption)."""
    if e == 0:
        return 0.0
    return e * (1.0 - (1.0 - min(k / e, 1.0)) ** tokens)


def _ring_hops(c: int, cus_per_package: int = 4) -> int:
    """Ring-broadcast hop count on the hierarchical topology (paper §IV):
    short UCIe hops within a 4-CU package, then the package-level outer
    ring via ring stations — so a full broadcast traverses
    (packages + in-package) hops, not one hop per CU."""
    import math
    return max(1, math.ceil(c / cus_per_package)) + min(c, cus_per_package)


def _attn_phases(cfg: ModelConfig, o: CompileOptions, window) -> list[Phase]:
    c, b = o.n_cus, o.batch
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s_eff = min(o.seq_len, window) if window else o.seq_len
    qkv_p = d * h * hd + 2 * d * kvh * hd
    o_p = h * hd * d
    kv_read = 2 * kvh * hd * s_eff * b * o.kv_bytes
    sdpa_flops = 2 * 2 * h * hd * s_eff * b           # QK^T + PV
    bcast_bytes = b * d * o.act_bytes
    gather_bytes = b * (h + 2 * kvh) * hd * o.act_bytes / c
    return [
        Phase("wqkv", mem_bytes=qkv_p * o.weight_bytes / c,
              flops=2 * qkv_p * b / c,
              net_bytes=bcast_bytes, net_hops=_ring_hops(c), overlap_net=True,
              kind="vmm"),
        Phase("sdpa", mem_bytes=kv_read / c + 2 * kvh * hd * b * o.kv_bytes / c,
              flops=sdpa_flops / c,
              net_bytes=gather_bytes * 3,
              net_hops=3 * _ring_hops(max(1, c // max(1, kvh))), kind="sdpa"),
        Phase("wo", mem_bytes=o_p * o.weight_bytes / c,
              flops=2 * o_p * b / c, kind="vmm"),
    ]


def _mla_phases(cfg: ModelConfig, o: CompileOptions) -> list[Phase]:
    c, b = o.n_cus, o.batch
    d, h = cfg.d_model, cfg.n_heads
    hd, rhd, vhd, r = cfg.hd, cfg.rope_head_dim, cfg.v_hd, cfg.kv_lora_rank
    p_total = _mla_params(cfg)
    kv_read = (r + rhd) * o.seq_len * b * o.kv_bytes
    # absorbed-latent attention: q_lat (H, r) . c_kv (S, r) + ctx expansion
    sdpa_flops = 2 * h * (r + rhd) * o.seq_len * b + 2 * h * r * vhd * b
    bcast_bytes = b * d * o.act_bytes
    return [
        Phase("mla_proj", mem_bytes=p_total * o.weight_bytes / c,
              flops=2 * p_total * b / c,
              net_bytes=bcast_bytes, net_hops=_ring_hops(c), overlap_net=True,
              kind="vmm"),
        Phase("mla_sdpa", mem_bytes=kv_read / c,
              flops=sdpa_flops / c,
              net_bytes=b * h * (r + rhd) * o.act_bytes / c * 3,
              net_hops=3 * _ring_hops(max(1, c // max(1, h))), kind="sdpa"),
    ]


def _mlp_phases(cfg: ModelConfig, o: CompileOptions, d_ff: int) -> list[Phase]:
    c, b, d = o.n_cus, o.batch, cfg.d_model
    up = 2 * d * d_ff
    down = d_ff * d
    bcast_bytes = b * d * o.act_bytes
    return [
        Phase("wupgate", mem_bytes=up * o.weight_bytes / c,
              flops=2 * up * b / c,
              net_bytes=bcast_bytes, net_hops=_ring_hops(c), overlap_net=True,
              kind="vmm"),
        Phase("wdown", mem_bytes=down * o.weight_bytes / c,
              flops=2 * down * b / c, kind="vmm"),
    ]


def _moe_phases(cfg: ModelConfig, o: CompileOptions) -> list[Phase]:
    c, b, d = o.n_cus, o.batch, cfg.d_model
    fe = cfg.moe_d_ff or cfg.d_ff
    e, k = cfg.n_experts, cfg.n_experts_per_token
    phases: list[Phase] = []
    bcast_bytes = b * d * o.act_bytes
    if cfg.n_shared_experts:
        sh = 3 * d * fe * cfg.n_shared_experts
        phases.append(Phase("moe_shared", mem_bytes=sh * o.weight_bytes / c,
                            flops=2 * sh * b / c,
                            net_bytes=bcast_bytes, net_hops=_ring_hops(c),
                            overlap_net=True, kind="vmm"))
    uniq = _unique_experts(e, k, b)
    exp_w = uniq * 3 * d * fe                      # streamed expert weights
    exp_f = 2 * k * 3 * d * fe * b                 # routed compute
    phases.append(Phase("moe_experts", mem_bytes=exp_w * o.weight_bytes / c,
                        flops=exp_f / c,
                        net_bytes=b * d * o.act_bytes, net_hops=_ring_hops(c),
                        overlap_net=True, kind="moe"))
    return phases


def _ssm_phases(cfg: ModelConfig, o: CompileOptions) -> list[Phase]:
    c, b = o.n_cus, o.batch
    p_total = _ssm_params(cfg)
    h, pd, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    state_elems = h * pd * n
    state_rw = 2 * state_elems * 4.0 * b           # f32 state read+write
    upd_flops = 6 * state_elems * b
    return [
        Phase("ssm", mem_bytes=(p_total * o.weight_bytes + state_rw) / c,
              flops=(2 * p_total * b + upd_flops) / c,
              net_bytes=b * cfg.d_model * o.act_bytes, net_hops=_ring_hops(c),
              overlap_net=True, kind="vmm"),
    ]


def compile_decode_step(cfg: ModelConfig, opts: CompileOptions) -> Program:
    """Lower one decode step to the per-CU phase program."""
    layers: list[LayerProgram] = []
    for seg in build_plan(cfg):
        seg_phases: list[Phase] = []
        for kind in seg.kinds:
            if kind in ("attn_dense", "attn_moe", "hybrid"):
                seg_phases += _attn_phases(cfg, opts, seg.window)
            if kind in ("mla_dense", "mla_moe"):
                seg_phases += _mla_phases(cfg, opts)
            if kind in ("ssm", "hybrid"):
                seg_phases += _ssm_phases(cfg, opts)
            if kind in ("attn_dense", "mla_dense", "hybrid"):
                seg_phases += _mlp_phases(cfg, opts, cfg.d_ff)
            if kind in ("attn_moe", "mla_moe"):
                seg_phases += _moe_phases(cfg, opts)
        layers.append(LayerProgram(f"seg{len(layers)}", seg_phases, seg.reps))

    # LM head (the final VMM) + logits gather
    c, b, d, v = opts.n_cus, opts.batch, cfg.d_model, cfg.vocab_size
    head = LayerProgram("head", [
        Phase("lm_head", mem_bytes=d * v * opts.weight_bytes / c,
              flops=2 * d * v * b / c,
              net_bytes=b * d * opts.act_bytes, net_hops=_ring_hops(c),
              overlap_net=True, kind="vmm"),
    ])
    layers.append(head)
    return Program(cfg.name, layers, batch=opts.batch, seq_len=opts.seq_len,
                   n_cus=opts.n_cus)
