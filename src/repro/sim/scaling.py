"""Strong scaling, ISO-TDP, and energy/cost studies (paper §VII-§VIII).

Reproduces the quantitative structure of Figs 9-13:
  * ``rpu_point``      — latency/energy of an N-CU RPU for one model, with
                         the optimal HBM-CO SKU selected per §VII.
  * ``strong_scaling`` — sweep CU counts; speedup + the broadcast plateau.
  * ``iso_tdp_cus``    — CU count matching a GPU system's TDP.
  * ``system_cost``    — silicon + memory + substrate + PCB cost model
                         (Fig 12 bottom).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import hardware
from repro.core.hbmco import (CANDIDATE_CO, HBM3E_LIKE, HBMCOConfig,
                              enumerate_design_space, pareto_frontier,
                              select_sku)
from repro.models.common import ModelConfig
from repro.models.footprint import compute_footprint
from repro.sim.compiler import CompileOptions, compile_decode_step
from repro.sim.engine import SimResult, simulate_program
from repro.sim.gpu_model import (GPUSystemConfig, gpu_decode_latency,
                                 min_gpus_for_model)

# Cost model constants (normalized to one HBM3e module == 1.0, matching
# core.hbmco).  Compute chiplet ~60mm2 N2-class die; packaging per §IV.
# Calibrated so that (a) fixed-HBM3e vs HBM-CO total-cost ratio at the
# 405B latency-optimal scale lands near the paper's 12.4x and (b) the
# memory:compute cost ratio at scale matches an 8xH100 DGX (paper §VIII).
COMPUTE_COST_PER_CU = 0.11
SUBSTRATE_COST_PER_PACKAGE = 0.02     # 4 CUs per package
PCB_COST_PER_RING = 0.08              # ring station + board, per 32 packages


@dataclasses.dataclass
class RPUPoint:
    n_cus: int
    sku: HBMCOConfig
    sim: SimResult
    tdp_w: float
    cost: float
    ms_per_token: float

    @property
    def tokens_per_s(self) -> float:
        return 1e3 / self.ms_per_token


def cu_tdp_w(rpu: hardware.RPUChipParams, sku: HBMCOConfig) -> float:
    """CU TDP: memory stream power at the SKU's energy/bit is 70-80% of the
    budget (paper §IV provisioning)."""
    return rpu.cu_tdp_w(sku.energy_pj_per_bit)


def select_sku_for(cfg: ModelConfig, n_cus: int, *, batch: int = 1,
                   seq_len: int = 8192, frontier=None) -> HBMCOConfig | None:
    """Optimal SKU = smallest frontier capacity fitting weights+KV per
    chiplet (2 memory chiplets per CU)."""
    fp = compute_footprint(cfg)
    need = fp.capacity_bytes(batch, seq_len) / (n_cus * 2)
    return select_sku(need, frontier)


def rpu_point(cfg: ModelConfig, n_cus: int, *, batch: int = 1,
              seq_len: int = 8192,
              rpu: hardware.RPUChipParams = hardware.RPU_DEFAULT,
              sku: HBMCOConfig | None = None,
              decoupled: bool = True,
              fine_grained_net: bool = True) -> RPUPoint | None:
    """Simulate one (model, n_cus) deployment; None if no SKU fits."""
    if sku is None:
        sku = select_sku_for(cfg, n_cus, batch=batch, seq_len=seq_len)
    if sku is None:
        return None
    prog = compile_decode_step(cfg, CompileOptions(
        n_cus=n_cus, batch=batch, seq_len=seq_len))
    sim = simulate_program(prog, rpu=rpu, mem=sku, decoupled=decoupled,
                           fine_grained_net=fine_grained_net)
    return RPUPoint(
        n_cus=n_cus, sku=sku, sim=sim,
        tdp_w=n_cus * cu_tdp_w(rpu, sku),
        cost=system_cost(n_cus, sku)["total"],
        ms_per_token=sim.latency_s * 1e3,
    )


def system_cost(n_cus: int, sku: HBMCOConfig) -> dict:
    """Fig 12 (bottom): silicon / memory / substrate / PCB breakdown."""
    silicon = n_cus * COMPUTE_COST_PER_CU
    memory = n_cus * 2 * sku.module_cost
    substrate = math.ceil(n_cus / 4) * SUBSTRATE_COST_PER_PACKAGE
    pcb = math.ceil(n_cus / 128) * PCB_COST_PER_RING
    return {"silicon": silicon, "memory": memory, "substrate": substrate,
            "pcb": pcb, "total": silicon + memory + substrate + pcb}


def iso_tdp_cus(target_w: float, sku: HBMCOConfig,
                rpu: hardware.RPUChipParams = hardware.RPU_DEFAULT) -> int:
    return max(1, int(target_w / cu_tdp_w(rpu, sku)))


def min_cus_for_model(cfg: ModelConfig, *, batch: int = 1,
                      seq_len: int = 8192, frontier=None) -> int:
    """Smallest CU count for which some frontier SKU fits the model."""
    if frontier is None:
        frontier = pareto_frontier(enumerate_design_space())
    biggest = max(frontier, key=lambda c: c.capacity_bytes)
    fp = compute_footprint(cfg)
    need = fp.capacity_bytes(batch, seq_len)
    return max(1, math.ceil(need / (2 * biggest.capacity_bytes)))


def strong_scaling(cfg: ModelConfig, cu_counts, *, batch: int = 1,
                   seq_len: int = 8192) -> list[RPUPoint]:
    out = []
    for n in cu_counts:
        p = rpu_point(cfg, n, batch=batch, seq_len=seq_len)
        if p is not None:
            out.append(p)
    return out


def iso_tdp_comparison(cfg: ModelConfig, *, batch: int = 1,
                       seq_len: int = 8192,
                       gpu_spec: hardware.GPUSpec = hardware.H100) -> dict:
    """Paper Fig 11/13 headline: RPU at the GPU system's TDP."""
    n_gpus = min_gpus_for_model(cfg, gpu_spec, batch=batch, seq_len=seq_len)
    gpu = GPUSystemConfig(chip=gpu_spec, n_gpus=n_gpus)
    g = gpu_decode_latency(cfg, gpu, batch=batch, seq_len=seq_len)

    # pick the SKU iteratively: CU count depends on SKU TDP, SKU on count.
    frontier = pareto_frontier(enumerate_design_space())
    n_cus = 64
    sku = None
    for _ in range(8):
        sku = select_sku_for(cfg, n_cus, batch=batch, seq_len=seq_len,
                             frontier=frontier)
        if sku is None:
            n_cus *= 2
            continue
        new_n = iso_tdp_cus(gpu.tdp_w, sku)
        if new_n == n_cus:
            break
        n_cus = new_n
    point = rpu_point(cfg, n_cus, batch=batch, seq_len=seq_len, sku=sku)
    tok = batch  # tokens produced per step
    return {
        "model": cfg.name,
        "n_gpus": n_gpus,
        "gpu_tdp_w": gpu.tdp_w,
        "gpu_ms_per_token": g.total_s * 1e3,
        "gpu_energy_per_token_j": g.energy_j,
        "rpu_cus": point.n_cus,
        "rpu_tdp_w": point.tdp_w,
        "rpu_ms_per_token": point.ms_per_token,
        "rpu_energy_per_token_j": point.sim.energy_j,
        "sku": point.sku.name,
        "speedup": g.total_s * 1e3 / point.ms_per_token,
        "energy_ratio": g.energy_j / max(point.sim.energy_j, 1e-12),
        "throughput_ratio": (tok / point.ms_per_token) / (tok / (g.total_s * 1e3)),
    }
