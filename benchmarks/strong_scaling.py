"""Paper Fig 11 (top): strong scaling + ISO-TDP anchors vs H100."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.core import hardware
from repro.sim.gpu_model import GPUSystemConfig, gpu_decode_latency
from repro.sim.scaling import rpu_point, strong_scaling


def run() -> list[Row]:
    rows: list[Row] = []
    # peak points the paper quotes (§VIII)
    for name, n_cus, paper_ms in [("llama3-70b", 204, 0.4),
                                  ("llama3-405b", 428, 1.0),
                                  ("llama4-maverick-400b-a17b", 128, 0.2)]:
        p = rpu_point(get_config(name), n_cus, batch=1, seq_len=8192)
        rows.append(Row("Fig11", f"{name} @ {n_cus} CUs", p.ms_per_token,
                        paper_ms, " ms/tok", f"sku={p.sku.name}"))

    # ISO-TDP anchors: the paper's GPU configs (2xH100 70B, 4xH100 405B)
    for name, n_gpus, paper_x in [("llama3-70b", 2, 47.0),
                                  ("llama3-405b", 4, 45.3)]:
        cfg = get_config(name)
        gpu = GPUSystemConfig(chip=hardware.H100, n_gpus=n_gpus)
        g = gpu_decode_latency(cfg, gpu, batch=1, seq_len=8192)
        # RPU at the same TDP with its best-fitting SKU
        from repro.sim.scaling import (cu_tdp_w, select_sku_for)
        n_cus, sku = 64, None
        for _ in range(8):
            sku = select_sku_for(cfg, n_cus, batch=1, seq_len=8192)
            if sku is None:
                n_cus *= 2
                continue
            new_n = max(1, int(gpu.tdp_w / cu_tdp_w(hardware.RPU_DEFAULT, sku)))
            if new_n == n_cus:
                break
            n_cus = new_n
        p = rpu_point(cfg, n_cus, batch=1, seq_len=8192, sku=sku)
        rows.append(Row("Fig11", f"{name} ISO-TDP speedup vs {n_gpus}xH100",
                        g.total_s * 1e3 / p.ms_per_token, paper_x, "x",
                        f"{gpu.tdp_w:.0f}W: GPU {g.total_s*1e3:.1f}ms vs "
                        f"RPU-{n_cus} {p.ms_per_token:.2f}ms"))

    # scaling curve shape for 70B (plateau check)
    pts = strong_scaling(get_config("llama3-70b"),
                         [32, 64, 128, 204, 256, 384, 512], batch=1,
                         seq_len=8192)
    curve = " ".join(f"{p.n_cus}:{p.ms_per_token:.2f}ms" for p in pts)
    rows.append(Row("Fig11", "llama3-70b scaling curve", curve, None, "",
                    "plateaus as broadcast dominates"))
    # edge/datacenter design points (§VIII): 220W edge, 1kW datacenter
    from repro.sim.scaling import cu_tdp_w as _ctw, select_sku_for as _ssf
    for tdp, paper_ms, label in [(220.0, 3.5, "edge"), (1000.0, 0.65, "datacenter")]:
        cfg = get_config("llama3-70b")
        n, sku = 16, None
        for _ in range(8):
            sku = _ssf(cfg, n, batch=1, seq_len=8192)
            if sku is None:
                n *= 2
                continue
            new_n = max(1, int(tdp / _ctw(hardware.RPU_DEFAULT, sku)))
            if new_n == n:
                break
            n = new_n
        p = rpu_point(cfg, n, batch=1, seq_len=8192, sku=sku)
        if p:
            rows.append(Row("Fig11", f"70B {label} ({tdp:.0f}W) latency",
                            p.ms_per_token, paper_ms, " ms/tok",
                            f"{n} CUs, tdp={p.tdp_w:.0f}W, "
                            f"BW/Cap={p.sku.bw_per_cap:.0f}"))
    return rows
