"""Public op wrappers for the decode-attention kernel (dense and paged)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.paged_kernel import paged_decode_attention
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, gather_pages, paged_decode_attention_ref,
    paged_decode_multi_attention_ref,
)


def gqa_decode_attention(q, k_cache, v_cache, cur_len, *, block_s: int = 512):
    """(B,H,D) x (B,S,KVH,D) cache -> (B,H,D); kernel when tiles fit,
    jnp oracle otherwise (tiny smoke shapes / ragged S)."""
    s = k_cache.shape[1]
    bs = min(block_s, s)
    if s % bs != 0 or q.shape[1] % k_cache.shape[2] != 0:
        return decode_attention_ref(q, k_cache, v_cache, cur_len)
    return decode_attention(q, k_cache, v_cache, cur_len, block_s=bs,
                            interpret=on_cpu())


def paged_gqa_multi_attention(q, k_pages, v_pages, page_table, start, *,
                              k_scales=None, v_scales=None, causal=True,
                              window=None, impl: str = "auto"):
    """Multi-token paged attention: the q_len > 1 counterpart of
    ``paged_gqa_decode_attention``, used by chunked prefill and the
    speculative verify step (q_len = gamma + 1).

    q:          (B, C, H, D) — C queries per slot at per-row absolute
                offsets ``start`` (query j of row b sits at position
                start[b] + j and attends causally up to itself)
    k_pages / v_pages / page_table / k_scales / v_scales: as in
                ``paged_gqa_decode_attention``

    Impls (no separate kernel either way — the gather-fused Pallas path
    only covers q_len == 1 today; multi-token flash-decode over
    scalar-prefetched pages is a recorded follow-on):

      * ``"blocked"``   — gather pages, dequantize, hand to
        ``blocked_attention``'s ragged ``q_offset`` online-softmax path.
        What chunked prefill has always used.
      * ``"reference"`` — ``paged_decode_multi_attention_ref``, op-for-op
        the single-token decode oracle per query.  The speculative verify
        step needs THIS on CPU: its per-position logits are bit-identical
        to the non-speculative decode step's, which is what makes greedy
        speculation byte-identical end to end (the blocked online softmax
        differs at ulp scale — enough to flip argmax on near-ties).
      * ``"auto"``      — reference on CPU (the byte-exactness contract
        lives there), blocked on accelerators (where single-token decode
        takes the fused online-softmax kernel anyway).
    """
    if impl == "auto":
        impl = "reference" if on_cpu() else "blocked"
    if impl == "reference":
        assert causal, "the multi-token decode oracle is causal-only"
        return paged_decode_multi_attention_ref(
            q, k_pages, v_pages, page_table, start, k_scales=k_scales,
            v_scales=v_scales, window=window)
    if impl != "blocked":
        raise ValueError(f"impl={impl!r} (want 'auto', 'blocked' or "
                         "'reference')")
    from repro.quant import kv as kvq
    k_d = gather_pages(k_pages, page_table)
    v_d = gather_pages(v_pages, page_table)
    if k_scales is not None:
        k_d = kvq.kv_dequantize(k_d, gather_pages(k_scales, page_table),
                                q.dtype)
        v_d = kvq.kv_dequantize(v_d, gather_pages(v_scales, page_table),
                                q.dtype)
    from repro.models.common import blocked_attention
    return blocked_attention(q, k_d, v_d, causal=causal, window=window,
                             q_offset=start)


def paged_gqa_decode_attention(q, k_pages, v_pages, page_table, pos, *,
                               k_scales=None, v_scales=None,
                               window=None, impl: str = "auto"):
    """Paged single-token decode attention behind one of two impls:

      * ``"fused"``     — the gather-fused Pallas kernel: the page table
        drives the grid, each K/V page streams HBM->VMEM straight into the
        flash-decode accumulator.  No dense ``(B, S, KVH, D)`` intermediate.
      * ``"reference"`` — gather-then-dense jnp oracle; the bit-exact
        counterpart of the dense serve path.

    ``"auto"`` takes the oracle on CPU (where the fused kernel would run in
    slow interpret mode, and token-exactness with the dense engine is the
    test contract) and the fused kernel on accelerators.  Tests exercise
    the fused kernel on CPU explicitly via ``impl="fused"`` +
    ``interpret=True`` inside ``paged_decode_attention``.
    """
    if impl == "auto":
        impl = "reference" if on_cpu() else "fused"
    if impl == "reference":
        return paged_decode_attention_ref(q, k_pages, v_pages, page_table,
                                          pos, k_scales=k_scales,
                                          v_scales=v_scales, window=window)
    if impl != "fused":
        raise ValueError(f"impl={impl!r} (want 'auto', 'fused' or 'reference')")
    return paged_decode_attention(q, k_pages, v_pages, page_table,
                                  pos.astype(jnp.int32), k_scales=k_scales,
                                  v_scales=v_scales, window=window,
                                  interpret=on_cpu())
