"""Continuous batching vs static batch serving under Poisson arrivals.

The paper's throughput claim (18.6x over H100 at ISO-TDP) assumes decode
stays bandwidth-bound and **occupied**; with ragged request arrivals and
long-tail output lengths, a static batch engine idles finished slots until
the slowest request of the batch drains, and stalls new arrivals until a
whole batch forms.  This benchmark measures both engines on the same
request trace:

  * useful tokens/s   — sum over requests of their own generated tokens,
                        divided by wall time (compile excluded by warmup);
  * slot occupancy    — mean busy-slot fraction per decode iteration.

The static baseline is generous: it decodes each arrival-order batch only
to its **longest member's budget** (not a global cap), so the measured gap
is purely batch-formation waiting + idle finished slots — the two things
iteration-level admission removes.

Output lengths are drawn long-tail (clipped lognormal): most requests are
short, a few run to the cap — the reasoning-workload shape where batch
occupancy is the throughput lever (cf. LIMINAL / inference-scaling studies
in PAPERS.md).

A second workload measures **shared-prefix** traffic (N requests over M
distinct prompts — the multi-turn / system-prompt shape): prefix caching
shares a repeated prompt's full pages read-only and chunked prefill skips
straight to the first unseen token, so TTFT and prefill FLOPs drop against
the PR-1-style path (no sharing, whole-prompt admission).  The decode HBM
story is reported analytically per step: the gather-then-dense path reads
every K/V page, writes the dense copy, and reads it back (3x the pool
bytes); the gather-fused kernel streams each page exactly once.

Both engines run f32 params and f32 KV caches: XLA:CPU has no native bf16
GEMM and re-converts bf16 buffers around every step, which would swamp the
scheduling effect being measured here (on TPU both run bf16).

Both execution strategies are driven through the SAME ``LLMEngine``
request-level API (``generate(prompts, sampling_params)``) — the benchmark
compares backends, not entrypoints.

  PYTHONPATH=src python -m benchmarks.continuous_batching \
      [--batch 8] [--requests 64] [--seed 0]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, dump
from repro.models.common import ModelConfig
from repro.models.model import build_model
from repro.runtime.llm import LLMEngine
from repro.runtime.sampling import SamplingParams

# Big enough that a fused decode step is compute/bandwidth-dominated on CPU
# (host dispatch noise < 5%), small enough to compile in seconds.
BENCH_CONFIG = ModelConfig(
    name="bench-serve", family="dense", n_layers=6, d_model=384,
    n_heads=8, n_kv_heads=4, head_dim=48, d_ff=1024, vocab_size=2048,
)

PROMPT_LEN = 16
MAX_NEW = 64          # per-request budget cap
PAGE = 40             # 2 blocks/request: paged gather width == dense width


def make_trace(n_req: int, seed: int, mean_interarrival: float):
    """Poisson arrivals, long-tail (clipped lognormal) output lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_interarrival, n_req))
    new_tokens = np.clip(rng.lognormal(np.log(6.0), 1.5, n_req).astype(int),
                         2, MAX_NEW)
    prompts = rng.integers(0, BENCH_CONFIG.vocab_size,
                           (n_req, PROMPT_LEN)).astype(np.int32)
    return arrivals, new_tokens, prompts


def run_static(model, params, arrivals, new_tokens, prompts, batch: int):
    """Arrival-order batches; each waits for full formation, then decodes to
    its longest member's budget (finished slots idle until then)."""
    llm = LLMEngine(model, params, backend="static",
                    max_len=PROMPT_LEN + MAX_NEW + 1,
                    cache_dtype=jnp.float32)
    n_req = prompts.shape[0]
    batches = [(lo, min(lo + batch, n_req))
               for lo in range(0, n_req, batch)]
    steps = [int(new_tokens[lo:hi].max()) for lo, hi in batches]
    shapes = {(hi - lo, n) for (lo, hi), n in zip(batches, steps)}
    for rows, n in sorted(shapes):         # compile each (rows, n_steps)
        llm.generate(list(prompts[:rows]), max_new_tokens=n)

    useful = 0
    t0 = time.monotonic()
    for (lo, hi), n in zip(batches, steps):
        wait = arrivals[hi - 1] - (time.monotonic() - t0)
        if wait > 0:                                  # batch not formed yet
            time.sleep(wait)
        llm.generate(list(prompts[lo:hi]),
                     [SamplingParams(max_tokens=int(t))
                      for t in new_tokens[lo:hi]])
        useful += int(new_tokens[lo:hi].sum())
    wall = time.monotonic() - t0
    return useful / wall, wall


def make_continuous_llm(model, params, batch: int) -> LLMEngine:
    return LLMEngine(
        model, params, backend="continuous", num_slots=batch, page_size=PAGE,
        num_pages=1 + 2 * batch * -(-(PROMPT_LEN + MAX_NEW) // PAGE),
        max_len=PROMPT_LEN + MAX_NEW, cache_dtype=jnp.float32,
        prefill_chunk=PROMPT_LEN)       # whole prompt in one chunk row


def run_continuous(model, params, arrivals, new_tokens, prompts, batch: int):
    llm = make_continuous_llm(model, params, batch)
    # warmup/compile: fused step + prefill/scatter at every pow-2 admission
    # bucket the run can hit
    b = 1
    while b <= batch:
        llm.generate([prompts[0]] * b, max_new_tokens=2)
        b *= 2

    llm.generate(list(prompts),
                 [SamplingParams(max_tokens=int(t)) for t in new_tokens],
                 arrival_times=[float(a) for a in arrivals])
    stats = llm.last_stats
    return stats.total_tokens / stats.wall, stats


# shared-prefix workload: prompts long enough to span several pages
SP_PROMPT_LEN = 96
SP_PAGE = 8
SP_MAX_NEW = 4


def decode_hbm_rows(mean_ctx: float) -> list[Row]:
    """Analytic decode-attention HBM traffic per generated token.

    The gather-fused kernel streams each live K/V page once
    (read-pool-only); the PR-1 gather-then-dense path reads the pool,
    writes the dense ``(B, S, KVH, D)`` copy, and reads it back in the
    kernel — 3x the bytes at equal context."""
    c = BENCH_CONFIG
    per_tok = 2 * mean_ctx * c.n_kv_heads * c.hd * 4 * c.n_layers  # K+V, f32
    fused = per_tok
    gather_dense = 3 * per_tok
    return [
        Row("ours:serving", "decode HBM bytes/token (gather-fused)",
            fused / 1e6, None, "MB",
            f"mean ctx {mean_ctx:.0f}, read each K/V page once"),
        Row("ours:serving", "decode HBM bytes/token (gather-then-dense)",
            gather_dense / 1e6, None, "MB",
            "PR-1 path: read pool + write dense + read dense"),
        Row("ours:serving", "fused decode HBM reduction", 3.0, None, "x",
            "paper's KV-stream argument: no dense intermediate"),
    ]


def run_shared_prefix(model, params, batch: int, n_req: int,
                      n_prompts: int, seed: int) -> list[Row]:
    """N requests over M distinct prompts: prefix caching + chunked prefill
    vs the PR-1-style path (no sharing, whole-prompt admission)."""
    max_len = SP_PROMPT_LEN + SP_MAX_NEW
    num_pages = 1 + 2 * batch * -(-max_len // SP_PAGE)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, BENCH_CONFIG.vocab_size,
                           (n_prompts, SP_PROMPT_LEN)).astype(np.int32)
    picks = np.arange(n_req) % n_prompts

    def make_engine(prefix: bool):
        return LLMEngine(
            model, params, backend="continuous", num_slots=batch,
            page_size=SP_PAGE, num_pages=num_pages, max_len=max_len,
            cache_dtype=jnp.float32,
            prefill_chunk=4 * SP_PAGE if prefix else SP_PROMPT_LEN,
            enable_prefix_cache=prefix)

    def warm(llm):
        # compile every pow-2 prefill-chunk bucket + the decode step (each
        # engine instance has its own jit caches, so warm per engine); the
        # staggered arrivals make later warm requests hit the prefix index,
        # compiling the short post-hit chunk width too
        b = 1
        while b <= batch:
            llm.generate([prompts[i % n_prompts] for i in range(b)],
                         max_new_tokens=2,
                         arrival_times=[0.2 * i for i in range(b)])
            b *= 2

    # calibrate arrival gaps to a decode step so prompts repeat while the
    # trace is still live (the regime prefix caching targets)
    probe = make_engine(True)
    warm(probe)
    t0 = time.monotonic()
    probe.generate([prompts[0]], max_new_tokens=8)
    step_s = (time.monotonic() - t0) / 8

    arrivals = [float(a) for a in np.cumsum(rng.exponential(8 * step_s, n_req))]
    trace_prompts = [prompts[picks[i]] for i in range(n_req)]

    def serve(llm):
        llm.generate(trace_prompts, max_new_tokens=SP_MAX_NEW,
                     arrival_times=arrivals)
        return llm.last_stats

    results = {}
    for name, prefix in (("prefix+chunked", True), ("pr1-style", False)):
        llm = make_engine(prefix)
        warm(llm)
        # best-of-2: wall-clock serving on a shared machine — keep the
        # least-interfered rep (same arrival trace both times)
        results[name] = min((serve(llm) for _ in range(2)),
                            key=lambda s: s.ttft_quantiles()[0])

    sp, s1 = results["prefix+chunked"], results["pr1-style"]
    p50, p99, pmean = sp.ttft_quantiles()
    q50, q99, qmean = s1.ttft_quantiles()
    mean_ctx = SP_PROMPT_LEN + SP_MAX_NEW / 2
    rows = [
        Row("ours:prefix", "prefix-cache hit rate", sp.prefix_hit_rate,
            None, "", f"{n_req} requests over {n_prompts} prompts"),
        Row("ours:prefix", "prefill tokens computed (prefix+chunked)",
            sp.prefill_tokens, None, "",
            f"of {sp.prompt_tokens} admitted ({sp.chunks} chunks)"),
        Row("ours:prefix", "prefill tokens computed (pr1-style)",
            s1.prefill_tokens, None, "", f"of {s1.prompt_tokens} admitted"),
        Row("ours:prefix", "prefill FLOPs saved",
            1.0 - sp.prefill_tokens / max(s1.prefill_tokens, 1), None, "",
            "fraction of prompt compute skipped via shared pages"),
        Row("ours:prefix", "TTFT p50 (prefix+chunked)", p50 * 1e3, None, "ms",
            f"vs {q50 * 1e3:.1f}ms pr1-style"),
        Row("ours:prefix", "TTFT p99 (prefix+chunked)", p99 * 1e3, None, "ms",
            f"vs {q99 * 1e3:.1f}ms pr1-style (admission interleaves with "
            "decode, so the running batch never stalls)"),
        Row("ours:prefix", "TTFT mean (prefix+chunked)", pmean * 1e3, None,
            "ms", f"vs {qmean * 1e3:.1f}ms pr1-style"),
        Row("ours:prefix", "TTFT p50 speedup", q50 / max(p50, 1e-9), None, "x",
            "prefix reuse skips shared full blocks"),
    ]
    return rows + decode_hbm_rows(mean_ctx)


# ---------------------------------------------------------------------------
# Capacity sweep (--capacity-sweep): the paper's capacity-vs-throughput
# trade-off on the REAL engine
# ---------------------------------------------------------------------------

# Fixed-bandwidth-interface HBM-CO stacks of growing capacity (the Fig 9/10
# provisioning axis, scaled to the toy model): the candidate's 256 GB/s
# interface (1 rank x 4 layers x 1 ch x 1 bank) at sub-array counts chosen
# so the derived KV budget crosses from cannot-fit-one-request, through
# preemption-storm, to knee-limited roomy (capacity = 32 x bank_mb MB).
# 0.37 (11.8MB) is the quantized-KV crossover: after the ~10.9MB exact
# mxfp4 weight bytes + workspace, the remainder backs one request's pages
# at fp8/int8 KV but not at f32 — the point the quant sweep serves and
# the f32 sweep reports "does not fit".
SWEEP_BANK_MBS = (0.15, 0.22, 0.25, 0.3, 0.37, 0.5, 1.0)


def run_capacity_sweep(model, params, n_req: int, seed: int,
                       bank_mbs=SWEEP_BANK_MBS,
                       cache_dtype=jnp.float32) -> list[Row]:
    """Serve the SAME greedy trace under DeploymentSpecs of growing HBM-CO
    capacity; report measured tokens/s and preemption rate against the
    spec's modeled roofline ceiling.

    Architectural assertions: outputs are byte-identical at every feasible
    point (restart-style preemption is invisible in the stream), and the
    derived pool grows monotonically with capacity.  Measured-vs-modeled
    is reported, not asserted — the model is the target hardware's memory
    roofline, the measurement is XLA:CPU.

    ``cache_dtype="fp8"`` / ``"int8"`` reruns the sweep with quantized KV
    page pools (weights execute mxfp4 either way): the derived pool gets
    ~4x the pages per MB, so stacks that "do not fit" under f32 KV serve
    the trace — the capacity knee of the sweep moves left.
    """
    from repro.core.hbmco import HBMCOConfig
    from repro.runtime.deployment import DeploymentError, DeploymentSpec

    max_len = PROMPT_LEN + MAX_NEW
    _, new_tokens, prompts = make_trace(n_req, seed, 0.0)  # all arrive at t0
    sps = [SamplingParams(max_tokens=int(t)) for t in new_tokens]
    tag = cache_dtype if isinstance(cache_dtype, str) \
        else jnp.dtype(cache_dtype).name
    group = f"ours:capacity[{tag}]" if isinstance(cache_dtype, str) \
        else "ours:capacity"

    rows: list[Row] = []
    ref_results = None
    last_pages = 0
    for mb in bank_mbs:
        hbm = HBMCOConfig(name=f"co-sweep-m{mb:g}", ranks=1,
                          channels_per_layer=1, banks_per_group=1,
                          bank_mb=mb)
        spec = DeploymentSpec(
            sku="rpu-cu", hbmco=hbm, stacks_per_device=1,
            weight_format="mxfp4", cache_dtype=cache_dtype,
            max_len=max_len, page_size=PAGE, prefill_chunk=PROMPT_LEN,
            max_slots=8, overcommit=2.0,
            mean_context=PROMPT_LEN + MAX_NEW // 2)
        try:
            llm = LLMEngine(model, params, backend="continuous", spec=spec)
        except DeploymentError as e:
            rows.append(Row(group,
                            f"{hbm.capacity_mb:.1f}MB stack measured tok/s",
                            0.0, None, "", f"does not fit: {e}"))
            continue
        dep = llm.deployment
        assert dep.num_pages >= last_pages, \
            "pool must grow monotonically with capacity"
        last_pages = dep.num_pages
        # warm every admission bucket the run can hit: pow-2 counts below
        # the slot count, plus a full-slots batch (whose prefill bucket is
        # pow2ceil(num_slots) — reachable even when num_slots is not a
        # power of two)
        b = 1
        while b < dep.num_slots:
            llm.generate([prompts[0]] * b, max_new_tokens=2)
            b *= 2
        llm.generate([prompts[0]] * dep.num_slots, max_new_tokens=2)
        outs = llm.generate(list(prompts), sps)
        stats = llm.last_stats
        results = [tuple(o.token_ids) for o in outs]
        if ref_results is None:
            ref_results = results
        else:
            assert results == ref_results, \
                "outputs must be byte-identical across capacity points"
        measured = stats.total_tokens / stats.wall
        preempt_rate = stats.preemptions / n_req
        cap = f"{hbm.capacity_mb:.1f}MB stack"
        rows.append(Row(
            group, f"{cap} measured tok/s", measured, None, "",
            f"{dep.num_pages} pages / {dep.num_slots} slots, "
            f"occupancy {stats.occupancy:.2f}, "
            f"{dep.kv_token_bytes}B KV/token ({tag})"))
        rows.append(Row(
            group, f"{cap} modeled ceiling",
            dep.tokens_per_s_ceiling, None, "tok/s",
            f"memory roofline at {dep.device.decode_bw / 1e9:.0f}GB/s "
            f"(target hardware, not the CPU host)"))
        rows.append(Row(
            group, f"{cap} preemptions/request", preempt_rate,
            None, "", f"{stats.preemptions} total over {n_req} requests"))
        rows.append(Row(
            group, f"{cap} KV budget",
            dep.kv_budget_bytes / 2**20, None, "MB",
            f"of {hbm.capacity_mb:.0f}MB after "
            f"{dep.weight_bytes_per_device / 2**20:.1f}MB mxfp4 weights + "
            f"{dep.workspace_bytes / 2**20:.1f}MB workspace; "
            f"{dep.modeled_j_per_token * 1e3:.2f} mJ/token modeled"))
    assert ref_results is not None, "no sweep point fit the model"
    return rows


# ---------------------------------------------------------------------------
# Tensor-parallel strong scaling (--mesh): 1 -> 8 host devices
# ---------------------------------------------------------------------------

_MESH_WORKER = """
import os, json, sys, time, dataclasses
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(tp)d"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, %(root)r)
from benchmarks.continuous_batching import (BENCH_CONFIG, MAX_NEW, PAGE,
                                            PROMPT_LEN, make_trace)
from repro.models.model import build_model
from repro.runtime.engine import ContinuousServeEngine
from repro.runtime.sampling import SamplingParams
from repro.runtime.scheduler import Request

tp, n_req, batch, seed = %(tp)d, %(n_req)d, %(batch)d, %(seed)d
# 8 KV heads so every TP degree of the sweep divides the KV-head axis
cfg = dataclasses.replace(BENCH_CONFIG, name="bench-serve-tp", n_kv_heads=8)
model = build_model(cfg)
params = jax.tree.map(
    lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
    model.init(jax.random.PRNGKey(seed)))
mesh = jax.make_mesh((1, tp), ("data", "model")) if tp > 1 else None
eng = ContinuousServeEngine(
    model, params, num_slots=batch, page_size=PAGE,
    num_pages=1 + 2 * batch * -(-(PROMPT_LEN + MAX_NEW) // PAGE),
    max_len=PROMPT_LEN + MAX_NEW, cache_dtype=jnp.float32,
    prefill_chunk=PROMPT_LEN, mesh=mesh)
_, new_tokens, prompts = make_trace(n_req, seed, 0.0)
mk = lambda rs: [Request(rid=i, prompt=prompts[i],
                         max_new_tokens=int(new_tokens[i]),
                         sampling=SamplingParams(max_tokens=int(new_tokens[i])))
                 for i in rs]
eng.run(mk(range(min(batch, n_req))))           # warm/compile
stats = min((eng.run(mk(range(n_req))) for _ in range(2)),
            key=lambda s: s.wall)
plan = eng.serve_plan
print(json.dumps({
    "tp": tp,
    "tokens_per_s": stats.total_tokens / stats.wall,
    "steps": stats.steps,
    "kv_bytes_per_token_per_device": eng.kv_token_bytes_per_device(),
    "psum_bytes_per_step_per_device":
        plan.psum_bytes_per_step(model, batch) if plan else 0,
    "reduce": plan.reduce if plan else "none",
}))
"""


def run_mesh_sweep(n_req: int, batch: int, seed: int,
                   tps=(1, 2, 4, 8)) -> list[Row]:
    """Strong-scaling sweep over the TP degree, one subprocess per point
    (each needs its own XLA host-device count and a clean compile cache).
    CPU host devices share one socket, so tokens/s is a smoke signal here;
    the architectural observables are per-device KV bytes/token (must
    shrink 1/TP — the paper's add-bandwidth-by-adding-CUs lever) and the
    per-step collective bytes the Megatron pairing costs."""
    import pathlib
    import subprocess
    import sys

    root = str(pathlib.Path(__file__).resolve().parents[1])
    results = []
    for tp in tps:
        code = _MESH_WORKER % {"tp": tp, "n_req": n_req, "batch": batch,
                               "seed": seed, "root": root}
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=1200,
                           env={**os.environ,
                                "PYTHONPATH": os.path.join(root, "src")})
        assert r.returncode == 0, r.stderr[-3000:]
        results.append(json.loads(r.stdout.strip().splitlines()[-1]))
    base = results[0]
    rows = []
    for res in results:
        tp = res["tp"]
        ratio = base["kv_bytes_per_token_per_device"] \
            / res["kv_bytes_per_token_per_device"]
        rows.append(Row("ours:tp-serving", f"tp={tp} useful tok/s",
                        res["tokens_per_s"], None, "",
                        f"{res['steps']} steps, reduce={res['reduce']}"))
        rows.append(Row("ours:tp-serving", f"tp={tp} KV bytes/token/device",
                        res["kv_bytes_per_token_per_device"] / 1e3, None,
                        "KB", f"{ratio:.0f}x below tp=1 (expect {tp}x)"))
        rows.append(Row("ours:tp-serving", f"tp={tp} collective bytes/step",
                        res["psum_bytes_per_step_per_device"] / 1e3, None,
                        "KB", "per device, attention+MLP pair closes"))
        assert res["kv_bytes_per_token_per_device"] \
            == base["kv_bytes_per_token_per_device"] // tp, \
            "per-device KV bytes must scale 1/TP"
    return rows


def run(model, params, batch: int = 8, n_req: int = 64,
        seed: int = 0) -> list[Row]:
    # Calibrate the arrival rate to the hardware: mean interarrival = one
    # fused decode step, i.e. arrivals stagger at decode granularity (the
    # regime continuous batching targets) without starving either engine
    # for whole seconds.
    llm = LLMEngine(model, params, backend="static",
                    max_len=PROMPT_LEN + MAX_NEW + 1,
                    cache_dtype=jnp.float32)
    probe = [np.zeros((PROMPT_LEN,), np.int32)] * batch
    llm.generate(probe, max_new_tokens=16)
    t0 = time.monotonic()
    llm.generate(probe, max_new_tokens=16)
    step_s = (time.monotonic() - t0) / 16
    mean_interarrival = step_s

    arrivals, new_tokens, prompts = make_trace(n_req, seed, mean_interarrival)
    # best-of-2 per engine: the serving loops are wall-clock measurements on
    # a shared machine, so take the least-interfered rep (min-of-N timing)
    static_tps, static_wall = max(
        (run_static(model, params, arrivals, new_tokens, prompts, batch)
         for _ in range(2)), key=lambda r: r[0])
    cont_tps, stats = max(
        (run_continuous(model, params, arrivals, new_tokens, prompts, batch)
         for _ in range(2)), key=lambda r: r[0])
    speedup = cont_tps / static_tps
    rows = [
        Row("ours:serving", f"static batch={batch} useful tok/s",
            static_tps, None, "",
            f"wall {static_wall:.2f}s, decodes to max(batch budgets)"),
        Row("ours:serving", f"continuous slots={batch} useful tok/s",
            cont_tps, None, "",
            f"wall {stats.wall:.2f}s, {stats.steps} steps, "
            f"occupancy {stats.occupancy:.2f}, "
            f"{stats.preemptions} preemptions"),
        Row("ours:serving", "continuous / static speedup", speedup, None, "x",
            f"{n_req} requests, Poisson mean gap {mean_interarrival*1e3:.1f}ms, "
            f"lognormal lengths [2,{MAX_NEW}]"),
        Row("ours:serving", "mean slot occupancy", stats.occupancy, None, "",
            "busy slots / total slots per decode iteration"),
    ]
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--prompts", type=int, default=0,
                    help="distinct prompts for the shared-prefix workload "
                         "(default requests // 4)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-throughput", action="store_true",
                    help="run only the shared-prefix workload (faster)")
    ap.add_argument("--mesh", action="store_true",
                    help="tensor-parallel strong-scaling sweep instead: "
                         "1 -> 8 host devices, one subprocess per TP "
                         "degree (tokens/s, per-device KV bytes/token, "
                         "per-step collective bytes)")
    ap.add_argument("--capacity-sweep", action="store_true",
                    help="DeploymentSpec capacity sweep instead: serve the "
                         "same trace under fixed-bandwidth HBM-CO stacks "
                         "of growing capacity (paper Fig 9/10 axis); "
                         "measured tokens/s + preemption rate vs the "
                         "modeled roofline ceiling, JSON artifact")
    ap.add_argument("--cache-dtype", default="f32",
                    choices=["f32", "fp8", "int8"],
                    help="KV pool dtype for --capacity-sweep; fp8/int8 "
                         "serve quantized page pools (mxfp4 weights either "
                         "way) and dump to capacity_sweep_quant")
    args = ap.parse_args(argv)
    if args.mesh:
        rows = run_mesh_sweep(args.requests, args.batch, args.seed)
        for r in rows:
            print(r.render())
        dump(rows, "continuous_batching_mesh")
        return 0
    if args.capacity_sweep:
        model = build_model(BENCH_CONFIG)
        params = jax.tree.map(
            lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
            model.init(jax.random.PRNGKey(args.seed)))
        cache_dtype = jnp.float32 if args.cache_dtype == "f32" \
            else args.cache_dtype
        rows = run_capacity_sweep(model, params, args.requests, args.seed,
                                  cache_dtype=cache_dtype)
        for r in rows:
            print(r.render())
        dump(rows, "capacity_sweep" if args.cache_dtype == "f32"
             else "capacity_sweep_quant")
        return 0
    model = build_model(BENCH_CONFIG)
    params = model.init(jax.random.PRNGKey(args.seed))
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        params)
    rows = [] if args.skip_throughput else run(model, params, args.batch,
                                               args.requests, args.seed)
    rows += run_shared_prefix(model, params, args.batch, args.requests,
                              args.prompts or max(args.requests // 4, 1),
                              args.seed)
    for r in rows:
        print(r.render())
    dump(rows, "continuous_batching")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
