"""End-to-end serving driver (the paper's kind: low-latency decode).

One ``LLMEngine`` front-end, three execution backends:
  * ``static``   — prefill, then the decode loop is ONE jitted lax.scan —
    no host round-trips (the JAX analogue of the RPU's autonomous
    execution);
  * ``continuous`` — iteration-level batching over the block-paged KV
    cache, streaming ``RequestOutput`` deltas as tokens land;
  * ``speculative`` — draft/target speculative decoding (paper Fig 14,
    lossless).

Every request carries its own ``SamplingParams`` — the demo serves a
heterogeneous greedy + sampled mix through the one compiled decode step.

  PYTHONPATH=src python examples/serve_decode.py [--arch h2o-danube-1.8b]
      [--batch 8] [--new 48] [--speculative]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models.model import build_model
from repro.runtime.deployment import DeploymentSpec
from repro.runtime.llm import LLMEngine
from repro.runtime.sampling import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--speculative", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    prompts = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size))

    # -- static batch: whole decode in one jitted scan ----------------------
    llm = LLMEngine(model, params, backend="static",
                    max_len=args.prompt_len + args.new + 1)
    # a per-request mix: half greedy, half sampled with distinct seeds —
    # all data, one compiled decode loop
    mix = [SamplingParams() if i % 2 == 0 else
           SamplingParams(temperature=args.temperature, top_p=0.95, seed=i)
           for i in range(args.batch)]
    llm.generate(list(prompts), mix, max_new_tokens=2)     # warm-up compile
    t0 = time.time()
    outs = llm.generate(list(prompts), mix, max_new_tokens=args.new)
    dt = time.time() - t0
    total = sum(len(o.token_ids) for o in outs)
    print(f"[static decode] {args.batch} requests x {args.new} tokens in "
          f"{dt:.2f}s = {total/dt:.0f} tok/s")
    print("  greedy row:", outs[0].token_ids[:12])
    print("  sampled row:", outs[1].token_ids[:12])

    # -- continuous batching: the pool/slot budget comes from a hardware
    # spec (paper's HBM-CO candidate device), not a hand-tuned knob -------
    try:
        spec = DeploymentSpec(sku="rpu-cu", hbmco="hbmco-768MB",
                              weight_format="mxfp4",
                              max_len=args.prompt_len + args.new + 1,
                              cache_dtype=jnp.float32,
                              max_slots=min(4, args.batch))
        cllm = LLMEngine(model, params, backend="continuous", spec=spec)
        print(cllm.deployment.describe())
        stream: dict[int, int] = {}
        cllm.generate(list(prompts[:4]), mix[:4], max_new_tokens=8,
                      on_output=lambda o: stream.__setitem__(
                          o.rid, stream.get(o.rid, 0) + len(o.new_token_ids)))
        print(f"[continuous] streamed deltas per request: "
              f"{dict(sorted(stream.items()))} "
              f"(occupancy {cllm.last_stats.occupancy:.2f})")
    except NotImplementedError as e:
        print(f"[continuous] skipped for {cfg.name}: {e}")

    if args.speculative:
        # With an agreeing draft (here: the target itself) every window
        # accepts all gamma tokens; real deployments use a small trained
        # draft (paper: Llama3-8B drafting for 70B, 4.6/8 accepted).
        # Untrained random drafts accept ~0 — run one of each to show the
        # acceptance machinery.
        sllm = LLMEngine(model, params, backend="speculative",
                         max_len=args.prompt_len + args.new + 8, gamma=4)
        out = sllm.generate(prompts[:1], max_new_tokens=args.new)[0]
        print(f"[speculative, ideal draft] {out.metrics['windows']} windows, "
              f"{out.metrics['accepted_per_window']:.2f}/4 accepted  tokens: "
              f"{out.token_ids[:8]}")
        draft_cfg = dataclasses.replace(cfg, name="draft",
                                        n_layers=max(2, cfg.n_layers // 2))
        draft = build_model(draft_cfg)
        dparams = draft.init(jax.random.fold_in(key, 2))
        dllm = LLMEngine(model, params, backend="speculative",
                         max_len=args.prompt_len + args.new + 8,
                         draft_model=draft, draft_params=dparams, gamma=4)
        out = dllm.generate(prompts[:1], max_new_tokens=args.new)[0]
        print(f"[speculative, random draft] {out.metrics['windows']} windows, "
              f"{out.metrics['accepted_per_window']:.2f}/4 accepted "
              "(untrained draft: low acceptance expected; output stays "
              "lossless)")


if __name__ == "__main__":
    main()
