"""Llama4-Maverick 400B-A17B — MoE 128 experts top-1, alternating
dense/MoE layers, one shared expert.  [hf:meta-llama/Llama-4-Maverick]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,                # dense layers' FFN
    vocab_size=202048, vocab_pad_multiple=512,
    moe=True,
    n_experts=128,
    n_experts_per_token=1,
    n_shared_experts=1,
    moe_d_ff=8192,
    moe_layer_period=2,       # every other layer is MoE
    rope_theta=500000.0,
)
