"""Analytical memory/compute footprints per ModelConfig.

Used by the RPU simulator (§VI), the HBM-CO SKU selection map (Fig 10),
and the roofline benchmarks.  All byte counts are exact functions of the
config — the same arithmetic the paper uses for "active parameters" and
"KV$ fraction".
"""
from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig
from repro.models.model import build_plan


def _attn_params(cfg: ModelConfig) -> int:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n = d * h * hd + 2 * d * kvh * hd + h * hd * d
    if cfg.qkv_bias:
        n += h * hd + 2 * kvh * hd
    return n


def _mla_params(cfg: ModelConfig) -> int:
    d, h = cfg.d_model, cfg.n_heads
    hd, rhd, vhd, r = cfg.hd, cfg.rope_head_dim, cfg.v_hd, cfg.kv_lora_rank
    return (d * h * (hd + rhd) + d * (r + rhd)
            + r * h * hd + r * h * vhd + h * vhd * d)


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    return 3 * cfg.d_model * d_ff


def _moe_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active-per-token) for one MoE layer."""
    fe = cfg.moe_d_ff or cfg.d_ff
    per_exp = 3 * cfg.d_model * fe
    total = cfg.n_experts * per_exp + cfg.d_model * cfg.n_experts
    active = cfg.n_experts_per_token * per_exp + cfg.d_model * cfg.n_experts
    if cfg.n_shared_experts:
        shared = 3 * cfg.d_model * fe * cfg.n_shared_experts
        total += shared
        active += shared
    return total, active


def _ssm_params(cfg: ModelConfig) -> int:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    conv_dim = di + 2 * g * n
    return (d * (2 * di + 2 * g * n + h) + cfg.conv_kernel * conv_dim
            + conv_dim + 3 * h + di + di * d)


@dataclasses.dataclass(frozen=True)
class Footprint:
    """Byte/param accounting for one architecture."""

    cfg: ModelConfig
    total_params: int
    active_params: int          # streamed per generated token (excl. embed table)
    kv_per_token: int           # KV$ *elements* per token per sequence
    state_elems: int            # fixed recurrent state elements per sequence

    def param_bytes(self, bits_per_weight: float = 4.25) -> float:
        return self.total_params * bits_per_weight / 8.0

    def active_param_bytes(self, bits_per_weight: float = 4.25) -> float:
        return self.active_params * bits_per_weight / 8.0

    def kv_bytes_per_token(self, kv_bytes: int = 1) -> float:
        """fp8 KV$ by default (paper's 'FP8 KV$' deployment)."""
        return self.kv_per_token * kv_bytes

    def kv_bytes(self, batch: int, seq_len: int, kv_bytes: int = 1) -> float:
        cfg = self.cfg
        eff = seq_len
        if cfg.sliding_window:
            eff = min(seq_len, cfg.sliding_window)
        return (self.kv_per_token * eff + self.state_elems) * kv_bytes * batch

    def capacity_bytes(self, batch: int, seq_len: int,
                       bits_per_weight: float = 4.25, kv_bytes: int = 1) -> float:
        return self.param_bytes(bits_per_weight) + self.kv_bytes(batch, seq_len, kv_bytes)

    def streamed_bytes_per_token(self, batch: int, seq_len: int,
                                 bits_per_weight: float = 4.25,
                                 kv_bytes: int = 1) -> float:
        """Bytes read from memory per decode step: every active weight once
        (shared across the batch) + each query's unique KV history."""
        return (self.active_param_bytes(bits_per_weight)
                + self.kv_bytes(batch, seq_len, kv_bytes))

    def decode_flops_per_token(self, batch: int, seq_len: int) -> float:
        """MACs*2 per decode step (batch shares weights; KV$ is per-query).
        Sliding-window archs only attend over the window."""
        eff = seq_len
        if self.cfg.sliding_window:
            eff = min(seq_len, self.cfg.sliding_window)
        w_flops = 2.0 * self.active_params * batch
        kv_flops = 2.0 * self.kv_per_token * eff * batch
        return w_flops + kv_flops


def compute_footprint(cfg: ModelConfig) -> Footprint:
    plan = build_plan(cfg)
    total = 0
    active = 0
    kv_per_tok = 0
    state = 0
    for seg in plan:
        for kind in seg.kinds:
            lt = la = lkv = lst = 0
            if kind in ("attn_dense", "attn_moe", "hybrid"):
                lt += _attn_params(cfg)
                # window caps the stored KV, handled in kv_bytes(); per-token
                # element count here:
                lkv += 2 * cfg.n_kv_heads * cfg.hd
            if kind in ("mla_dense", "mla_moe"):
                lt += _mla_params(cfg)
                lkv += cfg.kv_lora_rank + cfg.rope_head_dim
            if kind in ("attn_dense", "mla_dense", "hybrid"):
                lt += _mlp_params(cfg, cfg.d_ff)
            if kind in ("attn_moe", "mla_moe"):
                t, a = _moe_params(cfg)
                lt += t
                la += a + _attn_params(cfg) if kind == "attn_moe" else a + _mla_params(cfg)
            if kind in ("ssm", "hybrid"):
                lt += _ssm_params(cfg)
                lst += (cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                        + (cfg.conv_kernel - 1) * (cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state))
            if la == 0:
                la = lt                      # dense layer: all params active
            total += lt * seg.reps
            active += la * seg.reps
            kv_per_tok += lkv * seg.reps
            state += lst * seg.reps
    d, v = cfg.d_model, cfg.vocab_size
    if cfg.frontend == "audio":
        total += d * d + d * v
        active += d * d + d * v
    else:
        total += v * d + (0 if cfg.tie_embeddings else d * v)
        active += d + d * v                  # one embed row + the lm head
    return Footprint(cfg, total, active, kv_per_tok, state)
