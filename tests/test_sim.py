"""Event-driven RPU simulator vs the paper's §VI/§VIII/§IX claims."""
import pytest

from repro.configs import get_config
from repro.core import hardware
from repro.core.hbmco import CANDIDATE_CO
from repro.sim.compiler import CompileOptions, compile_decode_step
from repro.sim.engine import simulate_program
from repro.sim.gpu_model import GPUSystemConfig, gpu_decode_latency
from repro.sim.scaling import (iso_tdp_comparison, min_cus_for_model,
                               rpu_point, strong_scaling, system_cost)


def _sim(name, n_cus=64, batch=1, seq=16384, **kw):
    prog = compile_decode_step(get_config(name),
                               CompileOptions(n_cus=n_cus, batch=batch,
                                              seq_len=seq))
    return simulate_program(prog, **kw)


def test_bs1_saturates_memory_bandwidth():
    """Paper: 'At batch size 1, the RPU saturates memory bandwidth and
    achieves roofline performance.'"""
    r = _sim("llama3-8b", batch=1)
    assert r.mem_bw_utilization > 0.95


def test_compiled_bytes_match_footprint():
    """Compiler streams exactly the model's active bytes + KV$."""
    from repro.models.footprint import compute_footprint
    cfg = get_config("llama3-8b")
    opts = CompileOptions(n_cus=64, batch=1, seq_len=16384)
    prog = compile_decode_step(cfg, opts)
    fp = compute_footprint(cfg)
    want = fp.streamed_bytes_per_token(1, 16384) / 64
    got = prog.total_mem_bytes()
    assert got == pytest.approx(want, rel=0.1)


def test_decoupling_speedup_bs32():
    """§IX C3: decoupled execution (buffering the bimodal phases) is worth
    up to ~1.6x at batch 32; must be >1 and <= ~2."""
    r_dec = _sim("llama3-8b", batch=32, seq=8192)
    r_ser = _sim("llama3-8b", batch=32, seq=8192, decoupled=False)
    speedup = r_ser.latency_s / r_dec.latency_s
    assert 1.05 < speedup < 2.2, speedup


def test_fine_grained_net_avoids_collective_stalls():
    """§IX C3: fine-grained sharding avoids up to 2.0x from collective
    stalls (global-barrier ablation at the 405B/428CU scale)."""
    r_fg = _sim("llama3-405b", n_cus=428, batch=1, seq=8192)
    r_gb = _sim("llama3-405b", n_cus=428, batch=1, seq=8192,
                fine_grained_net=False)
    ratio = r_gb.latency_s / r_fg.latency_s
    assert 1.3 < ratio < 2.3, ratio


def test_batch32_slower_than_batch1():
    """Fig 8: BS=32 per-token latency multiples of BS=1 (KV$ serialization)."""
    r1 = _sim("llama3-8b", batch=1, seq=16384)
    r32 = _sim("llama3-8b", batch=32, seq=8192)
    ratio = r32.latency_s / r1.latency_s
    assert 3.0 < ratio < 20.0


def test_peak_latency_points_vs_paper():
    """§VIII: 70B @ 204 CUs ~ 0.4 ms/tok; 405B @ 428 CUs ~ 1.0 ms/tok;
    Scout @ 128 CUs ~ 0.2 ms/tok.  Allow 50% modeling slack."""
    p70 = rpu_point(get_config("llama3-70b"), 204, batch=1, seq_len=8192)
    assert p70.ms_per_token == pytest.approx(0.4, rel=0.5)
    p405 = rpu_point(get_config("llama3-405b"), 428, batch=1, seq_len=8192)
    assert p405.ms_per_token == pytest.approx(1.0, rel=0.5)
    scout = rpu_point(get_config("llama4-scout-109b-a17b"), 128, batch=1,
                      seq_len=8192)
    assert scout.ms_per_token == pytest.approx(0.2, rel=0.6)


def test_iso_tdp_headline_405b():
    """§VIII headline: 45.3x lower latency vs 4xH100 at ISO-TDP (2800W).
    Require the same order: 30x-60x."""
    r = iso_tdp_comparison(get_config("llama3-405b"), batch=1, seq_len=8192)
    assert r["n_gpus"] == 4
    assert 30.0 < r["speedup"] < 60.0, r["speedup"]
    assert abs(r["rpu_tdp_w"] - r["gpu_tdp_w"]) / r["gpu_tdp_w"] < 0.25
    assert r["energy_ratio"] > 5.0


def test_strong_scaling_monotone_then_plateau():
    """Latency falls with CU count until the activation broadcast
    dominates, then plateaus (paper: 'Beyond these scales, performance
    plateaus as broadcasting the activation becomes the bottleneck')."""
    cfg = get_config("llama3-70b")
    pts = strong_scaling(cfg, [32, 64, 128, 256, 512], batch=1, seq_len=8192)
    lat = [p.ms_per_token for p in pts]
    assert lat[1] < lat[0] and lat[2] < lat[1] and lat[3] < lat[2]
    # diminishing returns into the plateau: the last doubling gains much
    # less than the first (and may even regress slightly).
    gain_first = lat[0] / lat[1]
    gain_last = lat[-2] / lat[-1]
    assert gain_last < gain_first
    assert lat[-1] < lat[0]


def test_gpu_decode_utilization_calibration():
    """§II: H100 sustains ~32% of peak HBM bandwidth in distributed decode."""
    cfg = get_config("llama3-405b")
    g = gpu_decode_latency(cfg, GPUSystemConfig(n_gpus=4), batch=1,
                           seq_len=8192)
    assert g.bw_utilization == pytest.approx(0.32, abs=0.08)


def test_system_cost_components():
    c = system_cost(64, CANDIDATE_CO)
    assert c["total"] == pytest.approx(sum(
        c[k] for k in ("silicon", "memory", "substrate", "pcb")))
    assert c["memory"] > 0 and c["silicon"] > 0


def test_min_cus_scales_with_model():
    small = min_cus_for_model(get_config("llama3-8b"))
    big = min_cus_for_model(get_config("llama3-405b"))
    assert big > small
