"""``LLMEngine`` — one request-level generation front-end.

The paper's serving scenario is many concurrent reasoning requests with
long sampled output streams; the execution strategy underneath (static
batch scan, continuous batching over paged KV, speculative draft/target)
is a deployment decision, not an API.  ``LLMEngine`` is the single seam:

    llm = LLMEngine(model, params, backend="continuous", max_len=256,
                    num_slots=8)
    outs = llm.generate(prompts, SamplingParams(temperature=0.8, top_p=0.9,
                                                seed=7, max_tokens=64))

Every backend takes the same per-request ``SamplingParams`` and returns
the same structured ``RequestOutput`` list (token ids, finish_reason,
optional logprobs, timing metrics).  Greedy requests are token-exact
across all three backends; sampled requests are reproducible across the
static and continuous backends (fold_in(seed, pos) streams — see
``runtime.sampling``).  The continuous backend additionally streams
incremental deltas through ``on_output`` / the ``add_request()``/
``step()`` interface; static and speculative execution have no per-token
host loop (that is their point), so they emit one final output per
request.

Deployment sizing is hardware-aware: pass a ``DeploymentSpec``
(``runtime.deployment``) and the paged-KV pool, decode-slot count, and
admission hints derive from the named SKU / HBM-CO stack / weight format
instead of hand-tuned kwargs::

    llm = LLMEngine(model, params,
                    spec=DeploymentSpec(sku="rpu-cu", hbmco="hbmco-768MB",
                                        weight_format="mxfp4",
                                        max_len=4096))
    print(llm.deployment.describe())

Stateful cache layouts (SWA ring pages, SSM state pools —
``runtime.state_cache``) serve through the same façade: the continuous
engine classifies the model's plan and sizes ring/state pools itself.
Future backends (real-TPU serving) plug in behind this façade instead of
growing new ad-hoc entrypoints.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.runtime import sampling
from repro.runtime.engine import (
    ContinuousServeEngine, DisaggServeEngine, RequestOutput, ServeEngine,
)
from repro.runtime.sampling import SamplingParams
from repro.runtime.scheduler import Request

BACKENDS = ("static", "continuous", "speculative")


def _truncate(tokens: list[int], sp: SamplingParams,
              budget: int) -> tuple[list[int], str]:
    """Apply stop-token / budget finish semantics to a pre-generated
    stream (the static scan and speculative windows have fixed trip
    counts; the host applies the finish reason afterwards)."""
    tokens = tokens[:budget]
    for j, t in enumerate(tokens):
        if t in sp.stop_token_ids:
            return tokens[:j + 1], "stop"
    return tokens, "length"


class LLMEngine:
    """One ``generate(prompts, sampling_params)`` API over static,
    continuous, and speculative execution."""

    def __init__(self, model: Model, params: Any, *,
                 backend: str = "continuous", spec=None,
                 max_len: int | None = None,
                 num_slots: int | None = None, page_size: int | None = None,
                 num_pages: int | None = None,
                 prefill_chunk: int | None = None,
                 enable_prefix_cache: bool = True, cache_dtype=None,
                 weight_format: str | None = None,
                 max_top_k: int = sampling.MAX_TOP_K,
                 draft_model: Model | None = None, draft_params: Any = None,
                 gamma: int = 8, speculative=None,
                 default_sampling: SamplingParams | None = None,
                 mesh=None, tp_reduce: str = "auto",
                 disaggregate: bool = False,
                 prefill_mesh=None, decode_mesh=None,
                 prefill_slots: int | None = None,
                 prefill_pages: int | None = None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {backend!r}")
        if disaggregate and backend != "continuous":
            raise ValueError("disaggregate=True splits the continuous "
                             "backend into phase engines; other backends "
                             "have no prefill/decode split to make")
        if mesh is not None and backend != "continuous":
            raise ValueError(
                "mesh= shards the continuous paged serve path; run the "
                f"{backend!r} backend under an ambient mesh + sharding_rules "
                "context instead")
        if spec is not None and spec.mesh is not None \
                and backend != "continuous":
            raise ValueError("spec.mesh shards the continuous backend only")
        if speculative is not None and backend != "continuous":
            raise ValueError(
                "speculative= configures scheduler-integrated speculation "
                "in the continuous engine; the legacy 'speculative' "
                "backend takes draft_model=/draft_params=/gamma= directly")
        if spec is None:
            # legacy knob defaults (the pre-DeploymentSpec hand-tuned path)
            max_len = 256 if max_len is None else max_len
            num_slots = 8 if num_slots is None else num_slots
            page_size = 16 if page_size is None else page_size
            prefill_chunk = 64 if prefill_chunk is None else prefill_chunk
        elif max_len is None:
            max_len = spec.max_len
        self.model = model
        self.params = params
        self.backend = backend
        self.max_len = max_len
        self.default_sampling = default_sampling or sampling.GREEDY
        self.max_top_k = int(max_top_k)
        self.last_stats = None          # ContinuousStats of the last run
        if backend == "continuous":
            if spec is None and num_pages is None:
                num_pages = 1 + 2 * num_slots * -(-max_len // page_size)
            if disaggregate:
                self._eng = DisaggServeEngine(
                    model, params, num_slots=num_slots, page_size=page_size,
                    num_pages=num_pages, max_len=max_len, spec=spec,
                    prefill_mesh=prefill_mesh if prefill_mesh is not None
                    else mesh,
                    decode_mesh=decode_mesh if decode_mesh is not None
                    else mesh,
                    prefill_slots=prefill_slots, prefill_pages=prefill_pages,
                    sampling_params=self.default_sampling,
                    cache_dtype=cache_dtype, weight_format=weight_format,
                    prefill_chunk=prefill_chunk,
                    enable_prefix_cache=enable_prefix_cache,
                    max_top_k=self.max_top_k, tp_reduce=tp_reduce,
                    speculative=speculative)
            else:
                self._eng = ContinuousServeEngine(
                    model, params, num_slots=num_slots, page_size=page_size,
                    num_pages=num_pages, max_len=max_len, spec=spec,
                    sampling_params=self.default_sampling,
                    cache_dtype=cache_dtype, weight_format=weight_format,
                    prefill_chunk=prefill_chunk,
                    enable_prefix_cache=enable_prefix_cache,
                    max_top_k=self.max_top_k, mesh=mesh, tp_reduce=tp_reduce,
                    speculative=speculative)
        elif backend == "static":
            self._eng = ServeEngine(
                model, params, max_len=max_len, spec=spec,
                sampling_params=self.default_sampling, donate_cache=False,
                cache_dtype=cache_dtype, weight_format=weight_format,
                max_top_k=self.max_top_k)
        else:                            # speculative (legacy dense-cache)
            # with no draft the target drafts for itself ("ideal draft"):
            # every window accepts, output equals the target-only stream.
            # One SpeculativeEngine for the LLMEngine's lifetime: the
            # prefill jits and per-SamplingParams window jits are cached,
            # so repeated prompts stop re-tracing.
            from repro.runtime.speculative import SpeculativeEngine
            self.draft_model = draft_model or model
            self.draft_params = draft_params if draft_model is not None \
                else params
            self.gamma = gamma
            # a DeploymentSpec sizes this backend too (max_len came from it
            # above); the budget is priced with the draft's weights and
            # pool bytes, and the resolved point is kept for inspection
            self._speculative_deployment = (
                spec.resolve(model, params=params, draft=self.draft_model,
                             draft_params=self.draft_params, gamma=gamma)
                if spec is not None else None)
            self._spec = SpeculativeEngine(
                self.draft_model, self.draft_params, model, params,
                gamma=gamma)
            self._eng = None

    # -- mesh introspection (continuous backend) ----------------------------
    @property
    def serve_plan(self):
        """The engine's ``PagedServePlan`` (None off-mesh / other backends)."""
        return getattr(self._eng, "serve_plan", None)

    @property
    def deployment(self):
        """The resolved ``DeploymentSpec`` budget (None without spec=)."""
        if self._eng is None:              # legacy speculative backend
            return self._speculative_deployment
        return getattr(self._eng, "deployment", None)

    def kv_token_bytes_per_device(self) -> int:
        """Per-device pool bytes one cached token costs (continuous only)."""
        if self.backend != "continuous":
            raise ValueError("KV accounting needs backend='continuous'")
        return self._eng.kv_token_bytes_per_device()

    # -- request plumbing ---------------------------------------------------
    def _resolve(self, prompts, sampling_params, max_new_tokens):
        prompts = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        n = len(prompts)
        if sampling_params is None:
            sps = [self.default_sampling] * n
        elif isinstance(sampling_params, SamplingParams):
            sps = [sampling_params] * n
        else:
            sps = list(sampling_params)
            if len(sps) != n:
                raise ValueError(f"{len(sps)} SamplingParams for "
                                 f"{n} prompts")
        budgets = []
        for p, sp in zip(prompts, sps):
            budget = sp.max_tokens if sp.max_tokens is not None \
                else max_new_tokens
            if budget is None:
                raise ValueError("set SamplingParams.max_tokens or pass "
                                 "max_new_tokens")
            # the continuous engine enforces its own (page-rounded)
            # capacity in add_request; static caches are exactly max_len
            if (self.backend != "continuous"
                    and p.shape[0] + budget > self.max_len):
                raise ValueError(f"max_tokens={budget} exceeds max_len="
                                 f"{self.max_len} for a {p.shape[0]}-token "
                                 f"prompt")
            budgets.append(int(budget))
        return prompts, sps, budgets

    # -- incremental interface (continuous backend) -------------------------
    def add_request(self, prompt, sampling_params: SamplingParams | None = None,
                    *, rid: int | None = None, max_new_tokens: int | None = None,
                    arrival_time: float = 0.0) -> int:
        """Submit one request to the continuous engine; returns its rid.
        Drive with ``step()`` until ``has_unfinished()`` is False."""
        if self.backend != "continuous":
            raise ValueError("add_request()/step() need backend='continuous'")
        (prompt,), (sp,), (budget,) = self._resolve(
            [prompt], sampling_params, max_new_tokens)
        if rid is None:
            rid = getattr(self, "_next_rid", 0)
        # explicit low rids must never rewind the auto-rid counter into
        # collision with live requests
        self._next_rid = max(getattr(self, "_next_rid", 0), rid + 1)
        self._eng.add_request(Request(rid=rid, prompt=prompt,
                                      max_new_tokens=budget, sampling=sp,
                                      arrival_time=arrival_time))
        return rid

    def step(self) -> list[RequestOutput]:
        if self.backend != "continuous":
            raise ValueError("add_request()/step() need backend='continuous'")
        return self._eng.step()

    def has_unfinished(self) -> bool:
        return self.backend == "continuous" and self._eng.has_unfinished()

    # -- one-shot interface (all backends) ----------------------------------
    def generate(self, prompts: Iterable, sampling_params=None, *,
                 max_new_tokens: int | None = None,
                 arrival_times: Sequence[float] | None = None,
                 on_output: Callable[[RequestOutput], None] | None = None
                 ) -> list[RequestOutput]:
        """Generate for ``prompts`` (sequences of token ids); returns one
        final ``RequestOutput`` per prompt, in order.

        ``sampling_params``: one ``SamplingParams`` or a per-prompt list.
        ``arrival_times`` (continuous only) replays a ragged arrival trace.
        ``on_output`` streams incremental deltas (continuous) or final
        outputs as each request completes (static / speculative)."""
        prompts, sps, budgets = self._resolve(prompts, sampling_params,
                                              max_new_tokens)
        if arrival_times is not None and self.backend != "continuous":
            raise ValueError("arrival_times needs backend='continuous'")
        if self.backend == "continuous":
            return self._generate_continuous(prompts, sps, budgets,
                                             arrival_times, on_output)
        if self.backend == "static":
            return self._generate_static(prompts, sps, budgets, on_output)
        return self._generate_speculative(prompts, sps, budgets, on_output)

    def _generate_continuous(self, prompts, sps, budgets, arrival_times,
                             on_output):
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i],
                        sampling=sps[i],
                        arrival_time=(float(arrival_times[i])
                                      if arrival_times is not None else 0.0))
                for i in range(len(prompts))]
        stats = self._eng.run(reqs, on_output=on_output)
        self.last_stats = stats
        return [stats.outputs[i] for i in range(len(prompts))]

    def _generate_static(self, prompts, sps, budgets, on_output):
        lens = {p.shape[0] for p in prompts}
        if len(lens) != 1:
            raise ValueError(
                "backend='static' batches one prompt length per call "
                f"(got {sorted(lens)}); use backend='continuous' for "
                "ragged prompts")
        batch = jnp.asarray(np.stack(prompts))
        res = self._eng.generate({"tokens": batch},
                                 max_new_tokens=max(budgets),
                                 sampling_params=sps)
        plps = None
        if any(sp.prompt_logprobs for sp in sps):
            # score the prompt with one jitted forward: position k's
            # log-softmax row scores prompt token k+1 (raw model scores —
            # the generation-side processors don't apply to the prompt)
            logits = jax.jit(self.model.forward)(self._eng.params,
                                                 {"tokens": batch})
            ls = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            plps = np.asarray(jnp.take_along_axis(
                ls[:, :-1], batch[:, 1:, None], axis=-1)[..., 0])
        toks = np.asarray(res.tokens)
        outs = []
        for i, sp in enumerate(sps):
            ids, reason = _truncate([int(t) for t in toks[i]], sp, budgets[i])
            lps = ([float(v) for v in np.asarray(res.logprobs)[i, :len(ids)]]
                   if sp.logprobs else None)
            out = RequestOutput(rid=i, new_token_ids=list(ids),
                                token_ids=list(ids), finished=True,
                                finish_reason=reason, logprobs=lps,
                                prompt_logprobs=(
                                    [float(v) for v in plps[i]]
                                    if sp.prompt_logprobs else None),
                                metrics={})
            outs.append(out)
            if on_output is not None:
                on_output(out)
        return outs

    def _generate_speculative(self, prompts, sps, budgets, on_output):
        for sp in sps:
            if sp.repetition_penalty != 1.0 or sp.logit_bias:
                raise ValueError(
                    "backend='speculative' does not support "
                    "repetition_penalty/logit_bias (the continuous "
                    "engine's speculative= mode does — its verify step "
                    "threads the running presence through p and q)")
            if sp.prompt_logprobs:
                raise ValueError(
                    "backend='speculative' does not score prompts; use "
                    "backend='static' or 'continuous' for prompt_logprobs")
        outs = []
        for i, (p, sp, budget) in enumerate(zip(prompts, sps, budgets)):
            stats = self._spec.generate(
                jnp.asarray(p)[None], max_new_tokens=budget,
                sampling_params=sp, key=jax.random.PRNGKey(sp.seed))
            ids, reason = _truncate([int(t) for t in stats.tokens[:budget]],
                                    sp, budget)
            out = RequestOutput(
                rid=i, new_token_ids=list(ids), token_ids=list(ids),
                finished=True, finish_reason=reason, logprobs=None,
                metrics={"windows": stats.windows,
                         "accepted_per_window": stats.mean_accepted})
            outs.append(out)
            if on_output is not None:
                on_output(out)
        return outs
