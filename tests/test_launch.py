"""Launch layer: dry-run cell in a clean subprocess (512 host devices),
multi-device EP correctness, and the train/serve driver entry points."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(code: str, timeout=900):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=ENV, capture_output=True, text=True,
                          timeout=timeout, cwd=REPO)


def test_dryrun_cell_subprocess():
    """One full dry-run cell: 512 host devices, 16x16 mesh, lower+compile,
    memory & roofline artifacts."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-370m",
         "--shape", "decode_32k", "--single-pod"],
        env=ENV, capture_output=True, text=True, timeout=900, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout and "dominant=" in r.stdout


def test_dryrun_skip_semantics():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "hubert-xlarge", "--shape", "decode_32k", "--single-pod"],
        env=ENV, capture_output=True, text=True, timeout=300, cwd=REPO)
    assert r.returncode == 0
    assert "SKIP" in r.stdout and "encoder-only" in r.stdout


def test_moe_ep_multidevice():
    """Expert-parallel MoE == dense reference on a real 2x4 device mesh."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.models import moe as moe_lib
        key = jax.random.PRNGKey(0)
        cfg = reduced_config(get_config('deepseek-v2-lite-16b'))
        p = moe_lib.init_moe(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (4, 16, cfg.d_model), jnp.bfloat16)
        dense = moe_lib.moe_dense(x, p, cfg)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        with mesh:
            ep = jax.jit(lambda x, p: moe_lib.moe_ep(
                x, p, cfg, mesh, 'model',
                capacity_factor=float(cfg.n_experts)))(x, p)
        err = float(jnp.max(jnp.abs(ep.astype(np.float32)
                                    - dense.astype(np.float32))))
        assert err < 0.1, err
        print('ok', err)
    """)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ok" in r.stdout


@pytest.mark.slow
def test_train_launcher(tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "qwen3-14b", "--steps", "6", "--batch", "2",
               "--seq", "32", "--ckpt-dir", str(tmp_path / "ckpt")])
    assert rc == 0


def test_serve_launcher():
    from repro.launch.serve import main
    rc = main(["--arch", "h2o-danube-1.8b", "--batch", "2",
               "--prompt-len", "16", "--max-new", "8"])
    assert rc == 0


def test_cache_update_at_matches_dus():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models.common import cache_update_at
    key = jax.random.PRNGKey(0)
    cache = jax.random.normal(key, (2, 16, 4, 8), jnp.bfloat16)
    new = jax.random.normal(jax.random.fold_in(key, 1), (2, 1, 4, 8),
                            jnp.bfloat16)
    for slot in (0, 7, 15):
        a = cache_update_at(cache, new, jnp.int32(slot))
        b = jax.lax.dynamic_update_slice(cache, new, (0, slot, 0, 0))
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
