"""Llama3-405B (paper simulator baseline)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense", n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, head_dim=128, d_ff=53248,
    vocab_size=128256, vocab_pad_multiple=512, rope_theta=500000.0,
)
