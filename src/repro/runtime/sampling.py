"""Request-level sampling for the serve path (fp32 HP-VOPs analogue).

``SamplingParams`` is the per-request generation contract shared by every
engine front-end (static, continuous, speculative).  The batched per-slot
sampler ``sample_slots`` runs *inside* the jitted decode step: per-slot
temperature / top-k / top-p / min-p / seed live as ``(num_slots,)`` data
arrays — changing the request mix never changes the jit signature, so an
arbitrary blend of greedy and sampled requests shares one compiled step.

Reproducibility invariant: each request draws the token at sequence index
``pos`` from its own ``fold_in(PRNGKey(seed), pos)`` stream.  The key is a
function of (seed, position) only — not the slot, not the step the engine
happened to batch it into — so a restart-style preemption re-emits the
SAME sampled tokens (extending the greedy-restart invariant to stochastic
decoding), and slot permutations / static-vs-continuous execution agree
token for token.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# Static cap for the per-slot top-k threshold: one ``lax.top_k(lg, MAX_TOP_K)``
# yields the k-th-largest value for every per-slot k <= MAX_TOP_K as a data
# lookup, keeping per-request k out of the jit signature.
MAX_TOP_K = 64

# Static budget for the standalone helpers' top-p nucleus scan: cumulative
# mass is taken over the ``lax.top_k(p, TOP_P_BUDGET)`` prefix instead of a
# full-vocab sort (XLA:CPU sorts are ~20x slower than top_k at serving
# vocab sizes).  Exact whenever the nucleus fits the budget; if a
# (near-flat) distribution spills past it, the filter degrades soundly to
# keep-everything.
TOP_P_BUDGET = 512

# Candidate-set width of the fused per-slot sampler: ONE
# ``lax.top_k(logits, SLOT_CANDIDATES)`` supplies the greedy argmax, every
# per-slot top-k threshold, the top-p nucleus scan, and the draw
# candidates, so the whole sampler runs in a (B, 128) subspace with a
# single full-vocab reduction (the greedy-logprob normalizer).  Sampling
# is truncated to the 128 most probable tokens: exact for any top-k <=
# MAX_TOP_K (the kept set then lies inside the subspace, so the top-p
# nucleus matches ``dist``); with top-k off, the distribution — and hence
# the nucleus scale — is renormalized over the subspace, dropping the deep
# tail (a standard serving trade-off), which keeps the sampler well under
# 5% of decode-step latency (benchmarks/sampling_overhead.py).
SLOT_CANDIDATES = 128

# Static per-slot budget for token-level logit biases: each request's
# ``logit_bias`` map is stacked into ``(num_slots, MAX_LOGIT_BIAS)``
# token-id/value data arrays (rows padded with id -1), so any mix of
# biased and unbiased requests shares the one compiled decode step.
MAX_LOGIT_BIAS = 8

# Speculative-decoding PRNG stream tags: every draw inside a draft/verify
# window folds one of these into ``token_key(seed, pos)`` where ``pos`` is
# the sequence index of the token being decided — a pure function of
# (seed, index), so preemption restarts reproduce the same proposals,
# acceptance coin flips, and correction draws regardless of how windows
# re-align after the restart (they re-align identically: window boundaries
# are themselves deterministic in these streams).
TAG_PROPOSE, TAG_ACCEPT, TAG_CORRECT = 1, 2, 3


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters.

    temperature  0.0 = greedy; > 0 scales logits before sampling.
    top_k        0 = disabled; else sample among the k highest logits
                 (engines cap k at their static ``max_top_k``).
    top_p        nucleus sampling: keep the smallest prefix of the sorted
                 distribution with cumulative mass >= top_p (1.0 = off).
    min_p        drop tokens below ``min_p * max_prob`` (0.0 = off).
    seed         PRNG stream id; token at position ``pos`` is drawn with
                 ``fold_in(PRNGKey(seed), pos)`` (see module docstring).
    stop_token_ids  generation finishes ("stop") when one is emitted.
    max_tokens   generation budget; finishes with reason "length".
                 None defers to the caller's ``max_new_tokens``.
    logprobs     return the chosen token's logprob under the final
                 (filtered, temperature-scaled) distribution.
    repetition_penalty  CTRL-style: logits of tokens already present in
                 the request's stream (prompt + generated) are divided by
                 the penalty when positive, multiplied when negative
                 (1.0 = off).  Applied before temperature.
    logit_bias   additive per-token logit offsets, as a ``{token_id:
                 bias}`` mapping or ``((token_id, bias), ...)`` pairs; at
                 most ``MAX_LOGIT_BIAS`` entries per request (the static
                 per-slot data-array width).  Applied before filtering.
    prompt_logprobs  also score the prompt: ``RequestOutput
                 .prompt_logprobs[k]`` is the RAW model logprob (no
                 temperature / filtering / penalties) of prompt token
                 ``k + 1`` given tokens ``0..k`` — ``prompt_len - 1``
                 entries.  Continuous-engine requests with this set skip
                 prefix-cache sharing (shared pages are never re-scored).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    min_p: float = 0.0
    seed: int = 0
    stop_token_ids: tuple[int, ...] = ()
    max_tokens: int | None = None
    logprobs: bool = False
    repetition_penalty: float = 1.0
    logit_bias: tuple[tuple[int, float], ...] = ()
    prompt_logprobs: bool = False

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if not 0.0 <= self.min_p < 1.0:
            raise ValueError(f"min_p must be in [0, 1), got {self.min_p}")
        if not 0 <= self.seed < 2 ** 31:   # lives in int32 slot tensors
            raise ValueError(f"seed must be in [0, 2^31), got {self.seed}")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.repetition_penalty <= 0.0:
            raise ValueError(f"repetition_penalty must be > 0, got "
                             f"{self.repetition_penalty}")
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))
        bias = self.logit_bias
        if isinstance(bias, dict):
            bias = tuple(bias.items())
        bias = tuple((int(t), float(v)) for t, v in bias)
        if len(bias) > MAX_LOGIT_BIAS:
            raise ValueError(f"logit_bias holds {len(bias)} entries; the "
                             f"static per-slot budget is {MAX_LOGIT_BIAS}")
        if any(t < 0 for t, _ in bias):
            raise ValueError("logit_bias token ids must be >= 0")
        object.__setattr__(self, "logit_bias", bias)

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def token_key(seed, pos):
    """The PRNG key for the token at sequence index ``pos`` of stream
    ``seed`` — the whole reproducibility invariant lives here."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), pos)


def _topp_threshold(probs: jnp.ndarray, top_p,
                    budget: int = TOP_P_BUDGET) -> jnp.ndarray:
    """Smallest kept probability of the top-p nucleus, per row.

    probs (..., V); top_p broadcastable to (...,).  An entry is in the
    nucleus iff the cumulative mass of strictly-larger entries is < top_p,
    so the max-prob token is always kept and top_p=1.0 keeps everything.
    The scan runs over the descending ``lax.top_k`` prefix of ``budget``
    entries (no full-vocab sort); a nucleus spilling past the budget keeps
    everything (threshold 0)."""
    v = probs.shape[-1]
    budget = min(budget, v)
    tops = jax.lax.top_k(probs, budget)[0]             # descending
    cum = jnp.cumsum(tops, axis=-1)
    top_p = jnp.asarray(top_p)[..., None]
    keep = (cum - tops) < top_p
    thresh = jnp.min(jnp.where(keep, tops, jnp.inf), axis=-1)
    if budget == v:
        return thresh
    spilled = cum[..., -1] < top_p[..., 0]
    return jnp.where(spilled, 0.0, thresh)


def sample(key, logits: jnp.ndarray, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 1.0,
           min_p: float = 0.0) -> jnp.ndarray:
    """Single-distribution sampling with static (Python-level) params.

    logits: (..., V) -> (...) int32.  top-k uses ``jax.lax.top_k``
    (O(V log k)) rather than a full vocab sort."""
    if temperature <= 0.0:
        return greedy(logits)
    lg = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(lg, min(top_k, lg.shape[-1]))[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    if top_p < 1.0 or min_p > 0.0:
        p = jax.nn.softmax(lg, axis=-1)
        keep = p >= _topp_threshold(p, top_p)[..., None] if top_p < 1.0 \
            else jnp.ones_like(p, bool)
        if min_p > 0.0:
            keep &= p >= min_p * jnp.max(p, axis=-1, keepdims=True)
        lg = jnp.where(keep, lg, -jnp.inf)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def probs(logits: jnp.ndarray, temperature: float = 1.0) -> jnp.ndarray:
    return jax.nn.softmax(logits.astype(jnp.float32) / max(temperature, 1e-6),
                          axis=-1)


def dist(logits: jnp.ndarray, params: SamplingParams) -> jnp.ndarray:
    """The full filtered distribution a request samples from: (..., V) probs.

    Greedy requests get an exact one-hot at the argmax (not a sharpened
    softmax), so draft/target acceptance ratios in speculative decoding are
    well-defined at temperature 0.  Draft proposals MUST be drawn from this
    same distribution (via ``draw``) for the acceptance rule to be correct
    under top-k/top-p filtering."""
    lg = logits.astype(jnp.float32)
    if params.is_greedy:
        return jax.nn.one_hot(jnp.argmax(lg, -1), lg.shape[-1],
                              dtype=jnp.float32)
    lg = lg / params.temperature
    if params.top_k:
        kth = jax.lax.top_k(lg, min(params.top_k, lg.shape[-1]))[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    p = jax.nn.softmax(lg, axis=-1)
    if params.top_p < 1.0 or params.min_p > 0.0:
        keep = p >= _topp_threshold(p, params.top_p)[..., None]
        if params.min_p > 0.0:
            keep &= p >= params.min_p * jnp.max(p, axis=-1, keepdims=True)
        p = jnp.where(keep, p, 0.0)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p


def draw(key, dist: jnp.ndarray) -> jnp.ndarray:
    """Sample token ids from an explicit distribution (..., V) -> (...)."""
    return jax.random.categorical(
        key, jnp.log(jnp.maximum(dist, 1e-20)), axis=-1).astype(jnp.int32)


def apply_processors(logits: jnp.ndarray, rep_penalty=None, bias_ids=None,
                     bias_vals=None, presence=None) -> jnp.ndarray:
    """Per-slot logit processors shared by every sampler entry point.

    logits: (B, V) -> f32 (B, V) with additive ``logit_bias`` offsets and
    the CTRL-style repetition penalty applied (positive logits of tokens
    marked in ``presence`` divide by the penalty, negative multiply).  The
    speculative verify path calls this once per window position with the
    RUNNING presence row, so the p/q acceptance ratio sees exactly the
    penalized logits the sequential engine would have sampled from."""
    lg = logits.astype(jnp.float32)
    if bias_ids is not None:
        rows = jnp.arange(lg.shape[0])
        okb = bias_ids >= 0
        bias = jnp.zeros_like(lg).at[
            rows[:, None], jnp.where(okb, bias_ids, 0)].add(
            jnp.where(okb, bias_vals, 0.0))
        lg = lg + bias
    if presence is not None:
        pen = rep_penalty[:, None]
        lg = jnp.where(presence, jnp.where(lg > 0, lg / pen, lg * pen), lg)
    return lg


def slot_dist(lg: jnp.ndarray, temperature, top_k, top_p, min_p, *,
              max_top_k: int = MAX_TOP_K) -> jnp.ndarray:
    """The full per-slot filtered distribution ``sample_slots`` draws from.

    lg: (B, V) PROCESSED logits (``apply_processors`` already applied);
    temperature/top_p/min_p (B,) f32, top_k (B,) i32 — all data.  Returns
    (B, V) probabilities: greedy rows (temperature <= 0) are exact
    one-hots at the argmax; sampled rows reproduce ``sample_slots``'s
    candidate-subspace semantics exactly (per-slot top-k rank cut, top-p
    nucleus, min-p, all within the ``SLOT_CANDIDATES`` subspace and
    renormalized over it), scattered back to full-vocab token ids.

    This is the batched analogue of ``dist`` for the speculative
    continuous engine: draft proposals are drawn FROM this distribution
    (``slot_draw``), and the target scores with the same filtering, so
    the min(1, p/q) acceptance ratio is exact under any per-slot
    ``SamplingParams`` mix — including repetition penalty and logit bias,
    which enter through ``apply_processors`` on both sides."""
    b, v = lg.shape
    rows = jnp.arange(b)
    is_greedy = temperature <= 0.0
    kmax = min(int(max_top_k), v)
    budget = min(max(kmax, SLOT_CANDIDATES), v)
    # indices ARE needed here (the subspace dist scatters back to token
    # ids); this path runs a handful of times per speculative window, not
    # in the single-token hot loop, so the CPU variadic-sort penalty of
    # touching top_k's indices output is acceptable
    tops, idxs = jax.lax.top_k(lg, budget)      # (B, budget) descending
    s = tops / jnp.where(is_greedy, 1.0, temperature)[:, None]
    k = jnp.clip(top_k, 0, kmax)
    ranks = jnp.arange(budget)[None, :]
    keep = (k == 0)[:, None] | (ranks < k[:, None])
    z = jax.nn.logsumexp(jnp.where(keep, s, -jnp.inf), axis=-1,
                         keepdims=True)
    p = jnp.where(keep, jnp.exp(s - z), 0.0)
    cum = jnp.cumsum(p, axis=-1)
    keep &= (cum - p) < top_p[:, None]
    keep &= p >= min_p[:, None] * p[:, :1]
    w = jnp.where(keep, p, 0.0)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-38)
    out = jnp.zeros((b, v), jnp.float32).at[rows[:, None], idxs].set(w)
    one_hot = jax.nn.one_hot(jnp.argmax(lg, axis=-1), v, dtype=jnp.float32)
    return jnp.where(is_greedy[:, None], one_hot, out)


def slot_draw(dist: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Invert per-slot uniforms through a distribution's CDF.

    dist: (B, V) probabilities; u: (B,) uniforms in [0, 1) -> (B,) i32
    token ids.  One-hot rows return their argmax for every ``u`` (greedy
    slots never consume entropy)."""
    cum = jnp.cumsum(dist, axis=-1)
    total = cum[:, -1]
    r = jnp.sum(cum <= (u * total)[:, None], axis=-1)
    return jnp.minimum(r, dist.shape[-1] - 1).astype(jnp.int32)


def spec_uniform(seed, pos, tag: int) -> jnp.ndarray:
    """One uniform per (seed, pos) pair from the tagged speculative stream
    ``fold_in(token_key(seed, pos), tag)`` — see TAG_PROPOSE/ACCEPT/
    CORRECT.  ``seed`` and ``pos`` broadcast against each other; the
    result has the broadcast shape."""
    seed, pos = jnp.broadcast_arrays(jnp.asarray(seed), jnp.asarray(pos))

    def one(s, p):
        return jax.random.uniform(jax.random.fold_in(token_key(s, p), tag),
                                  ())

    return jax.vmap(one)(seed.ravel(), pos.ravel()).reshape(seed.shape)


def sample_slots(logits: jnp.ndarray, temperature, top_k, top_p, min_p,
                 seed, pos, *, max_top_k: int = MAX_TOP_K,
                 rep_penalty=None, bias_ids=None, bias_vals=None,
                 presence=None):
    """Batched per-slot sampler, fused into the jitted decode step.

    logits: (B, V).  temperature/top_p/min_p: (B,) f32; top_k/seed/pos:
    (B,) i32 (``pos`` broadcastable) — all DATA, so one compiled step
    serves any mix of greedy and sampled slots.  Slots with temperature
    <= 0 take the argmax; everything else draws from the filtered
    temperature-scaled distribution with ``token_key(seed, pos)``.

    Returns (tokens (B,) i32, logprobs (B,) f32) — the chosen token's
    logprob under the distribution it was drawn from (raw softmax for
    greedy slots).

    Hot-path shape: ONE static ``lax.top_k`` extracts the
    ``SLOT_CANDIDATES`` candidate subspace (argmax, per-slot top-k
    thresholds, top-p nucleus scan, and draw candidates all come from it —
    no full-vocab sort, and sampling beyond the candidate set is
    truncated, see ``SLOT_CANDIDATES``); the draw is a single uniform per
    slot inverted through the filtered CDF (no per-token Gumbel noise).
    ``benchmarks/sampling_overhead.py`` holds the whole sampler under 5%
    of decode-step latency.

    Optional per-slot processors (all data, defaults are exact no-ops):
    ``bias_ids``/``bias_vals`` (B, MAX_LOGIT_BIAS) additive logit offsets
    (ids < 0 are padding); ``rep_penalty`` (B,) f32 with ``presence``
    (B, V) bool marking tokens already in each slot's stream — CTRL-style
    penalty (positive logits divide, negative multiply), applied before
    temperature, so greedy slots are penalized too.
    """
    lg = apply_processors(logits, rep_penalty, bias_ids, bias_vals, presence)
    b, v = lg.shape
    rows = jnp.arange(b)
    pos = jnp.broadcast_to(pos, (b,))
    is_greedy = temperature <= 0.0
    kmax = min(int(max_top_k), v)
    budget = min(max(kmax, SLOT_CANDIDATES), v)
    # VALUES-only top_k: touching the indices output from a fused compute
    # chain makes XLA:CPU fall back to a full-vocab variadic sort (~10x
    # slower than the top-k itself); token ids are recovered at the end by
    # matching the drawn value back into the logits row
    tops = jax.lax.top_k(lg, budget)[0]         # (B, budget) descending
    s = tops / jnp.where(is_greedy, 1.0, temperature)[:, None]
    # per-slot top-k is a rank cut in the descending subspace (k == 0
    # disables); top-p / min-p act on the post-top-k renormalized
    # distribution (same order as the standalone ``sample`` / ``dist``)
    k = jnp.clip(top_k, 0, kmax)
    ranks = jnp.arange(budget)[None, :]
    keep = (k == 0)[:, None] | (ranks < k[:, None])
    z = jax.nn.logsumexp(jnp.where(keep, s, -jnp.inf), axis=-1,
                         keepdims=True)
    p = jnp.where(keep, jnp.exp(s - z), 0.0)    # descending within keep
    cum = jnp.cumsum(p, axis=-1)
    keep &= (cum - p) < top_p[:, None]          # nucleus (rank 0 always in)
    keep &= p >= min_p[:, None] * p[:, :1]
    w = jnp.where(keep, p, 0.0)
    # inverse-CDF draw: one uniform per slot from its fold_in(seed, pos)
    # stream, inverted through the filtered distribution's CDF
    wcum = jnp.cumsum(w, axis=-1)
    total = wcum[:, -1]
    keys = jax.vmap(token_key)(seed, pos)
    u = jax.vmap(lambda kk: jax.random.uniform(kk, ()))(keys)
    r = jnp.sum(wcum <= (u * total)[:, None], axis=-1)
    r = jnp.minimum(r, budget - 1)
    # recover the token id by matching the drawn rank's VALUE back into
    # the logits row; exact-equal logits collapse to the lowest index
    # (deterministic; bit-equal logits are vanishingly rare off toy
    # models, and such tokens are equiprobable up to that relabeling)
    chosen = jnp.take_along_axis(tops, r[:, None], axis=1)
    sampled = jnp.argmax(lg == chosen, axis=-1).astype(jnp.int32)
    tok = jnp.where(is_greedy, jnp.argmax(lg, axis=-1).astype(jnp.int32),
                    sampled)
    # chosen-token logprob under the distribution it was drawn from
    lp_greedy = tops[:, 0] - jax.nn.logsumexp(lg, axis=-1)
    lp_sampled = jnp.log(jnp.maximum(w[rows, r], 1e-38)) - jnp.log(total)
    return tok, jnp.where(is_greedy, lp_greedy, lp_sampled)


def stack_params(ps, n: int | None = None):
    """Stack per-request ``SamplingParams`` into per-row data arrays.

    Returns (temperature, top_k, top_p, min_p, seed) numpy arrays of shape
    (n,); rows past ``len(ps)`` are greedy padding."""
    n = len(ps) if n is None else n
    temp = np.zeros((n,), np.float32)
    topk = np.zeros((n,), np.int32)
    topp = np.ones((n,), np.float32)
    minp = np.zeros((n,), np.float32)
    seed = np.zeros((n,), np.int32)
    for i, sp in enumerate(ps):
        temp[i] = sp.temperature
        topk[i] = sp.top_k
        topp[i] = sp.top_p
        minp[i] = sp.min_p
        seed[i] = sp.seed
    return temp, topk, topp, minp, seed


def stack_extras(ps, n: int | None = None):
    """Stack the per-request logit processors into per-row data arrays:
    (rep_penalty (n,) f32, bias_ids (n, MAX_LOGIT_BIAS) i32, bias_vals
    (n, MAX_LOGIT_BIAS) f32).  Padding rows are exact no-ops (penalty
    1.0, bias ids -1)."""
    n = len(ps) if n is None else n
    rep = np.ones((n,), np.float32)
    bias_ids = np.full((n, MAX_LOGIT_BIAS), -1, np.int32)
    bias_vals = np.zeros((n, MAX_LOGIT_BIAS), np.float32)
    for i, sp in enumerate(ps):
        rep[i] = sp.repetition_penalty
        for j, (t, val) in enumerate(sp.logit_bias):
            bias_ids[i, j] = t
            bias_vals[i, j] = val
    return rep, bias_ids, bias_vals


class SlotSampling:
    """Per-slot sampling tensors living alongside the page table.

    Set on admission, cleared on eviction/finish; freed slots fall back to
    greedy so their (masked, scratch-routed) rows stay harmless.  The
    engine hands ``arrays()`` to the jitted step every iteration — data,
    not shapes, so the mix never recompiles."""

    def __init__(self, num_slots: int):
        (self.temperature, self.top_k, self.top_p, self.min_p,
         self.seed) = stack_params([], num_slots)
        (self.rep_penalty, self.bias_ids,
         self.bias_vals) = stack_extras([], num_slots)
        self._device = None

    def set(self, slot: int, sp: SamplingParams) -> None:
        self.temperature[slot] = sp.temperature
        self.top_k[slot] = sp.top_k
        self.top_p[slot] = sp.top_p
        self.min_p[slot] = sp.min_p
        self.seed[slot] = sp.seed
        self.rep_penalty[slot] = sp.repetition_penalty
        self.bias_ids[slot] = -1
        self.bias_vals[slot] = 0.0
        for j, (t, val) in enumerate(sp.logit_bias):
            self.bias_ids[slot, j] = t
            self.bias_vals[slot, j] = val
        self._device = None

    def clear(self, slot: int) -> None:
        self.set(slot, GREEDY)

    def arrays(self):
        # slots mutate only at admit/release; steady-state decode steps
        # reuse the transferred device arrays
        if self._device is None:
            self._device = (
                jnp.asarray(self.temperature), jnp.asarray(self.top_k),
                jnp.asarray(self.top_p), jnp.asarray(self.min_p),
                jnp.asarray(self.seed), jnp.asarray(self.rep_penalty),
                jnp.asarray(self.bias_ids), jnp.asarray(self.bias_vals))
        return self._device
