"""Serving engine + speculative decoding tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models.model import build_model
from repro.runtime import sampling
from repro.runtime.engine import ServeEngine, serve_step_fn
from repro.runtime.speculative import speculative_generate


@pytest.fixture(scope="module")
def small():
    cfg = reduced_config(get_config("qwen3-14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_generate_greedy_matches_manual_loop(small):
    cfg, model, params = small
    B, S, G = 2, 8, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    eng = ServeEngine(model, params, max_len=S + G + 1, donate_cache=False)
    out = eng.generate({"tokens": toks}, max_new_tokens=G)
    assert out.tokens.shape == (B, G)

    # manual teacher loop
    cache = model.init_cache(B, S + G + 1)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    manual = [cur]
    for i in range(G - 1):
        logits, cache = model.decode_step(params, cur, cache, jnp.int32(S + i))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        manual.append(cur)
    manual = jnp.stack(manual, 1)
    np.testing.assert_array_equal(np.asarray(out.tokens), np.asarray(manual))


def test_serve_step_fn_shapes(small):
    cfg, model, params = small
    B, S = 2, 16
    cache = model.init_cache(B, S)
    step = serve_step_fn(model)
    toks, new_cache = step(params, jnp.zeros((B,), jnp.int32), cache,
                           jnp.int32(0))
    assert toks.shape == (B,)
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_sampling_temperature_zero_is_greedy():
    logits = jnp.asarray([[0.1, 2.0, -1.0], [3.0, 0.0, 1.0]])
    t0 = sampling.sample(jax.random.PRNGKey(0), logits, 0.0, 0)
    np.testing.assert_array_equal(np.asarray(t0), [1, 0])


def test_sampling_topk_restricts_support():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[5.0, 4.0, -10.0, -10.0]])
    for i in range(20):
        t = sampling.sample(jax.random.fold_in(key, i), logits, 1.0, 2)
        assert int(t[0]) in (0, 1)


def test_speculative_exact_with_identical_models(small):
    """Draft == target: every speculated token accepted; output == greedy."""
    cfg, model, params = small
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                                cfg.vocab_size)
    G = 8
    stats = speculative_generate(model, params, model, params, prompt,
                                 max_new_tokens=G, gamma=4, temperature=0.0)
    assert float(stats.accepted_per_window.mean()) >= 3.9  # all gamma accepted

    eng = ServeEngine(model, params, max_len=64, donate_cache=False)
    ref = eng.generate({"tokens": prompt}, max_new_tokens=G)
    np.testing.assert_array_equal(np.asarray(stats.tokens[:G]),
                                  np.asarray(ref.tokens[0, :G]))


def test_speculative_correct_with_different_draft(small):
    """Weak draft: output must STILL equal the target-greedy sequence
    (speculative decoding is lossless at temperature 0)."""
    cfg, model, params = small
    draft_cfg = dataclasses.replace(cfg, n_layers=2)
    draft = build_model(draft_cfg)
    dparams = draft.init(jax.random.PRNGKey(9))
    prompt = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                                cfg.vocab_size)
    G = 8
    stats = speculative_generate(draft, dparams, model, params, prompt,
                                 max_new_tokens=G, gamma=4, temperature=0.0)
    eng = ServeEngine(model, params, max_len=64, donate_cache=False)
    ref = eng.generate({"tokens": prompt}, max_new_tokens=G)
    np.testing.assert_array_equal(np.asarray(stats.tokens[:G]),
                                  np.asarray(ref.tokens[0, :G]))
