"""Public op wrapper for the decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def gqa_decode_attention(q, k_cache, v_cache, cur_len, *, block_s: int = 512):
    """(B,H,D) x (B,S,KVH,D) cache -> (B,H,D); kernel when tiles fit,
    jnp oracle otherwise (tiny smoke shapes / ragged S)."""
    s = k_cache.shape[1]
    bs = min(block_s, s)
    if s % bs != 0 or q.shape[1] % k_cache.shape[2] != 0:
        return decode_attention_ref(q, k_cache, v_cache, cur_len)
    return decode_attention(q, k_cache, v_cache, cur_len, block_s=bs,
                            interpret=_on_cpu())
