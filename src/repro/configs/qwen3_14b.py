"""Qwen3-14B — GQA with per-head qk-norm.  [hf:Qwen/Qwen3-14B]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936, vocab_pad_multiple=512,
    qk_norm=True,
    rope_theta=1000000.0,
)
