"""Qwen2.5-14B — GQA with QKV bias.  [hf:Qwen/Qwen2.5-14B]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064, vocab_pad_multiple=512,
    qkv_bias=True,
    rope_theta=1000000.0,
)
