"""Serving launcher: prefill + autonomous decode loop.

The decode loop is ONE jitted ``lax.scan`` (no per-token host dispatch) —
the JAX analogue of the RPU's host-free execution model.  Optionally runs
speculative decoding (paper Fig 14 setup) with a reduced draft model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
      --batch 4 --prompt-len 64 --max-new 32 [--speculative]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.launch.mesh import make_small_mesh
from repro.models.model import build_model
from repro.parallel.hints import sharding_rules
from repro.parallel.plan import make_plan
from repro.runtime.engine import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--speculative", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if not cfg.has_decode:
        print(f"{cfg.name} is encoder-only: no decode step")
        return 1
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    mesh = make_small_mesh()
    plan = make_plan(cfg, mesh, global_batch=args.batch, shape_kind="decode")
    max_len = args.prompt_len + args.max_new

    batch = {"tokens": jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (args.batch, 8, cfg.d_model),
            jnp.bfloat16)
        max_len += 8

    with mesh, sharding_rules(plan.rules()):
        if args.speculative:
            from repro.runtime.speculative import speculative_generate
            import dataclasses
            draft_cfg = dataclasses.replace(
                cfg, name=cfg.name + "-draft",
                n_layers=max(2, cfg.n_layers // 4))
            draft = build_model(draft_cfg)
            draft_params = draft.init(jax.random.fold_in(key, 3))
            t0 = time.time()
            res = speculative_generate(
                draft, draft_params, model, params,
                batch["tokens"][:1], max_new_tokens=args.max_new,
                gamma=4, temperature=args.temperature, key=key)
            dt = time.time() - t0
            acc = float(res.accepted_per_window.mean()) if res.windows else 0.0
            print(f"speculative: accepted/window={acc:.2f} over {res.windows} windows")
            toks = res.tokens[None, :]
        else:
            eng = ServeEngine(model, params, max_len=max_len,
                              temperature=args.temperature)
            t0 = time.time()
            out = eng.generate(batch, max_new_tokens=args.max_new, key=key)
            dt = time.time() - t0
            toks = out.tokens

    n_tok = int(toks.shape[0] * toks.shape[1])
    print(f"arch={cfg.name} batch={args.batch} new_tokens={toks.shape[1]} "
          f"wall={dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile)")
    print("sample:", toks[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
