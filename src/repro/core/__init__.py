"""Core analytical library: the paper's contributions C1/C2 and the TPU
roofline machinery used by the dry-run."""
from repro.core.hardware import TPU_V5E, H100, H200, RPU_DEFAULT, ChipSpec, GPUSpec, RPUChipParams
from repro.core.hbmco import (
    HBMCOConfig, HBM3E_LIKE, CANDIDATE_CO,
    enumerate_design_space, hbmco_by_name, pareto_frontier, select_sku,
)
from repro.core.roofline import RooflineReport, analyze_compiled, parse_collectives, model_flops_estimate
from repro.core import provisioning, sku

__all__ = [
    "TPU_V5E", "H100", "H200", "RPU_DEFAULT", "ChipSpec", "GPUSpec", "RPUChipParams",
    "HBMCOConfig", "HBM3E_LIKE", "CANDIDATE_CO",
    "enumerate_design_space", "hbmco_by_name", "pareto_frontier",
    "select_sku",
    "RooflineReport", "analyze_compiled", "parse_collectives", "model_flops_estimate",
    "provisioning", "sku",
]
