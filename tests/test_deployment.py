"""DeploymentSpec: budget resolution arithmetic, the spec-driven engine
path, capacity-pressure behavior under a deliberately tiny pool, and the
bandwidth-model admission hint."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.hbmco import CANDIDATE_CO, HBM3E_LIKE, HBMCOConfig, \
    hbmco_by_name
from repro.models.model import build_model
from repro.quant import formats
from repro.runtime.deployment import DeploymentError, DeploymentSpec
from repro.runtime.engine import ContinuousServeEngine
from repro.runtime.llm import LLMEngine
from repro.runtime.sampling import SamplingParams
from repro.runtime.scheduler import Request


@pytest.fixture(scope="module")
def small():
    cfg = reduced_config(get_config("qwen3-14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def prompts(small):
    cfg, _, _ = small
    base = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                         cfg.vocab_size))
    return base[np.array([0, 1, 0, 1, 0, 1])]      # 2 distinct -> prefix hits


# ---------------------------------------------------------------------------
# Resolution arithmetic
# ---------------------------------------------------------------------------


def test_hbmco_by_name_named_and_design_space():
    assert hbmco_by_name("hbm3e-like") is HBM3E_LIKE
    assert hbmco_by_name("hbmco-768MB") is CANDIDATE_CO
    c = hbmco_by_name("co-r1c1b1m24")
    assert (c.ranks, c.channels_per_layer, c.banks_per_group,
            c.bank_mb) == (1, 1, 1, 24.0)
    # the paper's candidate knobs reproduce the candidate device numbers
    assert c.capacity_mb == CANDIDATE_CO.capacity_mb
    assert c.bandwidth_gbs == CANDIDATE_CO.bandwidth_gbs
    with pytest.raises(ValueError):
        hbmco_by_name("hbm9-unobtainium")


def test_resolve_budget_arithmetic(small):
    _, model, params = small
    spec = DeploymentSpec(sku="rpu-cu", hbmco="hbmco-768MB",
                          weight_format="mxfp4", cache_dtype=jnp.float32,
                          max_len=64, page_size=8, max_slots=4)
    dep = spec.resolve(model, params=params)
    # the budget split covers the device capacity
    assert dep.weight_bytes_per_device + dep.workspace_bytes \
        + dep.kv_budget_bytes == pytest.approx(dep.device.capacity_bytes)
    # the pool fits inside the KV budget and backs >= one full request
    assert dep.pool_bytes_per_device <= dep.kv_budget_bytes
    assert dep.num_pages - 1 >= -(-dep.max_len // dep.page_size)
    assert 1 <= dep.num_slots <= 4
    assert dep.max_decode_slots >= dep.num_slots
    assert dep.tokens_per_s_ceiling > 0
    # mxfp4 weight budget is the EXACT packed accounting the engine will
    # allocate: quantizable projections at packed_nbytes, everything else
    # (embeddings, norms, biases) at its native width
    from repro.quant.linear import serve_weight_bytes
    assert dep.weight_bytes_per_device == pytest.approx(
        serve_weight_bytes(params, "mxfp4"))
    # ... which is strictly more than the naive all-weights-at-4.25-bits
    # estimate (the non-quantizable leaves stay wide)
    n_weights = sum(leaf.size for leaf in jax.tree.leaves(params))
    assert dep.weight_bytes_per_device > \
        n_weights * formats.bits_per_element("mxfp4") / 8.0
    d = dep.as_dict()
    assert d["num_pages"] == dep.num_pages
    assert "roofline" in dep.describe()


def test_weight_format_shrinks_weight_budget(small):
    _, model, params = small
    base = dict(sku="rpu-cu", hbmco="hbmco-768MB", cache_dtype=jnp.float32,
                max_len=64, page_size=8)
    quant = DeploymentSpec(weight_format="mxfp4", **base).resolve(
        model, params=params)
    native = DeploymentSpec(**base).resolve(model, params=params)
    assert quant.weight_bytes_per_device < native.weight_bytes_per_device
    assert quant.kv_budget_bytes > native.kv_budget_bytes


def test_too_small_sku_raises(small):
    _, model, params = small
    tiny = HBMCOConfig(name="co-tiny", ranks=1, channels_per_layer=1,
                       banks_per_group=1, bank_mb=0.001)     # 32 KB stack
    spec = DeploymentSpec(sku="rpu-cu", hbmco=tiny, stacks_per_device=1,
                          cache_dtype=jnp.float32, max_len=64)
    with pytest.raises(DeploymentError, match="cannot back one"):
        spec.resolve(model, params=params)


def test_gpu_sku_derates_decode_bandwidth(small):
    _, model, params = small
    dep = DeploymentSpec(sku="h100", max_len=64).resolve(model,
                                                         params=params)
    from repro.core import hardware
    assert dep.device.decode_bw == pytest.approx(
        hardware.H100.hbm_bw * hardware.H100.decode_bw_utilization)
    assert dep.device.capacity_bytes == hardware.H100.hbm_capacity


def test_unknown_sku_and_format_raise():
    with pytest.raises(ValueError, match="weight_format"):
        DeploymentSpec(weight_format="int3")
    with pytest.raises(ValueError, match="unknown sku"):
        DeploymentSpec(sku="b200").device_budget()


# ---------------------------------------------------------------------------
# Spec-driven engines
# ---------------------------------------------------------------------------


def _reqs(prompts, sps, n=6, budget=8):
    return [Request(rid=i, prompt=prompts[i], max_new_tokens=budget,
                    sampling=sps[i], arrival_time=0.01 * i)
            for i in range(n)]


MIX = [SamplingParams() if i % 2 == 0 else
       SamplingParams(temperature=0.9, top_k=8, top_p=0.95, seed=100 + i)
       for i in range(6)]

SPEC = DeploymentSpec(sku="rpu-cu", hbmco="hbmco-768MB",
                      weight_format="mxfp4", cache_dtype=jnp.float32,
                      max_len=21, page_size=4, prefill_chunk=5,
                      max_slots=3)


@pytest.fixture(scope="module")
def manual_run(small, prompts):
    """Hand-tuned reference engine matching SPEC's derived geometry,
    driven incrementally so peak concurrency is observable.  Shared by
    the equality / storm / admission-hint tests (one compile)."""
    _, model, params = small
    dep = SPEC.resolve(model, params=params)
    eng = ContinuousServeEngine(
        model, params, num_slots=dep.num_slots, page_size=4,
        num_pages=dep.num_pages, max_len=21, prefill_chunk=5,
        cache_dtype=jnp.float32, weight_format="mxfp4")
    for r in _reqs(prompts, MIX):
        eng.add_request(r)
    peak = 0
    while eng.has_unfinished():
        eng.step()
        peak = max(peak, len(eng._sched.running))
    toks = [list(r.tokens[:8]) for r in eng._requests]
    return dep, peak, toks


def test_llm_engine_spec_path_matches_manual(small, prompts, manual_run):
    """``LLMEngine(spec=...)`` serves with derived pool/slots — no manual
    pool knob — and emits the same tokens as the hand-tuned engine."""
    _, model, params = small
    dep, _, ref_toks = manual_run
    llm = LLMEngine(model, params, backend="continuous", spec=SPEC)
    assert llm.deployment is not None
    eng = llm._eng
    assert eng.num_slots == dep.num_slots == llm.deployment.num_slots
    assert eng.num_pages == dep.num_pages
    outs = llm.generate(list(prompts), MIX, max_new_tokens=8)
    assert [o.token_ids for o in outs] == ref_toks


def test_static_backend_takes_spec(small):
    """The static backend resolves max_len / cache_dtype from the spec
    (no mesh; construction compiles nothing)."""
    _, model, params = small
    spec = DeploymentSpec(sku="tpu-v5e", max_len=21,
                          cache_dtype=jnp.float32)
    llm = LLMEngine(model, params, backend="static", spec=spec)
    assert llm.max_len == 21 and llm.deployment is not None
    assert llm._eng.cache_dtype == jnp.float32
    assert llm._eng.deployment.device.name == "tpu_v5e"


def test_engine_without_spec_requires_knobs(small):
    _, model, params = small
    with pytest.raises(ValueError, match="DeploymentSpec"):
        ContinuousServeEngine(model, params, num_slots=2)


def test_capacity_pressure_storm_byte_identical_with_invariants(
        small, prompts, manual_run):
    """Satellite: a deliberately tiny spec-derived pool must survive a
    preemption storm with byte-identical outputs and clean allocator
    ref-count invariants after every engine iteration."""
    _, model, params = small
    _, _, ref_toks = manual_run
    from repro.parallel.plan import paged_kv_token_bytes
    from repro.quant.linear import serve_weight_bytes
    page_bytes = paged_kv_token_bytes(model, dtype_bytes=4) * 4
    weight_bytes = serve_weight_bytes(params, "mxfp4")
    # capacity = weights + ~7 pages: far less than 3 slots x 6 blocks
    cap = weight_bytes + 7.6 * page_bytes
    hbm = HBMCOConfig(name="co-storm", ranks=1, channels_per_layer=1,
                      banks_per_group=1, bank_mb=cap / (32 * 2 ** 20))
    spec = DeploymentSpec(sku="rpu-cu", hbmco=hbm, stacks_per_device=1,
                          weight_format="mxfp4", cache_dtype=jnp.float32,
                          max_len=21, page_size=4, prefill_chunk=5,
                          max_slots=3, overcommit=4.0, mean_context=1,
                          workspace_fraction=0.0)
    eng = ContinuousServeEngine(model, params, spec=spec)
    assert eng.num_pages <= 9, "pool should be under pressure"
    assert eng.num_slots == 3
    for r in _reqs(prompts, MIX):
        eng.add_request(r)
    while eng.has_unfinished():
        eng.step()
        eng.cache.allocator.check()       # rc/conservation every iteration
    assert sum(r.preemptions for r in eng._requests) > 0, \
        "no preemption pressure exercised"
    # all request-held pages are back; only the prefix index may hold refs
    alloc = eng.cache.allocator
    for p in list(alloc._rc):
        assert alloc.refcount(p) == 1      # index refs only
    # byte-identical to the roomy reference (restarts are invisible)
    assert [list(r.tokens[:8]) for r in eng._requests] == ref_toks


def test_admission_hint_caps_concurrent_decoding(small, prompts, manual_run):
    """The bandwidth-model hint admits at most ``max_decode_slots``
    concurrent requests even when more slots exist, without changing any
    output stream."""
    _, model, params = small
    dep, ref_peak, ref_toks = manual_run
    assert ref_peak > 2                   # the uncapped engine went wider
    eng = ContinuousServeEngine(
        model, params, num_slots=dep.num_slots, page_size=4,
        num_pages=dep.num_pages, max_len=21, prefill_chunk=5,
        cache_dtype=jnp.float32, max_decode_slots=2,
        weight_format="mxfp4")
    for r in _reqs(prompts, MIX):
        eng.add_request(r)
    peak = 0
    while eng.has_unfinished():
        eng.step()
        peak = max(peak, len(eng._sched.running))
    assert peak <= 2
    assert [list(r.tokens[:8]) for r in eng._requests] == ref_toks
