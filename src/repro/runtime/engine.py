"""Serving engines: static batch and continuous batching.

``ServeEngine`` mirrors the paper's deployment model (§VI "Deployment"):
prefill and decode are separate entry points (Splitwise/Dynamo-style phase
splitting, the paper's prerequisite architecture), and the decode loop runs
as ONE jitted ``lax.scan`` over steps — no host round-trip per token, the
JAX analogue of the RPU's host-free autonomous execution ("eliminating the
host-driven offload model used by GPUs").

``ContinuousServeEngine`` is the throughput path the paper's ISO-TDP claim
rests on: decode is bandwidth-bound, so sustained tokens/s is proportional
to slot occupancy.  Requests arrive raggedly; iteration-level batching
admits each one into a freed decode slot the moment both a slot and KV
pages are available.  Admission runs **chunked prefill straight into the
page pools**: each iteration advances every admitted-but-unfilled request
by one fixed-size chunk (one jitted shape, batched across slots at ragged
offsets) interleaved with the fused decode step, so a long prompt never
stalls the running batch.  With prefix caching on, admission shares a
matching prompt's leading pages read-only and prefill starts at the first
unseen token — lower TTFT and fewer prefill FLOPs for shared-prefix
traffic.

Both engines are mesh-agnostic: pass shardings built by ``parallel.plan``
to run the same code distributed; CPU tests run them single-device.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.runtime import sampling
from repro.runtime.kv_cache import PagedKVCache
from repro.runtime.scheduler import RUNNING, Request, Scheduler


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # (B, n_new) int32
    logprobs: jnp.ndarray | None
    steps: int


class ServeEngine:
    """Batched request serving for one model."""

    def __init__(self, model: Model, params: Any, *, max_len: int,
                 temperature: float = 0.0, top_k: int = 0,
                 donate_cache: bool = True, cache_dtype=None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.top_k = top_k
        self.cache_dtype = cache_dtype
        self._decode_loop = jax.jit(
            self._decode_loop_impl,
            static_argnames=("n_steps",),
            donate_argnums=(1,) if donate_cache else (),
        )
        self._prefill = jax.jit(self.model.prefill)

    # -- phase 1: prefill ---------------------------------------------------
    def prefill(self, batch: dict):
        """Run the prompt; returns (first_token_logits, cache, prompt_len)."""
        b = (batch["features"] if "features" in batch else batch["tokens"]).shape[0]
        cache = self.model.init_cache(b, self.max_len, dtype=self.cache_dtype)
        logits, cache = self._prefill(self.params, batch, cache)
        plen = batch["tokens"].shape[1]
        if "image_embeds" in batch:
            plen += batch["image_embeds"].shape[1]
        return logits, cache, plen

    # -- phase 2: autonomous decode loop -------------------------------------
    def _decode_loop_impl(self, first_tokens, cache, start_pos, key, *,
                          n_steps: int):
        def step(carry, _):
            tokens, cache, pos, key = carry
            logits, cache = self.model.decode_step(self.params, tokens, cache, pos)
            key, sub = jax.random.split(key)
            nxt = sampling.sample(sub, logits, self.temperature, self.top_k)
            return (nxt, cache, pos + 1, key), nxt

        (_, cache, _, _), toks = jax.lax.scan(
            step, (first_tokens, cache, start_pos, key), length=n_steps)
        return jnp.moveaxis(toks, 0, 1), cache     # (B, n_steps)

    def generate(self, batch: dict, *, max_new_tokens: int,
                 key=None) -> GenerationResult:
        """prefill + decode max_new_tokens; returns all generated tokens."""
        key = key if key is not None else jax.random.PRNGKey(0)
        logits, cache, plen = self.prefill(batch)
        key, sub = jax.random.split(key)
        first = sampling.sample(sub, logits, self.temperature, self.top_k)
        toks, cache = self._decode_loop(
            first, cache, jnp.int32(plen), key, n_steps=max_new_tokens - 1)
        all_toks = jnp.concatenate([first[:, None], toks], axis=1)
        return GenerationResult(tokens=all_toks, logprobs=None,
                                steps=max_new_tokens)


@dataclasses.dataclass
class ContinuousStats:
    """Outcome of one ``ContinuousServeEngine.run``."""
    results: dict                 # rid -> np.ndarray (n_new,) int32
    steps: int                    # fused decode iterations executed
    occupancy: float              # mean fraction of decoding slots per step
    wall: float                   # seconds, admission of first request -> done
    preemptions: int
    chunks: int = 0               # prefill chunk rows executed
    prefill_tokens: int = 0       # prompt tokens actually computed
    prompt_tokens: int = 0        # prompt tokens across all admissions
    prefix_hit_tokens: int = 0    # prompt tokens served from shared pages
    cow_events: int = 0
    per_request: dict = dataclasses.field(default_factory=dict)
    # per_request[rid] = {"preemptions", "chunks", "shared_tokens", "ttft"}

    @property
    def total_tokens(self) -> int:
        return int(sum(t.shape[0] for t in self.results.values()))

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hit_tokens / max(self.prompt_tokens, 1)

    def ttft_quantiles(self) -> tuple[float, float, float] | None:
        """(p50, p99, mean) time-to-first-token in seconds, or None."""
        ts = sorted(r["ttft"] for r in self.per_request.values()
                    if r["ttft"] is not None)
        if not ts:
            return None
        p50 = ts[len(ts) // 2]
        p99 = ts[min(len(ts) - 1, int(len(ts) * 0.99))]
        return p50, p99, sum(ts) / len(ts)


class ContinuousServeEngine:
    """Iteration-level continuous batching over a block-paged KV cache.

    The jitted decode step has a fixed slot batch; per-slot page tables and
    ragged positions route each slot's K/V stream through the physical page
    pools (``Model.decode_step_paged`` — on accelerators the gather-fused
    Pallas kernel, no dense intermediate).  Admission (chunked prefill into
    the pools via ``Model.prefill_chunk_paged``), growth, eviction,
    copy-on-write, and retirement are host-side bookkeeping between steps —
    no recompiles: the only jitted shapes are the decode step and one
    ``(bucket, prefill_chunk)`` prefill chunk per power-of-two bucket.
    """

    def __init__(self, model: Model, params: Any, *, num_slots: int,
                 page_size: int, num_pages: int, max_len: int,
                 temperature: float = 0.0, top_k: int = 0,
                 cache_dtype=None, prefill_chunk: int = 64,
                 enable_prefix_cache: bool = True):
        if model.cfg.frontend is not None:
            raise NotImplementedError(
                "continuous batching serves token frontends only")
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_blocks = -(-max_len // page_size)
        if num_pages - 1 < self.max_blocks:   # page 0 is scratch
            raise ValueError(
                f"num_pages={num_pages} cannot back even one max-length "
                f"request ({self.max_blocks} blocks + scratch)")
        self.temperature = temperature
        self.top_k = top_k
        self.cache_dtype = cache_dtype
        if int(prefill_chunk) < 1:
            raise ValueError(f"prefill_chunk={prefill_chunk} must be >= 1")
        self.prefill_chunk = int(prefill_chunk)
        self.enable_prefix_cache = enable_prefix_cache
        self._step = jax.jit(self._step_impl, donate_argnums=(1,))
        self._chunk = jax.jit(self._chunk_impl, donate_argnums=(1,))
        self._copy_page = jax.jit(self._copy_page_impl, donate_argnums=(0,))

    # -- jitted pieces ------------------------------------------------------
    def _step_impl(self, params, pools, tokens, pos, page_table, key):
        logits, pools = self.model.decode_step_paged(params, tokens, pools,
                                                     page_table, pos)
        key, sub = jax.random.split(key)
        nxt = sampling.sample(sub, logits, self.temperature, self.top_k)
        return nxt, pools, key

    def _chunk_impl(self, params, pools, tokens, page_table, start, valid,
                    key):
        logits, pools = self.model.prefill_chunk_paged(
            params, tokens, pools, page_table, start, valid)
        key, sub = jax.random.split(key)
        first = sampling.sample(sub, logits, self.temperature, self.top_k)
        return first, pools, key

    def _copy_page_impl(self, pools, dst, src):
        """pools[dst] = pools[src] on every pool leaf (copy-on-write)."""
        new_pools = []
        for si, seg in enumerate(self.model.plan):
            copy = ((lambda a: a.at[dst].set(a[src])) if seg.reps == 1
                    else (lambda a: a.at[:, dst].set(a[:, src])))
            new_pools.append(tuple(
                {k: copy(v) for k, v in pool.items()} for pool in pools[si]))
        return new_pools

    def _permute_pools(self, pools, gather):
        """Apply a defrag page permutation to every pool leaf."""
        gather = jnp.asarray(gather)
        new_pools = []
        for si, seg in enumerate(self.model.plan):
            axis = 0 if seg.reps == 1 else 1
            new_pools.append(tuple(
                {k: jnp.take(v, gather, axis=axis) for k, v in pool.items()}
                for pool in pools[si]))
        return new_pools

    # -- host loop ----------------------------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        b = 1
        while b < n:
            b *= 2
        return b

    def _prefill_chunks(self, sched: Scheduler, pools, key, now):
        """Advance every PREFILL request by one chunk (one jitted call,
        batched across slots at ragged offsets).

        The chunk width is static (``prefill_chunk``) — size it to the
        workload: around the typical prompt length for low-latency
        admission, smaller to bound the per-iteration prefill slice
        interleaved with decode.  The page-table view is sliced to the
        pow-2 cover of the blocks actually resident after this chunk, so a
        short prompt's chunk never gathers (or attends over) the full
        ``max_blocks`` view; jitted shapes stay bounded by
        O(log2(num_slots) * log2(max_blocks))."""
        pre = sched.prefilling()
        c = self.prefill_chunk
        bucket = self._bucket(len(pre))
        need = max(-(-(r.pos + min(c, r.prompt_len - r.pos)) // self.page_size)
                   for r in pre)
        nb = min(self._bucket(need), self.max_blocks)
        tokens = np.zeros((bucket, c), np.int32)
        tables = np.zeros((bucket, nb), np.int32)      # pad rows -> scratch
        start = np.zeros((bucket,), np.int32)
        valid = np.zeros((bucket,), np.int32)
        table = self.cache.table()
        for i, r in enumerate(pre):
            n = min(c, r.prompt_len - r.pos)
            tokens[i, :n] = r.prompt[r.pos:r.pos + n]
            tables[i] = table[r.slot, :nb]
            start[i] = r.pos
            valid[i] = n
        first, pools, key = self._chunk(
            self.params, pools, jnp.asarray(tokens), jnp.asarray(tables),
            jnp.asarray(start), jnp.asarray(valid), key)
        first = np.asarray(first)                      # device sync
        done_now = []
        for i, r in enumerate(pre):
            r.chunks += 1
            self._n_chunks += 1
            self._prefill_tokens += int(valid[i])
            r.pos += int(valid[i])
            if r.pos == r.prompt_len:                  # prefill complete
                r.state = RUNNING
                r.tokens.append(int(first[i]))
                if r.first_token_time is None:
                    # greedy restart re-emits the tokens the client already
                    # has, so a preempted request keeps its original TTFT
                    r.first_token_time = now()
                self.cache.index_prompt(r.slot, r.prompt)
                if r.done:
                    done_now.append(r)
        for r in done_now:
            sched.finish(r, now())
        return pools, key

    def run(self, requests: Iterable[Request], *, key=None,
            defrag_every: int = 0) -> ContinuousStats:
        """Serve ``requests`` to completion; honors ``arrival_time``."""
        self.cache = PagedKVCache(num_slots=self.num_slots,
                                  num_pages=self.num_pages,
                                  page_size=self.page_size,
                                  max_blocks=self.max_blocks,
                                  enable_prefix_cache=self.enable_prefix_cache)
        sched = Scheduler(self.cache)
        requests = list(requests)
        for r in requests:
            if r.prompt_len + r.max_new_tokens > self.max_blocks * self.page_size:
                raise ValueError(
                    f"request {r.rid}: prompt {r.prompt_len} + "
                    f"{r.max_new_tokens} new tokens exceeds max_len "
                    f"{self.max_blocks * self.page_size}")
        sched.submit(requests)
        pools = self.model.init_paged_cache(self.num_pages, self.page_size,
                                            dtype=self.cache_dtype)
        key = key if key is not None else jax.random.PRNGKey(0)
        t0 = time.monotonic()
        now = lambda: time.monotonic() - t0
        steps, occ_sum = 0, 0.0
        self._n_chunks, self._prefill_tokens = 0, 0

        while sched.has_work():
            sched.admit(now())
            # -- chunked prefill, interleaved with the decode iterations --
            if sched.prefilling():
                pools, key = self._prefill_chunks(sched, pools, key, now)
            if not sched.decoding():
                if sched.prefilling():
                    continue                           # more chunks to run
                nxt_t = sched.next_arrival()
                if nxt_t is None:
                    break
                time.sleep(max(nxt_t - now(), 0.0))
                continue
            # -- capacity + copy-on-write barrier for the decode writes --
            for req in sched.decoding():
                if sched.running.get(req.slot) is req:  # not yet preempted
                    if sched.ensure_capacity(req):
                        moved = self.cache.cow(req.slot,
                                               req.pos // self.page_size)
                        if moved is not None:
                            pools = self._copy_page(pools, moved[1], moved[0])
            decoding = sched.decoding()
            if not decoding:
                continue
            if defrag_every and (steps + 1) % defrag_every == 0:
                gather = self.cache.defrag()
                if gather is not None:
                    pools = self._permute_pools(pools, gather)

            tokens = np.zeros((self.num_slots,), np.int32)
            pos = np.zeros((self.num_slots,), np.int32)
            # slots still prefilling (or free) must not touch live pages:
            # their rows are routed to the scratch page for this step
            step_table = np.zeros_like(self.cache.table())
            for req in decoding:
                tokens[req.slot] = req.tokens[-1]
                pos[req.slot] = req.pos
                step_table[req.slot] = self.cache.table()[req.slot]
            nxt, pools, key = self._step(
                self.params, pools, jnp.asarray(tokens), jnp.asarray(pos),
                jnp.asarray(step_table), key)
            nxt = np.asarray(nxt)                      # device sync
            occ_sum += len(decoding) / self.num_slots
            steps += 1
            for req in decoding:
                if sched.running.get(req.slot) is not req:
                    continue
                req.tokens.append(int(nxt[req.slot]))
                req.pos += 1
                if req.done:
                    sched.finish(req, now())

        results = {r.rid: np.asarray(r.tokens[:r.max_new_tokens], np.int32)
                   for r in requests}
        per_request = {r.rid: {"preemptions": r.preemptions,
                               "chunks": r.chunks,
                               "shared_tokens": r.shared_tokens,
                               "ttft": r.ttft}
                       for r in requests}
        return ContinuousStats(
            results=results, steps=steps,
            occupancy=occ_sum / max(steps, 1),
            wall=now(),
            preemptions=sum(r.preemptions for r in requests),
            chunks=self._n_chunks,
            prefill_tokens=self._prefill_tokens,
            prompt_tokens=self.cache.lookup_tokens,
            prefix_hit_tokens=self.cache.hit_tokens,
            cow_events=self.cache.cow_events,
            per_request=per_request)


def serve_step_fn(model: Model):
    """The bare decode step (one token, KV cache) — the function the
    dry-run lowers for ``decode_*`` / ``long_*`` shapes."""

    def serve_step(params, tokens, cache, cur_pos):
        logits, new_cache = model.decode_step(params, tokens, cache, cur_pos)
        return sampling.greedy(logits), new_cache

    return serve_step


def prefill_step_fn(model: Model):
    """Forward over the full prompt — lowered for ``prefill_*`` shapes."""

    def prefill_step(params, batch):
        return model.forward(params, batch)

    return prefill_step
