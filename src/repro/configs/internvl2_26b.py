"""InternVL2-26B — InternViT frontend (stubbed: input_specs provides patch
embeddings) + InternLM2 LM backbone.  [arXiv:2404.16821]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553, vocab_pad_multiple=512,
    frontend="vision",
    n_frontend_tokens=256,     # image patch tokens prepended
    rope_theta=1000000.0,
)
