"""Quantized paged-KV cache formats: fp8 (E4M3) and int8 page pools.

``cache_dtype`` grows two string values — ``"fp8"`` and ``"int8"`` — on
top of the usual jnp dtypes.  A quantized pool stores K/V *codes* in the
narrow storage dtype plus per-token-per-KV-head ``float32`` scales in
sibling ``k_scale`` / ``v_scale`` pool leaves of shape ``(P, page, KVH)``
(page-major scale metadata riding in the pool itself, so page copy /
permute / sharding machinery treats them like any other leaf).

Scales are computed at *write* time (amax of the token's head vector),
which is the only scheme compatible with incremental scatter writes: a
mutable per-page running amax would re-quantize history.  Dequant is a
single elementwise multiply — fused into the paged decode kernel's
page-streaming loop on the read side, and performed identically (f32
codes x f32 scale) in the jnp oracle so ``accum="exact"`` interpret mode
stays bit-exact.
"""
from __future__ import annotations

import jax.numpy as jnp

# name -> (storage dtype, max representable magnitude)
KV_FORMATS = {
    "fp8": (jnp.float8_e4m3fn, 448.0),
    "int8": (jnp.int8, 127.0),
}
SCALE_DTYPE = jnp.float32


def validate_cache_dtype(dtype) -> None:
    if isinstance(dtype, str) and dtype not in KV_FORMATS:
        raise ValueError(f"unknown quantized cache_dtype {dtype!r}; "
                         f"know {sorted(KV_FORMATS)} (or pass a jnp dtype)")


def is_quantized_cache_dtype(dtype) -> bool:
    """True for the string cache dtypes ("fp8" / "int8")."""
    validate_cache_dtype(dtype)
    return isinstance(dtype, str)


def cache_storage_dtype(dtype):
    """The dtype K/V codes are stored in (identity for plain dtypes)."""
    if is_quantized_cache_dtype(dtype):
        return KV_FORMATS[dtype][0]
    return dtype


def pool_cache_format(pool: dict) -> str | None:
    """Which quantized format a pool was built with (None = dense)."""
    if "k_scale" not in pool:
        return None
    for name, (store, _) in KV_FORMATS.items():
        if pool["k"].dtype == store:
            return name
    raise ValueError(f"pool has scale leaves but unrecognized code dtype "
                     f"{pool['k'].dtype}")


def kv_quantize(vals: jnp.ndarray, cache_dtype: str):
    """Quantize K or V vectors (..., KVH, HD) -> (codes, scales (..., KVH)).

    One f32 scale per stored token per KV head: ``amax / qmax`` (1.0 for
    all-zero vectors so dequant stays finite)."""
    store, qmax = KV_FORMATS[cache_dtype]
    v = vals.astype(jnp.float32)
    amax = jnp.max(jnp.abs(v), axis=-1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    scaled = v / scale[..., None]
    if store == jnp.int8:
        codes = jnp.clip(jnp.round(scaled), -qmax, qmax).astype(store)
    else:
        codes = jnp.clip(scaled, -qmax, qmax).astype(store)
    return codes, scale.astype(SCALE_DTYPE)


def kv_dequantize(codes: jnp.ndarray, scales: jnp.ndarray,
                  dtype=jnp.float32) -> jnp.ndarray:
    """codes (..., KVH, HD) x scales (..., KVH) -> values in ``dtype``.

    The same op sequence (f32 cast, then one multiply) the fused paged
    decode kernel applies per page, so oracle and kernel stay bit-exact.
    """
    return (codes.astype(jnp.float32) * scales[..., None]).astype(dtype)
