"""Event-driven simulator of the RPU's decoupled pipelines (paper §V/§VI).

Models one CU (all CUs are symmetric under the paper's fine-grained
sharding) as three pipelines coupled through a bounded SRAM buffer:

  memory  — streams each phase's HBM bytes in chunks at ``cu_mem_bw``;
            may run AHEAD of compute (prefetch) until the buffer fills —
            the decoupling that lets the RPU absorb network stalls and
            phase imbalance (Fig 8 ①③⑤).
  compute — consumes chunks in order at the phase's FLOP rate; cannot
            start a phase before its gating collective completes (Fig 8 ②④).
  network — per-phase ring collectives: hops x hop_latency + bytes/ring_bw.

Chunk-granular discrete-event execution (FIFO producer/consumer over one
buffer) reproduces the paper's transient behaviours: buffer occupancy
ramps, compute "catch-up" after stalls, and the bimodal smoothing claim
(§IX C3: decoupling is worth up to 1.6x at BS=32; ablate with
``decoupled=False`` / ``fine_grained_net=False``).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import hardware
from repro.core.hbmco import HBMCOConfig, CANDIDATE_CO
from repro.core.provisioning import DATAPATH_PJ_PER_BIT
from repro.sim.isa import Phase, Program

COMPUTE_PJ_PER_FLOP = 0.3     # 5 W / 16.4 TOPS (paper Fig 8 compute power)


@dataclasses.dataclass
class SimResult:
    latency_s: float
    mem_busy_s: float
    comp_busy_s: float
    net_busy_s: float
    mem_stall_buffer_s: float        # memory blocked on full buffer
    comp_stall_net_s: float          # compute blocked on collectives
    comp_stall_data_s: float         # compute blocked on memory stream
    energy_j: float
    buffer_peak_bytes: float
    phase_spans: list                # (name, comp_start, comp_end)
    tokens_per_s_per_query: float = 0.0
    batch: int = 1

    @property
    def mem_bw_utilization(self) -> float:
        return self.mem_busy_s / self.latency_s if self.latency_s else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.batch / self.latency_s if self.latency_s else 0.0


def simulate_program(
    program: Program,
    *,
    rpu: hardware.RPUChipParams = hardware.RPU_DEFAULT,
    mem: HBMCOConfig = CANDIDATE_CO,
    buffer_bytes: float | None = None,
    chunk_bytes: float = 64 * 1024,
    decoupled: bool = True,
    fine_grained_net: bool = True,
) -> SimResult:
    """Execute the compiled program on the decoupled-pipeline model."""
    phases = program.flat_phases()
    bw = rpu.cu_mem_bw
    tops = rpu.cu_tops
    ring_bw = rpu.ring_bw
    hop = rpu.cu_hop_latency_s
    if buffer_bytes is None:
        buffer_bytes = rpu.buffer_bytes_per_core * rpu.cores_per_cu

    # --- build the global chunk list (FIFO across phases)
    chunk_phase: list[int] = []
    chunk_mem_t: list[float] = []
    chunk_comp_t: list[float] = []
    for pi, ph in enumerate(phases):
        n = max(1, math.ceil(ph.mem_bytes / chunk_bytes)) if ph.mem_bytes else 1
        for j in range(n):
            frac = 1.0 / n
            chunk_phase.append(pi)
            chunk_mem_t.append(ph.mem_bytes * frac / bw)
            chunk_comp_t.append(ph.flops * frac / tops)

    nch = len(chunk_phase)
    stream_end = [0.0] * nch
    consume_end = [0.0] * nch

    # --- network schedule: gating collective for phase i starts when the
    # previous phase's compute has produced the activation.
    net_end = [0.0] * len(phases)

    # two-cursor simulation: memory cursor m, compute cursor c
    mem_free = 0.0
    comp_free = 0.0
    net_free = 0.0
    mem_stall_buffer = 0.0
    comp_stall_net = 0.0
    comp_stall_data = 0.0
    mem_busy = 0.0
    comp_busy = 0.0
    net_busy = 0.0
    buffer_peak = 0.0
    phase_comp_start = [0.0] * len(phases)
    phase_comp_end = [0.0] * len(phases)

    # buffer window: memory may stream chunk m only if the un-consumed bytes
    # stay <= buffer_bytes; with uniform chunks this is a sliding window.
    window = max(1, int(buffer_bytes / chunk_bytes)) if decoupled else 1

    prev_comp_end_of_phase = 0.0
    cur_phase_for_comp = -1

    m = 0
    c = 0
    # interleaved advance: always progress the earlier-available action.
    while c < nch:
        # --- advance memory cursor while it can stream
        while m < nch:
            # buffer space: chunk m-window must have been consumed
            space_t = consume_end[m - window] if m - window >= 0 else 0.0
            ph_m = phases[chunk_phase[m]]
            start_req = mem_free
            if not decoupled:
                # serial ablation: no cross-phase prefetch — memory may not
                # start phase p until compute finished phase p-1.
                pidx = chunk_phase[m]
                if pidx > 0:
                    start_req = max(start_req, phase_comp_end[pidx - 1])
            if not fine_grained_net:
                # global-barrier ablation: memory waits for the phase's
                # gating collective too.
                pidx = chunk_phase[m]
                if phases[pidx].net_bytes:
                    start_req = max(start_req, net_end[pidx])
            # occupancy bound: at most ``window`` chunks ahead of the
            # consume cursor (also keeps consume_end[m-window] well-defined)
            if m >= c + window:
                break
            start = max(start_req, space_t)
            if start > mem_free:
                mem_stall_buffer += start - mem_free
            dur = chunk_mem_t[m]
            stream_end[m] = start + dur
            mem_free = stream_end[m]
            mem_busy += dur
            buffer_peak = max(buffer_peak,
                              min(window, m - c + 1) * chunk_bytes)
            m += 1

        # --- advance compute by one chunk
        pidx = chunk_phase[c]
        ph = phases[pidx]
        # coarse-grained ablation (paper §IX C3): every collective becomes
        # a gating global barrier over the full flat ring (the fine-grained
        # sharding is what shrinks the sync scope and lets VMMs overlap
        # their broadcasts).
        gating = ph.net_bytes and (not ph.overlap_net or not fine_grained_net)
        if pidx != cur_phase_for_comp:
            cur_phase_for_comp = pidx
            # schedule this phase's collective (consumes the previous
            # phase's output, so it starts no earlier than that)
            if ph.net_bytes:
                ns = max(net_free, prev_comp_end_of_phase)
                hops = ph.net_hops if fine_grained_net else program.n_cus
                dur = hops * hop + ph.net_bytes / ring_bw
                net_end[pidx] = ns + dur
                net_free = ns + dur
                net_busy += dur
            first_start = max(comp_free, stream_end[c])
            if gating and net_end[pidx] > first_start:
                comp_stall_net += net_end[pidx] - first_start
            phase_comp_start[pidx] = max(
                first_start, net_end[pidx] if gating else 0.0)

        start = max(comp_free, stream_end[c])
        if gating:
            start = max(start, net_end[pidx])
        if stream_end[c] > comp_free:
            comp_stall_data += stream_end[c] - comp_free
        dur = chunk_comp_t[c]
        consume_end[c] = start + dur
        comp_free = consume_end[c]
        comp_busy += dur
        if c == nch - 1 or chunk_phase[c + 1] != pidx:
            # pipelined broadcast (paper §IV): the VMM cannot *finish*
            # before the last activation fragment has arrived.
            if ph.overlap_net and ph.net_bytes:
                if net_end[pidx] > comp_free:
                    comp_stall_net += net_end[pidx] - comp_free
                    comp_free = net_end[pidx]
                    consume_end[c] = comp_free
            phase_comp_end[pidx] = comp_free
            prev_comp_end_of_phase = comp_free
        c += 1

    latency = comp_free
    # --- energy
    pjb = (mem.energy_pj_per_bit + DATAPATH_PJ_PER_BIT) * 1e-12 * 8
    mem_bytes = program.total_mem_bytes()
    net_bytes = program.total_net_bytes()
    flops = program.total_flops()
    energy_per_cu = (mem_bytes * pjb
                     + flops * COMPUTE_PJ_PER_FLOP * 1e-12
                     + net_bytes * 8 * rpu.net_pj_per_bit_off_pkg * 1e-12)
    energy = energy_per_cu * program.n_cus

    spans = [(phases[i].name, phase_comp_start[i], phase_comp_end[i])
             for i in range(len(phases))]
    return SimResult(
        latency_s=latency,
        mem_busy_s=mem_busy,
        comp_busy_s=comp_busy,
        net_busy_s=net_busy,
        mem_stall_buffer_s=mem_stall_buffer,
        comp_stall_net_s=comp_stall_net,
        comp_stall_data_s=comp_stall_data,
        energy_j=energy,
        buffer_peak_bytes=buffer_peak,
        phase_spans=spans,
        batch=program.batch,
    )
