"""Serving engine: prefill/decode disaggregation + autonomous decode loop.

Mirrors the paper's deployment model (§VI "Deployment"): prefill and decode
are separate entry points (Splitwise/Dynamo-style phase splitting, the
paper's prerequisite architecture), and the decode loop runs as ONE jitted
``lax.scan`` over steps — no host round-trip per token, the JAX analogue of
the RPU's host-free autonomous execution ("eliminating the host-driven
offload model used by GPUs").

The engine is mesh-agnostic: pass shardings built by ``parallel.plan`` to
run the same code distributed; CPU tests run it single-device.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.runtime import sampling


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # (B, n_new) int32
    logprobs: jnp.ndarray | None
    steps: int


class ServeEngine:
    """Batched request serving for one model."""

    def __init__(self, model: Model, params: Any, *, max_len: int,
                 temperature: float = 0.0, top_k: int = 0,
                 donate_cache: bool = True):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.top_k = top_k
        self._decode_loop = jax.jit(
            self._decode_loop_impl,
            static_argnames=("n_steps",),
            donate_argnums=(1,) if donate_cache else (),
        )
        self._prefill = jax.jit(self.model.prefill)

    # -- phase 1: prefill ---------------------------------------------------
    def prefill(self, batch: dict):
        """Run the prompt; returns (first_token_logits, cache, prompt_len)."""
        b = (batch["features"] if "features" in batch else batch["tokens"]).shape[0]
        cache = self.model.init_cache(b, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)
        plen = batch["tokens"].shape[1]
        if "image_embeds" in batch:
            plen += batch["image_embeds"].shape[1]
        return logits, cache, plen

    # -- phase 2: autonomous decode loop -------------------------------------
    def _decode_loop_impl(self, first_tokens, cache, start_pos, key, *,
                          n_steps: int):
        def step(carry, _):
            tokens, cache, pos, key = carry
            logits, cache = self.model.decode_step(self.params, tokens, cache, pos)
            key, sub = jax.random.split(key)
            nxt = sampling.sample(sub, logits, self.temperature, self.top_k)
            return (nxt, cache, pos + 1, key), nxt

        (_, cache, _, _), toks = jax.lax.scan(
            step, (first_tokens, cache, start_pos, key), length=n_steps)
        return jnp.moveaxis(toks, 0, 1), cache     # (B, n_steps)

    def generate(self, batch: dict, *, max_new_tokens: int,
                 key=None) -> GenerationResult:
        """prefill + decode max_new_tokens; returns all generated tokens."""
        key = key if key is not None else jax.random.PRNGKey(0)
        logits, cache, plen = self.prefill(batch)
        key, sub = jax.random.split(key)
        first = sampling.sample(sub, logits, self.temperature, self.top_k)
        toks, cache = self._decode_loop(
            first, cache, jnp.int32(plen), key, n_steps=max_new_tokens - 1)
        all_toks = jnp.concatenate([first[:, None], toks], axis=1)
        return GenerationResult(tokens=all_toks, logprobs=None,
                                steps=max_new_tokens)


def serve_step_fn(model: Model):
    """The bare decode step (one token, KV cache) — the function the
    dry-run lowers for ``decode_*`` / ``long_*`` shapes."""

    def serve_step(params, tokens, cache, cur_pos):
        logits, new_cache = model.decode_step(params, tokens, cache, cur_pos)
        return sampling.greedy(logits), new_cache

    return serve_step


def prefill_step_fn(model: Model):
    """Forward over the full prompt — lowered for ``prefill_*`` shapes."""

    def prefill_step(params, batch):
        return model.forward(params, batch)

    return prefill_step
