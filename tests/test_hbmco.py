"""HBM-CO analytical model vs the paper's §III numbers."""
import pytest

from repro.core.hbmco import (CANDIDATE_CO, HBM3E_LIKE, enumerate_design_space,
                              pareto_frontier, select_sku)


def test_hbm3e_calibration():
    """Paper: 'We validate our HBM-CO model against HBM3e reported
    3.44 pJ/bit'; 48GB, 1024 GB/s-class stack."""
    assert HBM3E_LIKE.energy_pj_per_bit == pytest.approx(3.44, rel=0.02)
    assert HBM3E_LIKE.capacity_gb == pytest.approx(48, rel=0.01)
    assert HBM3E_LIKE.bandwidth_gbs == pytest.approx(1024, rel=0.01)


def test_candidate_pareto_point():
    """Paper: candidate = 768MB, 256GB/s, BW/Cap=341, ~1.45pJ/b."""
    assert CANDIDATE_CO.capacity_mb == pytest.approx(768, rel=0.01)
    assert CANDIDATE_CO.bandwidth_gbs == pytest.approx(256, rel=0.01)
    assert CANDIDATE_CO.bw_per_cap == pytest.approx(341, rel=0.02)
    assert CANDIDATE_CO.energy_pj_per_bit == pytest.approx(1.45, rel=0.05)


def test_candidate_tradeoffs_vs_hbm3e():
    """Paper §III takeaways: 2.4x energy, ~1.8x cost/GB, 35x module cost,
    >=5x bandwidth per dollar; 2.9ms ideal token latency."""
    e_ratio = HBM3E_LIKE.energy_pj_per_bit / CANDIDATE_CO.energy_pj_per_bit
    assert e_ratio == pytest.approx(2.4, rel=0.05)
    assert (CANDIDATE_CO.cost_per_gb / HBM3E_LIKE.cost_per_gb
            == pytest.approx(1.81, rel=0.05))
    assert (HBM3E_LIKE.module_cost / CANDIDATE_CO.module_cost
            == pytest.approx(35, rel=0.10))
    assert CANDIDATE_CO.bandwidth_per_cost / HBM3E_LIKE.bandwidth_per_cost >= 5.0
    assert CANDIDATE_CO.ideal_token_latency_s == pytest.approx(2.9e-3, rel=0.05)


def test_same_shoreline_bandwidth():
    """HBM-CO 'retains shoreline bandwidth': GB/s per mm equal."""
    r1 = HBM3E_LIKE.bandwidth_gbs / HBM3E_LIKE.shoreline_mm
    r2 = CANDIDATE_CO.bandwidth_gbs / CANDIDATE_CO.shoreline_mm
    assert r1 == pytest.approx(r2, rel=1e-6)


def test_bandwidth_independent_of_capacity_knobs():
    """Paper key insight: ranks / banks-per-group / bank size change
    capacity but not bandwidth."""
    from repro.core.hbmco import HBMCOConfig
    base = HBMCOConfig(ranks=1, banks_per_group=1, bank_mb=6.0)
    for ranks in (1, 2, 4):
        for banks in (1, 2, 4):
            for mb in (1.5, 6.0, 24.0):
                c = HBMCOConfig(ranks=ranks, banks_per_group=banks, bank_mb=mb)
                assert c.bandwidth_gbs == base.bandwidth_gbs
                if (ranks, banks, mb) > (1, 1, 6.0):
                    assert c.capacity_mb > base.capacity_mb or mb < 6.0


def test_pareto_frontier_monotone():
    f = pareto_frontier(enumerate_design_space())
    assert len(f) >= 4
    caps = [c.capacity_mb for c in f]
    es = [c.energy_pj_per_bit for c in f]
    assert caps == sorted(caps)
    assert es == sorted(es)          # more capacity => more energy/bit


def test_sku_selection_rule():
    """Fig 9/10 rule: smallest frontier capacity that fits."""
    f = pareto_frontier(enumerate_design_space())
    sku = select_sku(100e6, f)
    assert sku is not None and sku.capacity_bytes >= 100e6
    smaller = [c for c in f if c.capacity_bytes < sku.capacity_bytes]
    assert all(c.capacity_bytes < 100e6 for c in smaller)
    assert select_sku(1e15, f) is None
