"""Attention blocks: GQA (with SWA / qk-norm / bias variants) and MLA.

Each block kind exposes ``init_<kind>`` and three apply paths:
  * ``forward``  — full-sequence (training / prefill without cache)
  * ``prefill``  — full-sequence while materializing the decode cache
  * ``decode``   — single-token step against the cache

Caches are plain dicts of arrays so they stack cleanly along a layer axis
for ``lax.scan`` (see ``runtime.kv_cache`` for the container types).

The paged (continuous-batching) apply paths live in
``models/attention_backends.py``, which registers each family here behind
the attention-backend registry the model assembly dispatches through.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import (
    ModelConfig, NEG_INF, apply_rope, blocked_attention, decode_attention_ref,
    dense_init, rmsnorm, split_keys, swiglu,
)
from repro.parallel.hints import shard_hint
from repro.quant.linear import qdot

# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ModelConfig) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd),
        "wk": dense_init(ks[1], d, kvh * hd),
        "wv": dense_init(ks[2], d, kvh * hd),
        "wo": dense_init(ks[3], h * hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.bfloat16)
        p["bk"] = jnp.zeros((kvh * hd,), jnp.bfloat16)
        p["bv"] = jnp.zeros((kvh * hd,), jnp.bfloat16)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _qkv(p: dict, x: jnp.ndarray, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = qdot(x, p["wq"])
    k = qdot(x, p["wk"])
    v = qdot(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_hint(q, "act_bshd")
    k = shard_hint(k, "act_bskd")
    return q, k, v


def attn_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                 window=None, positions=None) -> jnp.ndarray:
    """Full-sequence attention.  ``window``: None | int | traced scalar."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    out = blocked_attention(q, k, v, causal=cfg.causal, window=window)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd)
    return qdot(out, p["wo"])


def attn_prefill(p: dict, x: jnp.ndarray, cfg: ModelConfig, cache: dict, *,
                 window=None) -> tuple[jnp.ndarray, dict]:
    """Prefill: run attention and write k/v into the cache at [0, s)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    out = blocked_attention(q, k, v, causal=cfg.causal, window=window)
    out = qdot(out.reshape(b, s, cfg.n_heads * cfg.hd), p["wo"])
    w = cache["k"].shape[1]
    if w >= s:
        new_k = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                             (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                             (0, 0, 0, 0))
        pos = jnp.arange(w)
        slot_pos = jnp.where(pos < s, pos, -1)
    else:  # sliding-window cache smaller than prefill: keep the tail
        new_k = k[:, s - w:].astype(cache["k"].dtype)
        new_v = v[:, s - w:].astype(cache["v"].dtype)
        # ring layout: slot j holds absolute position t ≡ j (mod w)
        tail = jnp.arange(s - w, s)
        slot = tail % w
        slot_pos = jnp.zeros((w,), jnp.int32).at[slot].set(tail)
        new_k = jnp.zeros_like(cache["k"]).at[:, slot].set(new_k)
        new_v = jnp.zeros_like(cache["v"]).at[:, slot].set(new_v)
    return out, {"k": new_k, "v": new_v, "slot_pos": slot_pos.astype(jnp.int32)}


def attn_decode(p: dict, x: jnp.ndarray, cfg: ModelConfig, cache: dict,
                cur_pos, *, window=None) -> tuple[jnp.ndarray, dict]:
    """One-token step.  x: (B, D); cur_pos: scalar int32 (position index)."""
    b, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    positions = jnp.full((b, 1), cur_pos)
    q, k, v = _qkv(p, x[:, None, :], cfg, positions)
    w = cache["k"].shape[1]
    slot = jnp.mod(cur_pos, w)
    new_k = common.cache_update_at(cache["k"], k, slot)
    new_v = common.cache_update_at(cache["v"], v, slot)
    slot_pos = cache["slot_pos"].at[slot].set(cur_pos)

    valid = (slot_pos >= 0) & (slot_pos <= cur_pos)
    if window is not None:
        valid = valid & (slot_pos > cur_pos - window)
    out = decode_attention_ref(
        q[:, 0], new_k, new_v, None, valid=valid[None, :].repeat(b, 0))
    out = qdot(out.reshape(b, h * hd), p["wo"])
    return out, {"k": new_k, "v": new_v, "slot_pos": slot_pos}


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    window: int | None = None,
                    dtype=jnp.bfloat16) -> dict:
    w = min(max_len, window) if window else max_len
    return {
        "k": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), dtype),
        "slot_pos": jnp.full((w,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    hd, rhd, vhd, r = cfg.hd, cfg.rope_head_dim, cfg.v_hd, cfg.kv_lora_rank
    ks = split_keys(key, 6)
    return {
        "wq": dense_init(ks[0], d, h * (hd + rhd)),
        "w_dkv": dense_init(ks[1], d, r + rhd),      # latent + shared k_rope
        "kv_norm": jnp.ones((r,), jnp.float32),
        "w_uk": dense_init(ks[2], r, h * hd),
        "w_uv": dense_init(ks[3], r, h * vhd),
        "wo": dense_init(ks[4], h * vhd, d),
    }


def _mla_qc(p, x, cfg: ModelConfig, positions):
    """Shared q / latent computation.  Returns q_nope, q_rope, c_kv, k_rope."""
    b, s, _ = x.shape
    h, hd, rhd, r = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.kv_lora_rank
    q = qdot(x, p["wq"]).reshape(b, s, h, hd + rhd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckr = x @ p["w_dkv"]
    c_kv = rmsnorm(ckr[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(ckr[..., None, r:], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                positions=None) -> jnp.ndarray:
    b, s, _ = x.shape
    h, hd, rhd, vhd = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.v_hd
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qc(p, x, cfg, positions)
    # expand per-head keys/values from the latent (prefill path)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, hd)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, vhd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (b, s, h, rhd))], axis=-1)
    scale = 1.0 / math.sqrt(hd + rhd)
    out = blocked_attention(q, k, v, causal=cfg.causal, scale=scale)
    return qdot(out.reshape(b, s, h * vhd), p["wo"])


def mla_prefill(p, x, cfg: ModelConfig, cache: dict):
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    out = mla_forward(p, x, cfg, positions=positions)
    _, _, c_kv, k_rope = _mla_qc(p, x, cfg, positions)
    new_c = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
    new_kr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0))
    w = cache["c_kv"].shape[1]
    pos = jnp.arange(w)
    slot_pos = jnp.where(pos < s, pos, -1).astype(jnp.int32)
    return out, {"c_kv": new_c, "k_rope": new_kr, "slot_pos": slot_pos}


def mla_decode(p, x, cfg: ModelConfig, cache: dict, cur_pos):
    """Absorbed-matmul MLA decode: attention in the latent space.

    score_h(t) = q_nope_h · (W_uk^T)_h c_t + q_rope_h · k_rope_t
    out_h      = (Σ_t p_t c_t) @ (W_uv)_h
    """
    b, d = x.shape
    h, hd, rhd, vhd, r = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.v_hd, cfg.kv_lora_rank
    positions = jnp.full((b, 1), cur_pos)
    q_nope, q_rope, c_kv, k_rope = _mla_qc(p, x[:, None, :], cfg, positions)
    slot = cur_pos  # full cache (no SWA for MLA archs)
    new_c = common.cache_update_at(cache["c_kv"], c_kv, slot)
    new_kr = common.cache_update_at(cache["k_rope"], k_rope, slot)
    slot_pos = cache["slot_pos"].at[slot].set(cur_pos)

    # absorb W_uk into q: (B, H, r)
    w_uk = p["w_uk"].reshape(r, h, hd)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    q_eff = jnp.concatenate([q_lat, q_rope[:, 0].astype(jnp.float32)], axis=-1)
    k_eff = jnp.concatenate([new_c.astype(jnp.float32),
                             new_kr.astype(jnp.float32)], axis=-1)  # (B,S,r+rhd)
    scale = 1.0 / math.sqrt(hd + rhd)
    s_ = jnp.einsum("bhr,bsr->bhs", q_eff, k_eff) * scale
    valid = (slot_pos >= 0) & (slot_pos <= cur_pos)
    s_ = jnp.where(valid[None, None, :], s_, NEG_INF)
    pattn = jax.nn.softmax(s_, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pattn, new_c.astype(jnp.float32))  # latent ctx
    w_uv = p["w_uv"].reshape(r, h, vhd)
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
    out = qdot(out.reshape(b, h * vhd).astype(x.dtype), p["wo"])
    return out, {"c_kv": new_c, "k_rope": new_kr, "slot_pos": slot_pos}


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        "slot_pos": jnp.full((max_len,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, f),
        "w_up": dense_init(ks[1], d, f),
        "w_down": dense_init(ks[2], f, d),
    }


def mlp_forward(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
