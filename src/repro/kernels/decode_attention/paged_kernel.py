"""Gather-fused paged flash-decode attention — pages are first-class all the
way into the kernel.

The serve path used to materialize a dense ``(B, S, KVH, D)`` copy of every
slot's pages before running the dense decode kernel, doubling decode HBM
traffic.  Here the page table itself drives the Pallas grid: the table and
per-slot positions are **scalar-prefetched**, so each grid step's BlockSpec
``index_map`` reads ``page_table[b, j]`` and the pipeline DMAs that physical
K/V page HBM->VMEM directly — the paper's "stream KV from HBM into the SDPA
pipeline" with no dense intermediate.

Grid: ``(B, KV_HEADS, n_blocks)``, page walk innermost.  ``rep = H / KVH``
query heads ride along per kv head (GQA head-packing), and the mask family
covers both the prefix case (``idx <= pos``) and sliding windows
(``pos - window < idx <= pos``).

Under tensor-parallel serving the kernel is already per-shard: the page
pools shard their KV-head axis over the mesh's model axis
(``AttentionBackend.paged_partition_spec``), so inside the manual
shard_map region KV_HEADS here is the LOCAL head count and the grid walks
only the shard's slice of every page — each CU streams its own KV$ cut,
the page table is the same replicated array on every shard, and no
cross-shard traffic happens until the block's closing reduction.

Two accumulator modes:

  * ``accum="online"`` — classic flash-decode: fp32 (m, l, acc) running
    state in VMEM scratch, rescaled per page.  O(1) scratch in sequence
    length; the production TPU path.
  * ``accum="exact"``  — scores and V pages are staged into position-ordered
    VMEM scratch during the page walk; the final grid step applies softmax
    and the P·V contraction as single ops, reproducing the oracle's op
    sequence **bit-exactly** (verified in CI against
    ``paged_decode_attention_ref`` in interpret mode).  Scratch is
    O(S_max · D) per (batch, kv-head) — the verification mode, and the
    numerics contract the online mode is tested against.

Pages whose positions are entirely masked (table tail pointing at the
scratch page, or pages outside a sliding window) are skipped with
``pl.when`` so they contribute neither FLOPs nor accumulator drift.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _page_mask(j, pos, page: int, window):
    """(1, page) bool mask of positions in page ``j`` visible from ``pos``."""
    idx = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = idx <= pos
    if window is not None:
        valid = valid & (idx > pos - window)
    return valid


def _page_live(j, pos, page: int, window):
    """Scalar: does page ``j`` contain any visible position?"""
    lo = j * page
    live = lo <= pos
    if window is not None:
        live = jnp.logical_and(live, lo + page - 1 > pos - window)
    return live


def _online_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                   page: int, n_blocks: int, scale: float, window,
                   quantized: bool = False):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b, j = pl.program_id(0), pl.program_id(2)
    pos = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    @pl.when(_page_live(j, pos, page, window))
    def _fold():
        q = q_ref[0, 0].astype(jnp.float32)              # (rep, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)           # (page, Dv)
        if quantized:
            # dequant fused into the page-streaming loop: one per-token
            # f32 scale per KV head (same elementwise op as the oracle)
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(_page_mask(j, pos, page, window), s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def _exact_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                  page: int, n_blocks: int, scale: float, window,
                  quantized: bool = False):
    """Stage scores and V position-ordered; softmax + contraction once at the
    end — the same op sequence as the gather-then-dense oracle, so the
    output is bit-identical to ``paged_decode_attention_ref`` (including
    the quantized path: dequant is the same f32 cast + multiply)."""
    if quantized:
        ksc_ref, vsc_ref, o_ref, s_ref, vs_ref = rest
    else:
        o_ref, s_ref, vs_ref = rest
    b, j = pl.program_id(0), pl.program_id(2)
    pos = pos_ref[b]

    q = q_ref[0, 0].astype(jnp.float32)                  # (rep, D)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (page, D)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (page, Dv)
    if quantized:
        k = k * ksc_ref[0, :, 0][:, None]
        v = v * vsc_ref[0, :, 0][:, None]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(_page_mask(j, pos, page, window), s, NEG_INF)
    s_ref[:, pl.ds(j * page, page)] = s
    vs_ref[pl.ds(j * page, page), :] = v

    @pl.when(j == n_blocks - 1)
    def _finalize():
        p = jax.nn.softmax(s_ref[...], axis=-1)          # (rep, S)
        o_ref[0, 0] = jnp.dot(p, vs_ref[...],
                              preferred_element_type=jnp.float32
                              ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "accum", "interpret"))
def paged_decode_attention(
    q: jnp.ndarray,            # (B, H, D)
    k_pages: jnp.ndarray,      # (P, page, KVH, D) physical page pool
    v_pages: jnp.ndarray,      # (P, page, KVH, Dv)
    page_table: jnp.ndarray,   # (B, n_blocks) int32 logical block -> page
    pos: jnp.ndarray,          # (B,) int32 per-slot position of the new token
    *,
    k_scales: jnp.ndarray | None = None,   # (P, page, KVH) f32 (fp8/int8 pools)
    v_scales: jnp.ndarray | None = None,
    window: int | None = None,
    accum: str = "online",
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-token paged GQA decode attention; returns (B, H, D) in q.dtype.

    With ``k_scales``/``v_scales`` the pools hold quantized codes (fp8
    e4m3 or int8) and dequantization fuses into the page-streaming loop:
    each page's codes are cast to f32 and multiplied by its per-token
    scales right after the DMA, before the flash-decode fold."""
    b, h, d = q.shape
    _, page, kvh, dv = v_pages.shape
    n_blocks = page_table.shape[1]
    assert h % kvh == 0, (h, kvh)
    quantized = k_scales is not None
    assert (v_scales is not None) == quantized, "pass both scales or neither"
    rep = h // kvh
    scale = 1.0 / math.sqrt(d)

    qg = q.reshape(b, kvh, rep, d)
    grid = (b, kvh, n_blocks)
    kernel = _online_kernel if accum == "online" else _exact_kernel
    if accum == "online":
        scratch = [
            pltpu.VMEM((rep, 1), jnp.float32),           # running max
            pltpu.VMEM((rep, 1), jnp.float32),           # running denom
            pltpu.VMEM((rep, dv), jnp.float32),          # running numerator
        ]
    elif accum == "exact":
        scratch = [
            pltpu.VMEM((rep, n_blocks * page), jnp.float32),   # scores
            pltpu.VMEM((n_blocks * page, dv), jnp.float32),    # staged V
        ]
    else:
        raise ValueError(f"accum={accum!r} (want 'online' or 'exact')")

    page_spec = lambda bb, g, j, pt, ps: (pt[bb, j], 0, g, 0)
    in_specs = [
        pl.BlockSpec((1, 1, rep, d), lambda bb, g, j, pt, ps: (bb, g, 0, 0)),
        pl.BlockSpec((1, page, 1, d), page_spec),
        pl.BlockSpec((1, page, 1, dv), page_spec),
    ]
    inputs = [qg, k_pages, v_pages]
    if quantized:
        # scale pages ride the same page-table-driven index map
        scale_spec = lambda bb, g, j, pt, ps: (pt[bb, j], 0, g)
        in_specs += [pl.BlockSpec((1, page, 1), scale_spec),
                     pl.BlockSpec((1, page, 1), scale_spec)]
        inputs += [k_scales, v_scales]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                           # page_table, pos
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rep, dv),
                               lambda bb, g, j, pt, ps: (bb, g, 0, 0)),
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        functools.partial(kernel, page=page, n_blocks=n_blocks, scale=scale,
                          window=window, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, dv), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32), *inputs)
    return out.reshape(b, h, dv)
