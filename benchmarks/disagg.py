"""Disaggregated prefill/decode gates: byte-identity, latency, fleet.

``run()`` (used by ``benchmarks.run``; same as ``--smoke``) is the fast
tier:

- **byte-identity gate**: a real tiny engine serves the same greedy
  ragged-prompt workload (shared prefixes on) colocated and
  disaggregated (``DisaggServeEngine``, KV-page handoff between the
  phase engines); every request's token stream must match exactly.
- **latency gate**: an MMPP (bursty) trace over *matched* simulated
  hardware — 4 colocated replicas vs 1 prefill + 3 decode replicas of
  the **same** device latency table.  Disaggregation must win BOTH p95
  TTFT and p95 TPOT: decode iterations stop paying the chunk-interleave
  tax, prefill stops queueing behind decode occupancy.
- **fleet plan gate**: phase-specialized SKU planning
  (``plan_disagg_fleet`` over the same candidate list crossed with
  itself) must beat the best feasible colocated plan on fleet die-mm²
  AND J/token for a decode-heavy reasoning envelope under a TTFT SLO
  that colocated RPU silicon cannot meet.

``main()`` adds the slow tier: byte-identity under fp8 KV, speculative
decoding, and page-pressure preemption; the latency gate across seeds;
and writes ``experiments/bench_disagg.json``.

  PYTHONPATH=src python -m benchmarks.disagg --smoke
  PYTHONPATH=src python -m benchmarks.disagg
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import Row, dump
from repro.configs import get_config
from repro.fleet import (SLO, DisaggFleetSimulator, FleetSimulator,
                         LatencyTable, PrefixAffinityRouter, ReplicaSpec,
                         TrafficEnvelope, default_candidates,
                         plan_disagg_fleet, plan_fleet)
from repro.fleet import traffic as tr
from repro.models.common import ModelConfig
from repro.models.model import build_model
from repro.runtime.deployment import DeploymentSpec

# ---------------------------------------------------------------------------
# byte-identity: real engines, colocated vs disaggregated
# ---------------------------------------------------------------------------

_BENCH_CFG = ModelConfig(name="disagg-bench", family="dense", n_layers=2,
                         d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
                         d_ff=256, vocab_size=512)


def _mk_requests(cfg, n: int, seed: int, *, prefix_len: int = 12,
                 max_new: int = 8) -> list:
    """Ragged greedy requests; even rids share a prompt prefix so the
    handoff exercises prefix-cache admission on the decode side."""
    from repro.runtime.sampling import SamplingParams
    from repro.runtime.scheduler import Request

    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(6, 20))).astype(np.int32)
        prompt = np.concatenate([shared, tail]) if i % 2 == 0 else tail
        out.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new,
                           sampling=SamplingParams(max_tokens=max_new)))
    return out


def byte_identity_rows(*, cache_dtype=None, speculative: bool = False,
                       num_pages: int = 48, max_len: int = 64,
                       max_new: int = 8, require_preemption: bool = False,
                       label: str = "base", seed: int = 3) -> list[Row]:
    import jax
    import jax.numpy as jnp
    from repro.runtime.engine import ContinuousServeEngine, DisaggServeEngine
    from repro.runtime.speculative import SpeculativeConfig

    cfg = _BENCH_CFG
    model = build_model(cfg)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)))
    kw = dict(num_slots=4, page_size=4, num_pages=num_pages, max_len=max_len,
              cache_dtype=cache_dtype or jnp.float32, prefill_chunk=8,
              enable_prefix_cache=True,
              speculative=SpeculativeConfig(gamma=3) if speculative
              else None)
    co = ContinuousServeEngine(model, params, **kw)
    dis = DisaggServeEngine(model, params, **kw)
    s_co = co.run(_mk_requests(cfg, 8, seed, max_new=max_new))
    s_di = dis.run(_mk_requests(cfg, 8, seed, max_new=max_new))
    assert set(s_co.outputs) == set(s_di.outputs)
    for rid in sorted(s_co.outputs):
        a = list(s_co.outputs[rid].token_ids)
        b = list(s_di.outputs[rid].token_ids)
        assert a == b, f"[{label}] rid {rid}: colocated {a} != disagg {b}"
    if require_preemption:
        # the gate must actually exercise the evict -> drain back to
        # prefill -> re-handoff path, not merely survive a small pool
        assert s_di.preemptions > 0, \
            f"[{label}] settings no longer force preemption"
    return [Row("ours:disagg", f"byte-identity ({label})",
                "identical",
                note=f"8 reqs, {s_di.handoffs} handoffs, "
                     f"{s_di.handoff_pages} pages, "
                     f"{s_di.handoff_shared_tokens} shared tok, "
                     f"preemptions {s_di.preemptions}")]


# ---------------------------------------------------------------------------
# latency: MMPP over matched simulated hardware
# ---------------------------------------------------------------------------


def latency_rows(seed: int = 5, requests: int = 400) -> list[Row]:
    import dataclasses

    model = build_model(get_config("qwen3-14b"))
    spec = DeploymentSpec(sku="h200", max_len=2048, weight_format="mxfp4",
                          cache_dtype="fp8", max_slots=32)
    r = spec.resolve(model)
    # honest chunk pricing: the bandwidth-roofline table floors prefill
    # near zero, but chunks are compute-bound — take the per-row cost
    # from the prefill-phase compute roofline instead.  The SAME table
    # serves both fleets, so the comparison is matched hardware exactly.
    rp = spec.resolve(model, phase="prefill")
    chunk_s = rp.step_seconds / max(rp.num_slots, 1)
    table = dataclasses.replace(LatencyTable.from_roofline(r),
                                prefill_chunk_s=float(chunk_s))
    rspec = ReplicaSpec(latency=table, num_slots=r.num_slots,
                        max_queue=2 * r.num_slots, page_size=r.page_size,
                        prefix_blocks=64)
    lengths = tr.LengthMix(prompt_mean=512.0, prompt_sigma=0.3,
                           prompt_min=128, prompt_max=1024,
                           output_mean=128.0, output_min=32, output_max=256)
    tenants = tr.TenantMix(n_tenants=8, prefix_len=128, zipf_s=0.8)
    trace = tr.make_trace(requests, seed, kind="mmpp", rate=30.0,
                          lengths=lengths, tenants=tenants)
    co = FleetSimulator(rspec, 4, PrefixAffinityRouter()).run(trace)
    dis = DisaggFleetSimulator(
        rspec, 2, rspec, 2, PrefixAffinityRouter(),
        kv_token_bytes=r.kv_token_bytes, handoff_gbs=64.0).run(trace)
    ct, dt = co.ttft_quantiles(), dis.ttft_quantiles()
    cp, dp = co.tpot_quantiles(), dis.tpot_quantiles()
    rows = [
        Row("ours:disagg", f"p95 TTFT, MMPP (seed {seed})",
            round(dt["p95"] * 1e3, 2), unit=" ms",
            note=f"colocated {ct['p95'] * 1e3:.2f} ms, matched 4 replicas"),
        Row("ours:disagg", f"p95 TPOT, MMPP (seed {seed})",
            round(dp["p95"] * 1e3, 3), unit=" ms",
            note=f"colocated {cp['p95'] * 1e3:.3f} ms"),
        Row("ours:disagg", f"handoff volume (seed {seed})",
            dis.handoffs,
            note=f"{dis.handoff_bytes / 1e9:.2f} GB moved, "
                 f"{dis.handoff_shared_tokens} tok prefix-shared"),
    ]
    # the headline gate: phase separation wins BOTH tails at matched iron
    assert dt["p95"] < ct["p95"], \
        f"seed {seed}: disagg p95 TTFT {dt['p95']:.4f}s >= " \
        f"colocated {ct['p95']:.4f}s"
    assert dp["p95"] < cp["p95"], \
        f"seed {seed}: disagg p95 TPOT {dp['p95']:.5f}s >= " \
        f"colocated {cp['p95']:.5f}s"
    assert len(dis.served) >= len(co.served), \
        f"disagg served {len(dis.served)} < colocated {len(co.served)}"
    return rows


# ---------------------------------------------------------------------------
# fleet planning: phase-specialized SKUs
# ---------------------------------------------------------------------------


def plan_rows() -> list[Row]:
    model = build_model(get_config("qwen3-14b"))
    lengths = tr.LengthMix(prompt_mean=512.0, prompt_min=64, prompt_max=1024,
                           output_mean=256.0, output_min=32, output_max=512)
    trace = tr.make_trace(600, 0, kind="diurnal", rate=200.0, lengths=lengths)
    env = TrafficEnvelope.from_trace(trace)
    # tight TTFT: colocated RPU silicon cannot chunk prompts fast enough,
    # so the colocated planner is forced onto compute-dense GPUs for
    # everything — the split gets to keep them for prefill only
    slo = SLO(ttft_s=0.4, tpot_s=0.05)
    base = DeploymentSpec(max_len=2048, weight_format="mxfp4",
                          cache_dtype="fp8", max_slots=32)
    cands = default_candidates(model, base)
    co_best, _ = plan_fleet(model, env, slo, cands)
    d_best, _ = plan_disagg_fleet(model, env, slo, cands, cands)
    die_win = co_best.die_mm2 / d_best.die_mm2
    energy_win = co_best.energy_j_per_token / d_best.energy_j_per_token
    rows = [
        Row("ours:disagg", "phase-specialized plan",
            f"{d_best.prefill.name} x {d_best.prefill.replicas} prefill + "
            f"{d_best.decode.name} x {d_best.decode.replicas} decode",
            note=f"colocated pick {co_best.name} x {co_best.replicas}"),
        Row("ours:disagg", "fleet die-mm2 vs colocated plan",
            round(die_win, 2), unit="x",
            note=f"{d_best.die_mm2:.0f} vs {co_best.die_mm2:.0f} mm2"),
        Row("ours:disagg", "fleet J/token vs colocated plan",
            round(energy_win, 2), unit="x",
            note=f"{d_best.energy_j_per_token:.4f} vs "
                 f"{co_best.energy_j_per_token:.4f}"),
    ]
    assert d_best.feasible and co_best.feasible
    assert d_best.die_mm2 < co_best.die_mm2, \
        f"disagg die {d_best.die_mm2:.0f} >= colocated {co_best.die_mm2:.0f}"
    assert d_best.energy_j_per_token < co_best.energy_j_per_token, \
        f"disagg {d_best.energy_j_per_token:.4f} J/tok >= " \
        f"colocated {co_best.energy_j_per_token:.4f}"
    return rows


# ---------------------------------------------------------------------------
# tiers
# ---------------------------------------------------------------------------


def run() -> list[Row]:
    """Fast tier for ``benchmarks.run``: all three gates, small sizes."""
    return byte_identity_rows() + latency_rows() + plan_rows()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast tier only")
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.smoke:
        rows = run()
    else:
        rows = byte_identity_rows()
        rows += byte_identity_rows(cache_dtype="fp8", label="fp8 KV")
        rows += byte_identity_rows(speculative=True, label="speculative")
        rows += byte_identity_rows(num_pages=16, max_len=56, max_new=24,
                                   require_preemption=True,
                                   label="page pressure", seed=9)
        for seed in (5, 11, 23):
            rows += latency_rows(seed=seed, requests=800)
        rows += plan_rows()
    for r in rows:
        print(r.render())
    dump(rows, "disagg")
    print(f"[{time.time() - t0:.1f}s] all disagg gates passed "
          f"-> experiments/bench_disagg.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
