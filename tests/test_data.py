"""Data pipeline: determinism, host sharding, straggler mitigation."""
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.data.pipeline import SyntheticTokenPipeline


@pytest.fixture()
def cfg():
    return reduced_config(get_config("qwen3-14b"))


def test_batches_deterministic(cfg):
    p1 = SyntheticTokenPipeline(cfg, global_batch=4, seq_len=16, seed=7)
    p2 = SyntheticTokenPipeline(cfg, global_batch=4, seq_len=16, seed=7)
    for step in (0, 1, 5):
        np.testing.assert_array_equal(p1.get_batch(step)["tokens"],
                                      p2.get_batch(step)["tokens"])


def test_batches_differ_across_steps_and_shards(cfg):
    p = SyntheticTokenPipeline(cfg, global_batch=4, seq_len=16, seed=7)
    assert not np.array_equal(p.get_batch(0)["tokens"], p.get_batch(1)["tokens"])
    pa = SyntheticTokenPipeline(cfg, global_batch=8, seq_len=16, shard=0,
                                n_shards=2)
    pb = SyntheticTokenPipeline(cfg, global_batch=8, seq_len=16, shard=1,
                                n_shards=2)
    assert pa.local_batch == 4
    assert not np.array_equal(pa.get_batch(0)["tokens"],
                              pb.get_batch(0)["tokens"])


def test_straggler_fallback_reuses_last_batch(cfg):
    """A slow fetch beyond the timeout falls back to the last good batch
    instead of stalling the step (bounded reuse)."""
    slow_steps = {3, 4}
    p = SyntheticTokenPipeline(
        cfg, global_batch=4, seq_len=16, straggler_timeout_s=0.01,
        delay_fn=lambda s: 0.2 if s in slow_steps else 0.0)
    b2 = p.get_batch(2)
    b3 = p.get_batch(3)          # slow -> reuse of b2
    np.testing.assert_array_equal(b2["tokens"], b3["tokens"])
    assert p.stats.straggler_fallbacks >= 1
    b5 = p.get_batch(5)          # fast again -> fresh data
    assert not np.array_equal(b5["tokens"], b2["tokens"])


def test_straggler_reuse_budget_blocks_for_fresh_data(cfg):
    """After max_batch_reuse consecutive fallbacks the pipeline must stop
    reusing stale data and block for a real batch."""
    p = SyntheticTokenPipeline(
        cfg, global_batch=2, seq_len=8, straggler_timeout_s=0.01,
        max_batch_reuse=2, delay_fn=lambda s: 0.2 if s >= 1 else 0.0)
    b0 = p.get_batch(0)
    b1 = p.get_batch(1)          # reuse 1
    b2 = p.get_batch(2)          # reuse 2
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])
    np.testing.assert_array_equal(b0["tokens"], b2["tokens"])
    b3 = p.get_batch(3)          # budget exhausted -> blocking fresh fetch
    assert not np.array_equal(b3["tokens"], b0["tokens"])
    assert p.stats.max_reuse_run == 2 or p.stats.straggler_fallbacks >= 3
