from repro.data.pipeline import SyntheticTokenPipeline, PipelineStats
