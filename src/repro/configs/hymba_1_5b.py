"""Hymba-1.5B — hybrid heads: attention and Mamba(2) SSM in parallel in
every layer; SWA except a few global layers.  [arXiv:2411.13676]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001, vocab_pad_multiple=512,
    sliding_window=1024,
    global_attn_every=16,      # global attention at layers 0, 16, 31
    ssm=True,
    hybrid=True,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,              # d_inner = 3200 -> 50 ssm heads
    ssm_chunk=256,
)
