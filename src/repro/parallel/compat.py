"""JAX version compatibility shims for the parallel substrate.

The repo targets current JAX (`jax.shard_map`, `AbstractMesh(shape, axes)`)
but must also run on 0.4.x images where shard_map still lives under
``jax.experimental`` and ``AbstractMesh`` takes ``((name, size), ...)``
pairs.  Import ``shard_map`` / ``make_abstract_mesh`` from here instead of
touching ``jax`` directly.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:                                    # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def _spec_axes(specs):
        """Mesh axis names referenced anywhere in a specs pytree."""
        from jax.sharding import PartitionSpec
        names: set[str] = set()
        for spec in jax.tree.leaves(
                specs, is_leaf=lambda s: isinstance(s, PartitionSpec)):
            if not isinstance(spec, PartitionSpec):
                continue
            for entry in spec:
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    names.add(a)
        return names

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        """Map the modern kwargs onto the experimental signature.

        0.4.x's partial-manual mode (``auto=``) hard-crashes the XLA:CPU
        partitioner on some programs, so the shim runs FULLY manual
        instead.  That is semantically identical as long as the specs never
        mention a non-manual axis (the body then sees data replicated over
        those axes and recomputes redundantly) — asserted below, and true
        for every call site in this repo.
        """
        if axis_names is not None:
            extra = _spec_axes((in_specs, out_specs)) - frozenset(axis_names)
            if extra:
                raise NotImplementedError(
                    f"jax<0.5 shard_map shim: specs reference non-manual "
                    f"axes {sorted(extra)}; partial-manual is unsupported")
        return _shard_map_experimental(f, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs,
                                       check_rep=check_vma)


def make_abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """AbstractMesh across the signature change (positional shape+axes vs
    a single tuple of (name, size) pairs)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))
