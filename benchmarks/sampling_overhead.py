"""Fused per-slot sampler overhead on the continuous decode step.

The per-request generation API fuses a batched per-slot sampler
(temperature / top-k / top-p / min-p / seeded PRNG streams) into the
jitted paged decode step.  The promise is that request-level sampling is
effectively free on the hot path: all controls are ``(num_slots,)`` data
arrays, top-k thresholds come from one static ``lax.top_k``, and the model
forward dominates.  This benchmark measures the fused step against a
greedy-argmax-only step on the same model/pools and asserts the sampler
adds < ``--tolerance`` (default 5%) decode-step latency on CPU.

  PYTHONPATH=src python -m benchmarks.sampling_overhead [--slots 8]
      [--iters 50] [--tolerance 0.05]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, dump
from repro.models.common import ModelConfig
from repro.models.model import build_model
from repro.runtime import sampling

# Same scale as benchmarks.continuous_batching: big enough that a decode
# step is compute/bandwidth-dominated on CPU, small enough to compile fast.
BENCH_CONFIG = ModelConfig(
    name="bench-sampling", family="dense", n_layers=6, d_model=384,
    n_heads=8, n_kv_heads=4, head_dim=48, d_ff=1024, vocab_size=2048,
)
PAGE = 16
CTX = 64          # resident context per slot when measuring


def _interleaved_medians(fns_args: list, iters: int) -> list[float]:
    """Median step time per variant, measured round-robin so machine load
    spikes hit every variant equally (this box swings ±40% run to run)."""
    times = [[] for _ in fns_args]
    for _ in range(iters):
        for i, (fn, args) in enumerate(fns_args):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args)[0])
            times[i].append(time.perf_counter() - t0)
    return [float(np.median(t)) for t in times]


def run(slots: int = 8, iters: int = 50, seed: int = 0) -> tuple[list[Row], float]:
    model = build_model(BENCH_CONFIG)
    params = model.init(jax.random.PRNGKey(seed))
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        params)
    blocks = -(-CTX // PAGE) + 1
    num_pages = 1 + slots * blocks
    pools = model.init_paged_cache(num_pages, PAGE, dtype=jnp.float32)
    table = jnp.asarray(
        1 + np.arange(slots * blocks, dtype=np.int32).reshape(slots, blocks))
    tokens = jnp.asarray(
        np.random.default_rng(seed).integers(
            0, BENCH_CONFIG.vocab_size, slots).astype(np.int32))
    pos = jnp.full((slots,), CTX, jnp.int32)

    @jax.jit
    def step_greedy(pools, tokens, pos):
        logits, pools = model.decode_step_paged(params, tokens, pools, table,
                                                pos)
        return sampling.greedy(logits), pools

    # a heterogeneous worst-case mix: every slot stochastic with top-k AND
    # top-p AND min-p active (greedy slots only skip work on the host side)
    samp = sampling.stack_params([
        sampling.SamplingParams(temperature=0.7 + 0.05 * i, top_k=40,
                                top_p=0.9, min_p=0.05, seed=i)
        for i in range(slots)])
    samp = tuple(jnp.asarray(a) for a in samp)

    @jax.jit
    def step_sampled(pools, tokens, pos, temp, topk, topp, minp, sd):
        logits, pools = model.decode_step_paged(params, tokens, pools, table,
                                                pos)
        nxt, _ = sampling.sample_slots(logits, temp, topk, topp, minp, sd,
                                       pos + 1)
        return nxt, pools

    # warm both compilations
    jax.block_until_ready(step_greedy(pools, tokens, pos)[0])
    jax.block_until_ready(step_sampled(pools, tokens, pos, *samp)[0])

    greedy_s, sampled_s = _interleaved_medians(
        [(step_greedy, (pools, tokens, pos)),
         (step_sampled, (pools, tokens, pos, *samp))], iters)
    overhead = sampled_s / greedy_s - 1.0
    rows = [
        Row("ours:sampling", f"greedy decode step (slots={slots})",
            greedy_s * 1e3, None, "ms", "argmax only, median"),
        Row("ours:sampling", "fused per-slot sampled decode step",
            sampled_s * 1e3, None, "ms",
            "temp+top-k+top-p+min-p+seeded streams, every slot stochastic"),
        Row("ours:sampling", "sampler overhead", overhead, None, "",
            "fraction of decode-step latency; budget < 5%"),
    ]
    return rows, overhead


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="max allowed fractional overhead (default 5%)")
    args = ap.parse_args(argv)
    rows, overhead = run(args.slots, args.iters, args.seed)
    for r in rows:
        print(r.render())
    dump(rows, "sampling_overhead")
    if overhead >= args.tolerance:
        print(f"FAIL: sampler overhead {overhead:.1%} >= "
              f"{args.tolerance:.0%} budget", file=sys.stderr)
        return 1
    print(f"ok: sampler overhead {overhead:.1%} < {args.tolerance:.0%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
