"""Quantized execution end to end: mxfp4 weight matmuls and fp8/int8
paged KV pools in the serve path.

The contracts under test:

  * E2M1 rounding is OCP-MX round-to-nearest-even (every midpoint picks
    the even mantissa) and non-finite inputs saturate to +/-6.0;
  * the fused paged decode kernel with fp8/int8 code pools is bit-exact
    against the dequant oracle in ``accum="exact"`` interpret mode (the
    in-loop dequant is the same f32-cast-then-multiply op sequence);
  * greedy serving with ``weight_format="mxfp4"`` (and quantized KV on
    top) emits the SAME tokens as the dense bf16 engine once the weights
    are round-tripped through mxfp4 — quantization is idempotent, so the
    packed engine and the dense engine compute identical matmuls;
  * budget == execution: ``DeploymentSpec.resolve`` reports exactly the
    bytes ``quantize_params`` / ``init_paged_cache`` allocate.
"""
import warnings

import numpy as np
import pytest

import repro.models  # noqa: F401  (import order: models before kernels.ref)
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.kernels.decode_attention.paged_kernel import paged_decode_attention
from repro.kernels.decode_attention.ref import paged_decode_attention_ref
from repro.kernels.mxfp4_vmm import ops as vmm_ops
from repro.models.model import build_model
from repro.parallel.plan import paged_kv_token_bytes
from repro.quant import formats
from repro.quant import kv as kvq
from repro.quant.linear import quantizable_leaf, quantize_params, \
    serve_weight_bytes
from repro.runtime.deployment import DeploymentSpec
from repro.runtime.engine import ContinuousServeEngine, ServeEngine
from repro.runtime.scheduler import Request


# ---------------------------------------------------------------------------
# E2M1 rounding (quant-format correctness satellites)
# ---------------------------------------------------------------------------


def _fp4_decode(codes: np.ndarray) -> np.ndarray:
    return formats.FP4_VALUES[codes & 7] * np.where(codes >> 3, -1.0, 1.0)


def test_fp4_rne_midpoints_exhaustive():
    """All 7 E2M1 midpoints, both signs: round-half-to-even mantissa."""
    mids = [0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0]
    want = [0.0, 1.0, 1.0, 2.0, 2.0, 4.0, 4.0]
    x = jnp.asarray(mids + [-m for m in mids], jnp.float32)
    codes = np.asarray(formats._quantize_fp4_codes(x))
    np.testing.assert_array_equal(
        _fp4_decode(codes),
        np.asarray(want + [-w for w in want], np.float32))


def test_fp4_off_midpoints_round_to_nearest():
    rng = np.random.default_rng(0)
    x = rng.uniform(-7.0, 7.0, 512).astype(np.float32)
    mids = (formats.FP4_VALUES[1:] + formats.FP4_VALUES[:-1]) / 2
    for m in mids:                       # ties are tested exhaustively above
        x = np.where(np.isclose(np.abs(x), m), x + 1e-3, x)
    codes = np.asarray(formats._quantize_fp4_codes(jnp.asarray(x)))
    expect_mag = formats.FP4_VALUES[
        np.argmin(np.abs(np.abs(x)[:, None] - formats.FP4_VALUES[None, :]),
                  axis=1)]
    np.testing.assert_array_equal(
        _fp4_decode(codes), np.where(x < 0, -1.0, 1.0) * expect_mag)


def test_fp4_nonfinite_saturates_to_six():
    x = jnp.asarray([np.inf, -np.inf, np.nan], jnp.float32)
    codes = np.asarray(formats._quantize_fp4_codes(x))
    assert np.all(formats.FP4_VALUES[codes & 7] == 6.0)
    assert (codes[0] >> 3) == 0 and (codes[1] >> 3) == 1


def test_mxfp4_tileable_llama3_8b_projections_and_fallback_stats():
    """Every llama3-8b serve projection takes the Pallas kernel path; a
    non-tileable shape falls back to the oracle, counted not silent."""
    for k, n in [(4096, 4096),    # wq / wo
                 (4096, 1024),    # wk / wv (8 KV heads x 128)
                 (4096, 14336),   # w_gate / w_up
                 (14336, 4096)]:  # w_down
        assert vmm_ops.mxfp4_tileable(k, n), (k, n)
    # K=544 is 32-aligned (quantizable) but not 512-tileable
    assert not vmm_ops.mxfp4_tileable(544, 8)
    qw = formats.quantize(
        jax.random.normal(jax.random.PRNGKey(0), (544, 8), jnp.float32),
        "mxfp4")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 544), jnp.bfloat16)
    before = vmm_ops.FALLBACK_STATS["fallback"]
    with warnings.catch_warnings():      # one-shot warning may have fired
        warnings.simplefilter("ignore", RuntimeWarning)
        out = vmm_ops.mxfp4_matmul(x, qw, impl="fused")
    assert vmm_ops.FALLBACK_STATS["fallback"] == before + 1
    ref = vmm_ops.mxfp4_matmul(x, qw, impl="reference")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# fp8/int8 KV quantization + the fused paged decode kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cd", ["fp8", "int8"])
def test_kv_quantize_roundtrip(cd):
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 6, 2, 16),
                          jnp.float32) * 3.0
    codes, scales = kvq.kv_quantize(x, cd)
    assert codes.dtype == kvq.cache_storage_dtype(cd)
    assert scales.dtype == kvq.SCALE_DTYPE and scales.shape == x.shape[:-1]
    xd = np.asarray(kvq.kv_dequantize(codes, scales, jnp.float32))
    tol = 0.07 if cd == "fp8" else 0.01      # e4m3 step vs 1/127
    err = np.max(np.abs(xd - np.asarray(x)), axis=-1)
    amax = np.max(np.abs(np.asarray(x)), axis=-1)
    assert np.all(err <= tol * amax)
    # all-zero vectors quantize to scale 1.0 (finite dequant)
    zc, zs = kvq.kv_quantize(jnp.zeros((2, 3, 8)), cd)
    np.testing.assert_array_equal(np.asarray(zs), 1.0)
    np.testing.assert_array_equal(
        np.asarray(kvq.kv_dequantize(zc, zs)), 0.0)


def _quantized_paged_case(seed, cache, B=3, H=8, KVH=2, D=32, page=8,
                          n_blocks=5):
    """Quantized page pools + permuted page tables + ragged positions."""
    key = jax.random.PRNGKey(seed)
    P = 1 + B * n_blocks
    rng = np.random.default_rng(seed)
    ids = rng.permutation(np.arange(1, P))
    table = jnp.asarray(ids[:B * n_blocks].reshape(B, n_blocks), jnp.int32)
    q = jax.random.normal(key, (B, H, D), jnp.float32)
    kp = jax.random.normal(jax.random.fold_in(key, 1), (P, page, KVH, D))
    vp = jax.random.normal(jax.random.fold_in(key, 2), (P, page, KVH, D))
    pos = jnp.asarray(rng.integers(0, page * n_blocks, B), jnp.int32)
    kc, ks = kvq.kv_quantize(kp, cache)
    vc, vs = kvq.kv_quantize(vp, cache)
    return q, kc, ks, vc, vs, table, pos


@pytest.mark.parametrize("cd", ["fp8", "int8"])
def test_quantized_paged_kernel_exact_bitwise(cd):
    """Fused in-loop dequant == oracle dequant, bit for bit."""
    q, kc, ks, vc, vs, table, pos = _quantized_paged_case(3, cd)
    ref = paged_decode_attention_ref(q, kc, vc, table, pos,
                                     k_scales=ks, v_scales=vs)
    out = paged_decode_attention(q, kc, vc, table, pos, k_scales=ks,
                                 v_scales=vs, accum="exact", interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("cd,window", [("fp8", None), ("fp8", 5),
                                       ("int8", None)])
def test_quantized_paged_kernel_online_close(cd, window):
    q, kc, ks, vc, vs, table, pos = _quantized_paged_case(7, cd)
    ref = np.asarray(paged_decode_attention_ref(
        q, kc, vc, table, pos, k_scales=ks, v_scales=vs, window=window),
        np.float32)
    out = np.asarray(paged_decode_attention(
        q, kc, vc, table, pos, k_scales=ks, v_scales=vs, window=window,
        accum="online", interpret=True), np.float32)
    np.testing.assert_allclose(out, ref, rtol=2e-6, atol=2e-6)


def test_quantized_vs_dense_attention_close():
    """A quantized pool approximates the dense pool it was written from."""
    q, kc, ks, vc, vs, table, pos = _quantized_paged_case(11, "fp8")
    kd = kvq.kv_dequantize(kc, ks, jnp.float32)
    vd = kvq.kv_dequantize(vc, vs, jnp.float32)
    dense = np.asarray(paged_decode_attention_ref(q, kd, vd, table, pos),
                       np.float32)
    quant = np.asarray(paged_decode_attention_ref(
        q, kc, vc, table, pos, k_scales=ks, v_scales=vs), np.float32)
    np.testing.assert_array_equal(quant, dense)   # same dequant values


# ---------------------------------------------------------------------------
# End-to-end serving: bf16 == mxfp4 == mxfp4 + quantized KV (greedy)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    """Reduced model whose projection weights are round-tripped through
    mxfp4: quantization is then idempotent, so the packed engine computes
    bit-identical matmuls to the dense engine and greedy tokens match
    EXACTLY (the e2e acceptance contract)."""
    cfg = reduced_config(get_config("qwen3-14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))

    def rt(path, leaf):
        if quantizable_leaf(path, leaf, "mxfp4"):
            p = formats.quantize(leaf, "mxfp4")
            return formats.dequantize(p, "mxfp4").astype(leaf.dtype)
        return leaf

    params = jax.tree_util.tree_map_with_path(rt, params)
    return cfg, model, params


def _greedy(model, params, **kw):
    eng = ContinuousServeEngine(model, params, num_slots=3, page_size=4,
                                num_pages=32, max_len=24, prefill_chunk=5,
                                **kw)
    for i in range(3):
        eng.add_request(Request(rid=i,
                                prompt=np.arange(1 + i, 6 + i,
                                                 dtype=np.int32),
                                max_new_tokens=8))
    while eng.has_unfinished():
        eng.step()
    return eng, [list(r.tokens) for r in eng._requests]


@pytest.fixture(scope="module")
def ref_tokens(served):
    _, model, params = served
    _, toks = _greedy(model, params, cache_dtype=jnp.float32)
    return toks


def test_mxfp4_engine_matches_dense_greedy_exactly(served, ref_tokens):
    _, model, params = served
    eng, toks = _greedy(model, params, cache_dtype=jnp.float32,
                        weight_format="mxfp4")
    assert toks == ref_tokens
    packed = [l for l in jax.tree.leaves(
        eng.params, is_leaf=lambda x: isinstance(x, formats.PackedMXFP4))
        if isinstance(l, formats.PackedMXFP4)]
    assert len(packed) == 7          # wq wk wv wo w_gate w_up w_down


@pytest.mark.parametrize("cd", ["fp8", "int8"])
def test_quantized_kv_engine_matches_dense_greedy(served, ref_tokens, cd):
    """mxfp4 weights + quantized paged KV: same greedy stream on short
    sequences (seeded so near-ties in the logits don't flip argmax)."""
    _, model, params = served
    eng, toks = _greedy(model, params, cache_dtype=cd,
                        weight_format="mxfp4")
    assert toks == ref_tokens
    assert eng.kv_token_bytes_per_device() \
        == paged_kv_token_bytes(model, cache_dtype=cd) \
        < paged_kv_token_bytes(model, cache_dtype=jnp.float32)


def test_static_engine_rejects_quantized_cache(served):
    _, model, params = served
    with pytest.raises(NotImplementedError, match="cache_dtype"):
        ServeEngine(model, params, max_len=24, cache_dtype="fp8")


def test_mla_quantized_pool_not_implemented():
    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    model = build_model(cfg)
    with pytest.raises(NotImplementedError, match="MLA"):
        model.init_paged_cache(2, 1, dtype="fp8")


def test_unknown_cache_dtype_rejected(served):
    _, model, params = served
    with pytest.raises(ValueError, match="cache_dtype"):
        ContinuousServeEngine(model, params, num_slots=2, page_size=4,
                              num_pages=8, max_len=16, cache_dtype="fp4")


# ---------------------------------------------------------------------------
# Budget == execution
# ---------------------------------------------------------------------------


def test_resolved_weight_bytes_equal_allocated_bytes(served):
    """``resolve`` prices weights at the EXACT bytes ``quantize_params``
    allocates — packed codes+scales for quantizable leaves, native bytes
    for the rest."""
    _, model, params = served
    spec = DeploymentSpec(sku="rpu-cu", hbmco="hbmco-768MB",
                          weight_format="mxfp4", cache_dtype="fp8",
                          max_len=24, page_size=4, max_slots=3)
    dep = spec.resolve(model, params=params)
    qp = quantize_params(params, "mxfp4")
    allocated = sum(int(np.asarray(l).nbytes) for l in jax.tree.leaves(qp))
    assert dep.weight_bytes_per_device == allocated \
        == serve_weight_bytes(params, "mxfp4")


@pytest.mark.parametrize("cd", ["fp8", "int8", jnp.float32])
def test_paged_kv_token_bytes_match_pool_allocation(served, cd):
    """The accounting helper reports exactly what a pool of that dtype
    allocates, scale metadata included."""
    _, model, _ = served
    per_tok = paged_kv_token_bytes(model, cache_dtype=cd)
    num_pages, page_size = 3, 2
    pools = model.init_paged_cache(num_pages, page_size, dtype=cd)
    total = sum(int(np.asarray(l).nbytes) for l in jax.tree.leaves(pools))
    assert total == per_tok * num_pages * page_size
    if isinstance(cd, str):
        # codes shrink 4x vs f32; the f32 scale leaves are the remainder
        dense = paged_kv_token_bytes(model, cache_dtype=jnp.float32)
        assert dense // 4 < per_tok < dense // 2
