"""Public op wrappers for the decode-attention kernel (dense and paged)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.paged_kernel import paged_decode_attention
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, paged_decode_attention_ref,
)


def gqa_decode_attention(q, k_cache, v_cache, cur_len, *, block_s: int = 512):
    """(B,H,D) x (B,S,KVH,D) cache -> (B,H,D); kernel when tiles fit,
    jnp oracle otherwise (tiny smoke shapes / ragged S)."""
    s = k_cache.shape[1]
    bs = min(block_s, s)
    if s % bs != 0 or q.shape[1] % k_cache.shape[2] != 0:
        return decode_attention_ref(q, k_cache, v_cache, cur_len)
    return decode_attention(q, k_cache, v_cache, cur_len, block_s=bs,
                            interpret=on_cpu())


def paged_gqa_decode_attention(q, k_pages, v_pages, page_table, pos, *,
                               k_scales=None, v_scales=None,
                               window=None, impl: str = "auto"):
    """Paged single-token decode attention behind one of two impls:

      * ``"fused"``     — the gather-fused Pallas kernel: the page table
        drives the grid, each K/V page streams HBM->VMEM straight into the
        flash-decode accumulator.  No dense ``(B, S, KVH, D)`` intermediate.
      * ``"reference"`` — gather-then-dense jnp oracle; the bit-exact
        counterpart of the dense serve path.

    ``"auto"`` takes the oracle on CPU (where the fused kernel would run in
    slow interpret mode, and token-exactness with the dense engine is the
    test contract) and the fused kernel on accelerators.  Tests exercise
    the fused kernel on CPU explicitly via ``impl="fused"`` +
    ``interpret=True`` inside ``paged_decode_attention``.
    """
    if impl == "auto":
        impl = "reference" if on_cpu() else "fused"
    if impl == "reference":
        return paged_decode_attention_ref(q, k_pages, v_pages, page_table,
                                          pos, k_scales=k_scales,
                                          v_scales=v_scales, window=window)
    if impl != "fused":
        raise ValueError(f"impl={impl!r} (want 'auto', 'fused' or 'reference')")
    return paged_decode_attention(q, k_pages, v_pages, page_table,
                                  pos.astype(jnp.int32), k_scales=k_scales,
                                  v_scales=v_scales, window=window,
                                  interpret=on_cpu())
