"""Paper Fig 10: HBM-CO SKU selection map for Llama4-Maverick on 64 CUs
(batch x sequence-length grid) + slowdown sub-metrics."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.models.footprint import compute_footprint
from repro.sim.scaling import rpu_point, select_sku_for


def run() -> list[Row]:
    cfg = get_config("llama4-maverick-400b-a17b")
    fp = compute_footprint(cfg)
    rows: list[Row] = []
    base = rpu_point(cfg, 64, batch=1, seq_len=8192)
    grid = []
    for batch in (1, 8, 32, 128):
        for seq in (8192, 32768, 131072):
            sku = select_sku_for(cfg, 64, batch=batch, seq_len=seq)
            if sku is None:
                grid.append(f"b{batch}/s{seq//1024}k:none")
                continue
            p = rpu_point(cfg, 64, batch=batch, seq_len=seq, sku=sku)
            kv_frac = fp.kv_bytes(batch, seq) / fp.capacity_bytes(batch, seq)
            grid.append(
                f"b{batch}/s{seq//1024}k:{sku.bw_per_cap:.0f}"
                f"({p.ms_per_token/base.ms_per_token:.1f}x,kv={kv_frac:.0%})")
    rows.append(Row("Fig10", "maverick 64CU SKU map (BW/Cap, slowdown, KV%)",
                    "  ".join(grid), None, "",
                    "high BW/Cap best for low-batch; KV$>50% at b8/128k"))
    kv_frac_8_128k = fp.kv_bytes(8, 131072) / fp.capacity_bytes(8, 131072)
    rows.append(Row("Fig10", "KV$ fraction of active bytes at BS=8 128k",
                    kv_frac_8_128k, 0.5, "",
                    "paper: >50% of active parameters are KV$"))
    return rows
