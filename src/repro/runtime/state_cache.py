"""Stateful cache layouts: SSM/hybrid state pools and ring-page spaces.

The paged-KV engine (``runtime/engine.py``) was built for one residency
model: every layer streams full-context KV through ref-counted pages.
The paper's capacity/bandwidth trade (RPU §II-III) has two limiting
cases that model cannot serve:

  * **constant state** — SSM blocks keep a fixed-size recurrent state
    per sequence (conv tail + SSD state, ``models/ssm.py``) and write no
    token-indexed pages at all;
  * **O(window) residency** — sliding-window attention only ever reads
    the last ``window`` keys, so pages wholly behind the window are dead
    weight (the mask skips them; PR 4 landed the mask, this module lands
    the capacity half).

This module is the host-side bookkeeping for both:

  * ``SegmentCacheLayout`` / ``ModelCacheLayout`` — classify each scanned
    segment of a model plan by what it keeps resident (``full`` pages,
    ``ring`` pages, per-slot ``state``), derived from the per-kind
    ``CacheLayout`` registry in ``models/attention_backends.py`` plus the
    segment's window.  The engine uses this one classification everywhere
    it must treat spaces differently (page walkers, defrag, prefix
    scoping, deployment accounting).
  * ``RingPageSpace`` — a second ``PageAllocator`` + page table whose
    blocks are reclaimed as the window slides past them.  Ring pages are
    per-slot private (never shared, CoW'd, prefix-indexed, or
    defragged), so the space is a strict simplification of the full
    space: monotone block indices per slot, dead blocks repointed at the
    scratch page (the sliding mask already excludes those positions, so
    reclamation cannot change logits).

State pools themselves are device pytrees built by
``Model.init_state_pools`` (slot-indexed leaves, mirroring the page-pool
pytree structure); this module stays JAX-free so the invariants are
testable without compiles.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.attention_backends import layout_for_kind
from repro.runtime.kv_cache import SCRATCH_PAGE, PageAllocator


# ---------------------------------------------------------------------------
# Residency classification
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SegmentCacheLayout:
    """What one scanned segment keeps resident per slot.

    ``paged``: ``"full"`` (full-context KV pages), ``"ring"``
    (window-reclaimed KV pages), or None (no token-indexed pages).
    ``state``: the segment carries per-slot recurrent state.
    """
    paged: str | None
    state: bool
    window: int | None
    reps: int


@dataclasses.dataclass(frozen=True)
class ModelCacheLayout:
    """Per-segment residency of a whole model plan."""
    segments: tuple[SegmentCacheLayout, ...]

    @property
    def has_full(self) -> bool:
        return any(s.paged == "full" for s in self.segments)

    @property
    def has_ring(self) -> bool:
        return any(s.paged == "ring" for s in self.segments)

    @property
    def has_state(self) -> bool:
        return any(s.state for s in self.segments)

    @property
    def stateful(self) -> bool:
        """Anything beyond the classic all-full-KV layout."""
        return self.has_ring or self.has_state

    @property
    def ring_window(self) -> int | None:
        """The reclamation window: ring blocks are shared across ring
        segments through ONE ring table, so reclamation must respect the
        widest window any ring segment still reads."""
        ws = [s.window for s in self.segments if s.paged == "ring"]
        return max(ws) if ws else None

    def ring_layers(self) -> int:
        return sum(s.reps for s in self.segments if s.paged == "ring")

    def full_layers(self) -> int:
        return sum(s.reps for s in self.segments if s.paged == "full")


def model_cache_layout(segments, cfg=None) -> ModelCacheLayout:
    """Classify a model plan's segments (``models.model.Segment`` list).

    A segment pages KV iff any of its kinds has a KV half; it is ring iff
    additionally the segment carries a sliding window (global-attention
    layers of the same hybrid model land in separate ``window=None``
    segments, so the split is exact)."""
    out = []
    for seg in segments:
        layouts = [layout_for_kind(k) for k in seg.kinds]
        kv = any(l.kv for l in layouts)
        state = any(l.state for l in layouts)
        paged = None if not kv else ("ring" if seg.window is not None
                                     else "full")
        out.append(SegmentCacheLayout(paged=paged, state=state,
                                      window=seg.window, reps=seg.reps))
    return ModelCacheLayout(segments=tuple(out))


# ---------------------------------------------------------------------------
# Ring-page space
# ---------------------------------------------------------------------------


def ring_blocks_cap(window: int, page_size: int) -> int:
    """Steady-state decode residency bound: a slot's live ring blocks
    never exceed ``ceil(window/page_size) + 1`` (the +1 is the write
    frontier straddling a block boundary)."""
    return -(-window // page_size) + 1


def ring_pages_needed(*, num_slots: int, window: int, page_size: int,
                      max_blocks: int, prefill_chunk: int = 1) -> int:
    """Pool size (incl. scratch) at which ring ``ensure`` can never fail.

    The transient bound is wider than the decode bound: a prefill chunk
    writes ``prefill_chunk`` positions in one dispatch, with reclamation
    only possible between dispatches, so a slot briefly holds
    ``ceil((window + prefill_chunk)/page) + 1`` blocks."""
    cap = min(max_blocks,
              -(-(window + max(prefill_chunk, 1)) // page_size) + 1)
    return 1 + num_slots * cap


class RingPageSpace:
    """Per-slot ring-page tables over a private ``PageAllocator``.

    Block indices are **logical and monotone**: block ``b`` of a slot
    always covers absolute positions ``[b*page, (b+1)*page)``; the ring
    reclaims the PHYSICAL page behind an out-of-window block and repoints
    the table entry at scratch, it never renumbers.  Per slot:

        ``_low``  — first block still backed by a live page
        ``_next`` — first block never allocated (the write frontier)

    so ``[_low, _next)`` are the live blocks and everything below
    ``_low`` reads as scratch (masked out by the sliding window).
    Ring pages are exclusively owned — no sharing, no CoW, no prefix
    index, no defrag — which keeps every allocator rc at exactly 1.
    """

    def __init__(self, *, num_slots: int, num_pages: int, page_size: int,
                 max_blocks: int, window: int):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.num_slots = num_slots
        self.page_size = page_size
        self.max_blocks = max_blocks
        self.window = window
        self.allocator = PageAllocator(num_pages, page_size)
        self._table = np.zeros((num_slots, max_blocks), np.int32)
        self._low = [0] * num_slots
        self._next = [0] * num_slots

    # -- queries ------------------------------------------------------------
    def table(self) -> np.ndarray:
        return self._table

    def live_blocks(self, slot: int) -> int:
        return self._next[slot] - self._low[slot]

    @property
    def decode_cap(self) -> int:
        return ring_blocks_cap(self.window, self.page_size)

    # -- lifecycle ----------------------------------------------------------
    def ensure(self, slot: int, pos: int) -> bool:
        """Back position ``pos`` (and everything since the window's low
        edge) with ring pages.  All-or-nothing, like the full space."""
        need = pos // self.page_size + 1
        if need > self.max_blocks:
            return False
        have = self._next[slot]
        if need <= have:
            return True
        pages = self.allocator.alloc(("ring", slot), need - have)
        if pages is None:
            return False
        self._table[slot, have:need] = pages
        self._next[slot] = need
        return True

    def reclaim(self, slot: int, pos_next: int) -> int:
        """Free every block wholly behind the window of the NEXT query
        position; returns pages freed.  Conservative by one position
        (``first_needed = pos_next - window`` rather than ``- window +
        1``) so the reclamation is correct under either inclusive or
        exclusive window conventions."""
        first_needed = pos_next - self.window
        dead = max(0, first_needed // self.page_size)
        dead = min(dead, self._next[slot])
        freed = 0
        owner = ("ring", slot)
        for b in range(self._low[slot], dead):
            page = int(self._table[slot, b])
            assert page != SCRATCH_PAGE
            self.allocator.drop_page(owner, page)
            self._table[slot, b] = SCRATCH_PAGE
            freed += 1
        self._low[slot] = max(self._low[slot], dead)
        return freed

    def release(self, slot: int) -> int:
        freed = self.allocator.free_owner(("ring", slot))
        self._table[slot, :] = SCRATCH_PAGE
        self._low[slot] = 0
        self._next[slot] = 0
        return freed

    # -- invariants ---------------------------------------------------------
    def check(self) -> None:
        self.allocator.check()
        for slot in range(self.num_slots):
            lo, nx = self._low[slot], self._next[slot]
            assert 0 <= lo <= nx <= self.max_blocks
            row = self._table[slot]
            live = sorted(int(p) for p in row[lo:nx])
            assert SCRATCH_PAGE not in live, "live ring block on scratch"
            assert live == sorted(self.allocator.pages_of(("ring", slot))), \
                "ring table out of sync with allocator"
            assert all(int(p) == SCRATCH_PAGE for p in row[:lo]), \
                "reclaimed ring block not repointed at scratch"
            assert all(int(p) == SCRATCH_PAGE for p in row[nx:])
            assert all(self.allocator.refcount(p) == 1 for p in live), \
                "ring pages are never shared"


# ---------------------------------------------------------------------------
# State-pool accounting (DeploymentSpec.resolve pricing)
# ---------------------------------------------------------------------------


def state_bytes_per_slot(cfg) -> int:
    """Exact per-slot bytes of one layer-stack's SSM state pools.

    Mirrors ``models/ssm.py init_ssm_state``: conv tail
    ``(K-1, conv_dim)`` bf16 + SSD state ``(H, P, N)`` f32, summed over
    every state-carrying layer of the plan (ssm and hybrid kinds)."""
    from repro.models.model import build_plan
    layers = 0
    for seg in build_plan(cfg):
        if any(layout_for_kind(k).state for k in seg.kinds):
            layers += seg.reps
    if not layers:
        return 0
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    conv = (cfg.conv_kernel - 1) * conv_dim * 2          # bf16
    ssd = cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4  # f32
    return (conv + ssd) * layers
