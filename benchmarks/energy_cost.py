"""Paper Fig 9 (Pareto of HBM-CO for 405B/64CU), Fig 12 (energy & cost vs
scale), §IX decomposed contributions."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.hbmco import (HBM3E_LIKE, enumerate_design_space,
                              pareto_frontier)
from repro.sim.scaling import rpu_point, system_cost


def run() -> list[Row]:
    rows: list[Row] = []
    cfg405 = get_config("llama3-405b")
    frontier = pareto_frontier(enumerate_design_space())

    # Fig 9: 64-CU 405B — optimal SKU + energy vs an HBM3e-like choice
    p_co = rpu_point(cfg405, 64, batch=1, seq_len=8192)
    p_3e = rpu_point(cfg405, 64, batch=1, seq_len=8192, sku=HBM3E_LIKE)
    rows += [
        Row("Fig9", "405B/64CU optimal SKU", p_co.sku.name, None, "",
            f"{p_co.sku.capacity_mb:.0f}MB, {p_co.sku.energy_pj_per_bit:.2f}pJ/b"),
        Row("Fig9", "energy/token HBM-CO vs HBM3e",
            p_3e.sim.energy_j / p_co.sim.energy_j, 1.7, "x",
            "paper: 1.7x at system level (64 CU)"),
    ]

    # Fig 12: energy + cost across scales; HBM-CO vs fixed HBM3e
    scales = [64, 128, 256, 268, 428]
    e_curve, c_curve = [], []
    for n in scales:
        p = rpu_point(cfg405, n, batch=1, seq_len=8192)
        if p is None:
            continue
        e_curve.append((n, p.sim.energy_j, p.sku.name))
        c_curve.append((n, p.cost))
    rows.append(Row("Fig12", "405B energy/token vs scale",
                    " ".join(f"{n}:{e:.2f}J({s})" for n, e, s in e_curve),
                    None, "", "energy falls with scale until max-BW/Cap SKU"))
    # paper's 2.2x: HBM-CO vs an HBM3e-BW/Cap memory AT the same scale
    n_best = e_curve[-1][0]
    p_best = rpu_point(cfg405, n_best, batch=1, seq_len=8192)
    p_best_3e = rpu_point(cfg405, n_best, batch=1, seq_len=8192,
                          sku=HBM3E_LIKE)
    rows.append(Row("Fig12", f"energy HBM3e/HBM-CO at {n_best}CU",
                    p_best_3e.sim.energy_j / p_best.sim.energy_j, 2.2, "x",
                    "paper: up to 2.2x"))

    # cost: HBM-CO-selected vs fixed HBM3e at the latency-optimal scale
    n = 428
    p = rpu_point(cfg405, n, batch=1, seq_len=8192)
    cost_co = system_cost(n, p.sku)
    cost_3e = system_cost(n, HBM3E_LIKE)
    rows += [
        Row("Fig12", f"405B/{n}CU cost breakdown",
            " ".join(f"{k}={v:.2f}" for k, v in cost_co.items())),
        Row("Fig12", "total cost fixed-HBM3e / HBM-CO",
            cost_3e["total"] / cost_co["total"], 12.4, "x",
            "paper: up to 12.4x"),
    ]

    # EDP vs 4xH100 (§VIII: 412x)
    from repro.core import hardware
    from repro.sim.gpu_model import GPUSystemConfig, gpu_decode_latency
    g = gpu_decode_latency(cfg405, GPUSystemConfig(n_gpus=4), batch=1,
                           seq_len=8192)
    edp = (g.total_s * g.energy_j) / (p.ms_per_token * 1e-3 * p.sim.energy_j)
    rows.append(Row("Fig12", "EDP improvement vs 4xH100", edp, 412, "x",
                    "energy accounting scope differs; see EXPERIMENTS.md"))
    rows.append(Row("Fig12", "energy/token vs 4xH100",
                    g.energy_j / p.sim.energy_j, 6.5, "x",
                    "paper 6.5x; ours excludes prefill energy"))
    return rows
