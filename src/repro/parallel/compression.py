"""Gradient compression for cross-pod data parallelism.

At 1000+ node scale the inter-pod (DCN / outer-ring) links are the slow
tier, exactly like the paper's off-package UCIe vs in-package hops.  We
keep the *intra-pod* gradient reduction in full bf16/f32 (fast ICI) and
compress only the *cross-pod* sync: int8 per-tensor quantization with
error feedback (the residual is carried to the next step, so the scheme
is unbiased over time and provably converges for smooth objectives).

Wire cost per device: all_gather of int8 shards = (P-1)/P x N bytes vs
2 x (P-1)/P x 4N bytes for a ring all-reduce in f32 — an ~8x reduction.

Usage: inside ``jax.shard_map(..., axis_names={"pod"})`` with grads
replicated over the pod axis *after* the intra-pod reduction; see
``train.train_step.make_train_step(compress_pods=True)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_quantize(g: jnp.ndarray):
    """Symmetric per-tensor int8 quantization."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_mean(g: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    """Error-feedback compressed mean over ``axis_name``.

    Returns (mean_g, new_err).  Both inputs are the *local* values inside a
    shard_map manual over ``axis_name``.
    """
    p = jax.lax.psum(1, axis_name)
    target = g.astype(jnp.float32) + err
    q, scale = int8_quantize(target)
    sent = int8_dequantize(q, scale)
    new_err = target - sent
    # all_gather int8 + local dequant-sum: the wire carries 1 byte/elem.
    qs = jax.lax.all_gather(q, axis_name)              # (P, ...)
    ss = jax.lax.all_gather(scale, axis_name)          # (P,)
    mean = jnp.tensordot(ss, qs.astype(jnp.float32), axes=(0, 0)) / p
    return mean.astype(g.dtype), new_err


def tree_compressed_mean(grads, err_tree, axis_name: str):
    """Apply ``compressed_mean`` leaf-wise over a gradient pytree."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        mg, ne = compressed_mean(g, e, axis_name)
        out_g.append(mg)
        out_e.append(ne)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
