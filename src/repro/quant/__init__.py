"""Block-quantized formats for weight streaming (paper §V Stream Decoder)."""
from repro.quant.formats import (
    MX_BLOCK, BFP_BLOCK, FP4_LUT, FP4_VALUES, FORMATS,
    PackedMXFP4, PackedMXFP8, PackedBFP, PackedNXFP4,
    quantize, dequantize, bits_per_element,
    quantize_mxfp4, dequantize_mxfp4,
    quantize_mxfp8, dequantize_mxfp8,
    quantize_bfp, dequantize_bfp,
    quantize_nxfp4, dequantize_nxfp4,
)
