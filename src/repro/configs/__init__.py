"""Architecture config registry.

One module per assigned architecture (exact published dims) plus the
paper's own evaluation models (Llama3 family, Llama4-Scout) used by the
RPU simulator benchmarks.  ``get_config(name)`` accepts either the
registry id (``qwen2.5-14b``) or the module name (``qwen2_5_14b``).

``reduced_config(cfg)`` returns a tiny same-family config for CPU smoke
tests (few layers / small widths / few experts), per the assignment:
full configs are exercised only via the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ModelConfig

_ARCH_MODULES = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen3-14b": "qwen3_14b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "hubert-xlarge": "hubert_xlarge",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "hymba-1.5b": "hymba_1_5b",
    "internvl2-26b": "internvl2_26b",
    "mamba2-370m": "mamba2_370m",
    # paper-benchmark models (simulator baselines, not dry-run archs)
    "llama3-8b": "llama3_8b",
    "llama3-70b": "llama3_70b",
    "llama3-405b": "llama3_405b",
    "llama4-scout-109b-a17b": "llama4_scout",
}

ASSIGNED_ARCHS = list(_ARCH_MODULES)[:10]
PAPER_ARCHS = list(_ARCH_MODULES)[10:]


def list_configs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    key = name if name in _ARCH_MODULES else None
    if key is None:
        for k, mod in _ARCH_MODULES.items():
            if mod == name.replace("-", "_").replace(".", "_"):
                key = k
                break
    if key is None:
        raise KeyError(f"unknown architecture {name!r}; know {list(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[key]}")
    return mod.CONFIG


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw: dict = dict(
        name=cfg.name + "-reduced",
        n_layers=2 if cfg.moe_layer_period <= 1 else 2 * cfg.moe_layer_period,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        vocab_pad_multiple=1,
    )
    if cfg.mla:
        kw.update(kv_lora_rank=32, rope_head_dim=8, head_dim=16)
    if cfg.moe:
        kw.update(n_experts=4, n_experts_per_token=min(2, cfg.n_experts_per_token),
                  moe_d_ff=64,
                  first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.ssm or cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=8, ssm_head_dim=8, ssm_heads=0, ssm_chunk=16)
    if cfg.sliding_window is not None:
        kw.update(sliding_window=8)
    return dataclasses.replace(cfg, **kw)
