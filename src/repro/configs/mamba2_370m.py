"""Mamba2-370M — attention-free SSD (state-space duality) stack.
[arXiv:2405.21060]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                    # no MLP: pure mamba blocks
    vocab_size=50280, vocab_pad_multiple=512,
    ssm=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,              # d_inner = 2048 -> 32 heads
    ssm_chunk=256,
    conv_kernel=4,
    tie_embeddings=True,
)
