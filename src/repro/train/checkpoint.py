"""Sharded checkpointing with atomic commit, async save, and elastic
restore (re-shard onto a different mesh / device count).

Layout::

    <dir>/step_<N>/arrays.npz     flattened leaves, key = joined tree path
    <dir>/step_<N>/tree.json      pytree structure + dtypes/shapes
    <dir>/step_<N>/COMMIT         written last => checkpoint is valid

Fault-tolerance contract: ``restore_latest`` only considers committed
checkpoints, so a crash mid-save can never be restored from.  Restore takes
optional ``shardings`` (a pytree of NamedSharding for the *current* mesh),
which is what makes restarts elastic: the same arrays are re-laid-out onto
whatever mesh the restarted job has (the paper's "scale by composing
different numbers of CUs" applied to training restarts).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for k in path:
            if isinstance(k, jax.tree_util.DictKey):
                keys.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                keys.append(str(k.idx))
            elif isinstance(k, jax.tree_util.GetAttrKey):
                keys.append(k.name)
            else:
                keys.append(str(k))
        flat[_SEP.join(keys)] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, state, *,
                    async_save: bool = False) -> str | threading.Thread:
    """Save ``state`` (any pytree).  Returns path (or the writer thread)."""
    flat = _flatten_with_paths(state)               # device->host copy here
    treedef = jax.tree_util.tree_structure(state)
    meta = {"step": int(step), "treedef": str(treedef),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()}}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def list_checkpoints(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "COMMIT")):
                steps.append(int(d[5:]))
    return sorted(steps)


def restore_checkpoint(ckpt_dir: str, step: int, template, *,
                       shardings=None):
    """Restore into the structure of ``template``; optionally re-shard.

    ``shardings``: pytree of (Named)Sharding matching ``template`` — pass
    the *current* plan's shardings to restore elastically onto a different
    mesh than the one that saved.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    keys = list(_flatten_with_paths(template).keys())
    assert len(keys) == len(flat_t)
    if shardings is not None:
        flat_s = treedef.flatten_up_to(shardings)
    leaves = []
    for i, (k, t) in enumerate(zip(keys, flat_t)):
        arr = data[k]
        if list(arr.shape) != list(t.shape):
            raise ValueError(f"checkpoint leaf {k} shape {arr.shape} != "
                             f"template {t.shape}")
        if arr.dtype.kind == "V":
            # numpy round-trips ml_dtypes (bf16/fp8) as raw void bytes
            arr = arr.view(np.dtype(t.dtype))
        arr = arr.astype(t.dtype)
        if shardings is not None:
            leaves.append(jax.device_put(arr, flat_s[i]))
        else:
            leaves.append(jnp.asarray(arr))
    return treedef.unflatten(leaves)


def restore_latest(ckpt_dir: str, template, *, shardings=None):
    """Returns (state, step) from the newest committed checkpoint, or
    (None, -1) if none exists."""
    steps = list_checkpoints(ckpt_dir)
    if not steps:
        return None, -1
    step = steps[-1]
    return restore_checkpoint(ckpt_dir, step, template,
                              shardings=shardings), step
