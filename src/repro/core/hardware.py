"""Hardware constants for the RPU reproduction.

Three roles:
  * ``TPU_V5E`` — the *target* chip for the JAX/Pallas framework itself
    (roofline analysis in ``core.roofline`` uses these numbers).
  * ``H100`` / ``H200`` — the paper's GPU baselines (§II, Fig 11-14),
    calibrated with the paper's measured utilization numbers.
  * ``RPUChipParams`` — the RPU compute-unit parameters from §IV/§V used by
    the analytical + event-driven simulator.

All bandwidths are bytes/second, energies pJ/bit unless noted.
"""
from __future__ import annotations

import dataclasses

# ----------------------------------------------------------------------------
# TPU v5e — roofline target for the JAX framework (per system prompt).
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """A generic accelerator chip for roofline arithmetic."""

    name: str
    peak_flops_bf16: float        # FLOP/s
    hbm_bw: float                 # bytes/s
    ici_link_bw: float            # bytes/s per link (uni-directional)
    ici_links: int                # usable links per chip
    hbm_capacity: float           # bytes
    tdp_w: float                  # watts

    @property
    def ops_per_byte(self) -> float:
        """Compute-to-bandwidth ratio (the paper's central provisioning metric)."""
        return self.peak_flops_bf16 / self.hbm_bw


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,       # 197 TFLOP/s bf16
    hbm_bw=819e9,                 # 819 GB/s
    ici_link_bw=50e9,             # ~50 GB/s per ICI link
    ici_links=4,                  # 2D torus on v5e
    hbm_capacity=16 * 2**30,
    tdp_w=250.0,
)

# ----------------------------------------------------------------------------
# GPU baselines (paper §II measurements drive the efficiency factors).
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GPUSpec(ChipSpec):
    """GPU baseline with the paper's measured decode-phase derates."""

    # Paper §II: "H100 only utilizes 32% of its peak memory bandwidth during
    # distributed LLM decode"; full BW only reached for working sets > ~1GB.
    decode_bw_utilization: float = 0.32
    # Compute efficiency for the large, compute-bound phases (prefill ~90% TDP,
    # high utilization). Dense-kernel sustained fraction of peak.
    compute_efficiency: float = 0.70
    # Kernel launch + scheduling overhead per kernel (paper §II cites launch
    # overheads "non-negligible for small kernel sizes"; ~ microseconds).
    kernel_launch_s: float = 4.0e-6
    # NVLink per-direction aggregate bandwidth (bytes/s) and per-collective
    # latency for TP collectives.
    nvlink_bw: float = 450e9
    collective_latency_s: float = 9.0e-6


H100 = GPUSpec(
    name="h100_sxm",
    peak_flops_bf16=989e12,       # dense bf16 (with sparsity excluded)
    hbm_bw=3.35e12,               # HBM3 3.35 TB/s
    ici_link_bw=450e9,            # NVLink4 aggregate per direction
    ici_links=1,
    hbm_capacity=80 * 2**30,
    tdp_w=700.0,
)

H200 = GPUSpec(
    name="h200_sxm",
    peak_flops_bf16=989e12,
    hbm_bw=4.8e12,                # HBM3e 4.8 TB/s
    ici_link_bw=450e9,
    ici_links=1,
    hbm_capacity=141 * 2**30,
    tdp_w=700.0,
)

# ----------------------------------------------------------------------------
# RPU parameters (paper §IV/§V).
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RPUChipParams:
    """One RPU Compute Unit (CU) and its reasoning cores.

    Paper §IV: each CU = 1 compute chiplet + 2 HBM-CO chiplets; dual 256 GB/s
    shorelines (512 GB/s per CU); 32 OPs/Byte ⇒ 8 TOPS per 256 GB/s shoreline
    (16 TOPS per CU); 16 reasoning cores per CU (8 per shoreline), each core
    with four 8x8 TMACs fed by one 32 GB/s pseudo-channel.
    """

    cores_per_cu: int = 16
    tmacs_per_core: int = 4
    macs_per_tmac: int = 64                   # 8x8 array
    core_clock_hz: float = 1.0e9
    pch_bw: float = 32e9                      # bytes/s per core (pseudo-channel)
    cu_mem_bw: float = 512e9                  # bytes/s per CU (dual shoreline)
    ops_per_byte: float = 32.0                # provisioned compute : BW ratio
    # ⇒ compute per CU = 32 OPs/B × 512 GB/s = 16.384 TOPS
    cus_per_package: int = 4
    # Network: UCIe in-package + PCB ring (§IV). Outer-ring BW and hop latency.
    cu_hop_latency_s: float = 10e-9           # ≤10ns per CU-to-CU hop
    ring_bw: float = 128e9                    # bytes/s outer-ring per direction
    # on-chip buffer per core (SRAM memory+network buffers, §V / Fig 8 shows
    # ~6MB per-CU lookahead ⇒ ~384KB/core usable staging; round to 512KB)
    buffer_bytes_per_core: int = 512 * 1024
    # Power (paper §IV: 70-80% of power to memory interfaces).
    mem_power_fraction: float = 0.75
    # Compute datapath energy (paper Fig 8 text: ~1.7 pJ/b datapath to write
    # memory buffer; ~5W/CU at full compute utilization).
    compute_w_per_cu_peak: float = 5.0
    sram_pj_per_bit: float = 1.7
    # Network energies (UCIe §IV): in-package 0.5 pJ/b, off-package 0.75-1.2.
    net_pj_per_bit_in_pkg: float = 0.5
    net_pj_per_bit_off_pkg: float = 1.0

    @property
    def cu_tops(self) -> float:
        """Provisioned OPs/s per CU."""
        return self.ops_per_byte * self.cu_mem_bw

    @property
    def core_tops(self) -> float:
        return self.cu_tops / self.cores_per_cu

    def cu_tdp_w(self, mem_pj_per_bit: float) -> float:
        """TDP of one CU given its memory device energy (paper: memory power
        dominates; peak power ≈ mem stream power / mem_power_fraction)."""
        mem_w = self.cu_mem_bw * 8 * mem_pj_per_bit * 1e-12
        return mem_w / self.mem_power_fraction


RPU_DEFAULT = RPUChipParams()

# Useful time constants
US = 1e-6
MS = 1e-3
GB = 1e9
GIB = float(2**30)
