"""Disaggregated prefill/decode serving: phase-split engines, KV-page
handoff, phase-aware deployment budgets, and the KV-aware fleet layer.

Byte-identity is the load-bearing property: a DisaggServeEngine must
reproduce the colocated engine's greedy streams exactly — through prefix
sharing, fp8 KV, speculative decoding, and decode-side preemption (which
drains back to the prefill engine for a re-prefill restart)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.fleet import (SLO, DisaggFleetSimulator, FleetSimulator,
                         LatencyTable, PrefixAffinityRouter, ReplicaSpec,
                         RoundRobinRouter, TrafficEnvelope,
                         default_candidates, plan_disagg_fleet, plan_fleet)
from repro.fleet import traffic as tr
from repro.launch.fleet import gate_table, gate_workload
from repro.models.common import ModelConfig
from repro.models.model import build_model
from repro.parallel.plan import split_mesh
from repro.runtime.deployment import DeploymentSpec
from repro.runtime.engine import (ContinuousServeEngine, DisaggServeEngine,
                                  KVHandoff)
from repro.runtime.sampling import SamplingParams
from repro.runtime.scheduler import Request
from repro.runtime.speculative import SpeculativeConfig

# ---------------------------------------------------------------------------
# byte-identity: colocated vs disaggregated, same greedy streams
# ---------------------------------------------------------------------------

_CFG = ModelConfig(name="disagg-test", family="dense", n_layers=2,
                   d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
                   d_ff=256, vocab_size=512)


@pytest.fixture(scope="module")
def tiny():
    model = build_model(_CFG)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)))
    return _CFG, model, params


def _mk_requests(n: int, seed: int, *, max_new: int = 8) -> list:
    """Ragged greedy requests; even rids share a 12-token prefix so the
    handoff exercises decode-side prefix admission."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, _CFG.vocab_size, 12).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, _CFG.vocab_size,
                            int(rng.integers(6, 20))).astype(np.int32)
        prompt = np.concatenate([shared, tail]) if i % 2 == 0 else tail
        out.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new,
                           sampling=SamplingParams(max_tokens=max_new)))
    return out


def _identical(tiny, *, seed=3, max_new=8, **kw):
    """Run the same workload colocated and disaggregated; assert every
    request's token stream matches exactly.  Returns the disagg stats."""
    _, model, params = tiny
    kw.setdefault("num_slots", 4)
    kw.setdefault("page_size", 4)
    kw.setdefault("num_pages", 48)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("enable_prefix_cache", True)
    co = ContinuousServeEngine(model, params, **kw)
    dis = DisaggServeEngine(model, params, **kw)
    s_co = co.run(_mk_requests(8, seed, max_new=max_new))
    s_di = dis.run(_mk_requests(8, seed, max_new=max_new))
    assert set(s_co.outputs) == set(s_di.outputs)
    for rid in sorted(s_co.outputs):
        a = list(s_co.outputs[rid].token_ids)
        b = list(s_di.outputs[rid].token_ids)
        assert a == b, f"rid {rid}: colocated {a} != disagg {b}"
    assert s_di.handoffs >= 8                    # every request transferred
    return s_di


def test_byte_identity_with_prefix_sharing(tiny):
    s = _identical(tiny)
    assert s.handoff_shared_tokens > 0           # decode-side prefix hits
    assert s.handoff_bytes > 0 and s.handoff_pages > 0


def test_byte_identity_fp8_kv(tiny):
    s = _identical(tiny, cache_dtype="fp8")
    assert s.handoff_bytes > 0


def test_byte_identity_speculative(tiny):
    s = _identical(tiny, speculative=SpeculativeConfig(gamma=3))
    assert s.spec_windows > 0                    # windows actually ran


def test_byte_identity_under_preemption(tiny):
    """Page pressure evicts decoding requests; a disagg victim restarts
    on the PREFILL engine and hands off again — streams must still match
    the colocated engine token for token."""
    s = _identical(tiny, seed=9, max_new=24, num_pages=16, max_len=56)
    assert s.preemptions > 0, "settings no longer force preemption"
    assert s.handoffs > 8                        # re-handoffs after restarts


def test_disagg_incremental_api_and_run_guard(tiny):
    _, model, params = tiny
    dis = DisaggServeEngine(model, params, num_slots=4, page_size=4,
                            num_pages=48, max_len=64, prefill_chunk=8)
    reqs = _mk_requests(3, 7)
    for r in reqs:
        dis.add_request(r)
    dis.step()
    assert dis.has_unfinished()
    with pytest.raises(RuntimeError, match="unfinished"):
        dis.run(_mk_requests(2, 8))
    steps = 0
    while dis.has_unfinished():
        dis.step()
        steps += 1
        assert steps < 200
    assert all(len(r.tokens) >= r.max_new_tokens for r in reqs)


def test_handoff_geometry_mismatch_raises(tiny):
    _, model, params = tiny
    a = ContinuousServeEngine(model, params, num_slots=2, page_size=4,
                              num_pages=16, max_len=32)
    b = ContinuousServeEngine(model, params, num_slots=2, page_size=8,
                              num_pages=16, max_len=32)
    with pytest.raises(ValueError, match="page_size"):
        KVHandoff(a, b)
    c = ContinuousServeEngine(model, params, num_slots=2, page_size=4,
                              num_pages=16, max_len=32,
                              speculative=SpeculativeConfig(gamma=3))
    with pytest.raises(ValueError, match="speculative"):
        KVHandoff(a, c)


# ---------------------------------------------------------------------------
# phase-aware deployment budgets
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen_full():
    return build_model(get_config("qwen3-14b"))


def test_phase_resolve_budgets(qwen_full):
    spec = DeploymentSpec(sku="h200", max_len=2048, weight_format="mxfp4",
                          cache_dtype="fp8", max_slots=32)
    rc = spec.resolve(qwen_full)
    rp = spec.resolve(qwen_full, phase="prefill")
    rd = spec.resolve(qwen_full, phase="decode")
    assert (rc.phase, rp.phase, rd.phase) == ("colocated", "prefill",
                                              "decode")
    # the prefill class sizes slots for concurrent CHUNKS, not residents —
    # far fewer than the decode side's batch
    assert rp.num_slots < rd.num_slots
    assert rp.num_pages < rd.num_pages
    # prefill ceiling counts prompt tokens/s off the compute roofline and
    # must beat the decode-phase (bandwidth) ceiling on prompt work
    assert rp.tokens_per_s_ceiling > rd.tokens_per_s_ceiling
    assert rp.chunk_knee_tokens > 0 and rp.prefill_chunk_derived
    assert rp.prefill_chunk % rp.page_size == 0
    assert "[prefill]" in rp.describe() and "[decode]" in rd.describe()
    with pytest.raises(ValueError, match="phase"):
        spec.resolve(qwen_full, phase="verify")


def test_phase_resolve_chunk_knee_tracks_compute(qwen_full):
    """The derived chunk sits at the FLOPs knee: a compute-denser SKU
    (same bandwidth class) wants LARGER chunks to cover its weight
    stream."""
    weak = DeploymentSpec(sku="h100", max_len=2048,
                          max_slots=32).resolve(qwen_full, phase="prefill")
    strong = DeploymentSpec(sku="h200", max_len=2048,
                            max_slots=32).resolve(qwen_full, phase="prefill")
    assert strong.chunk_knee_tokens != weak.chunk_knee_tokens
    explicit = DeploymentSpec(sku="h100", max_len=2048, prefill_chunk=64,
                              max_slots=32).resolve(qwen_full,
                                                    phase="prefill")
    assert explicit.prefill_chunk == 64 and not explicit.prefill_chunk_derived


# ---------------------------------------------------------------------------
# mesh splitting (single host device: duck-typed stand-in)
# ---------------------------------------------------------------------------


class _FakeMesh:
    """Duck-typed mesh: split_mesh only touches .devices/.axis_names and
    rebuilds via type(mesh), so tests need no multi-device runtime."""

    def __init__(self, devices, axis_names):
        self.devices = np.asarray(devices, dtype=object)
        self.axis_names = tuple(axis_names)


def test_split_mesh_phase_slices():
    mesh = _FakeMesh(np.arange(8).reshape(2, 4), ("data", "model"))
    pre, dec = split_mesh(mesh, 1, axis="model")
    assert isinstance(pre, _FakeMesh) and isinstance(dec, _FakeMesh)
    assert pre.devices.shape == (2, 1) and dec.devices.shape == (2, 3)
    assert pre.axis_names == dec.axis_names == ("data", "model")
    # disjoint and order-preserving
    np.testing.assert_array_equal(pre.devices[:, 0], [0, 4])
    np.testing.assert_array_equal(dec.devices, [[1, 2, 3], [5, 6, 7]])
    # explicit n_second may leave devices unused
    pre2, dec2 = split_mesh(mesh, 1, 2, axis="model")
    assert dec2.devices.shape == (2, 2)


def test_split_mesh_rejects_bad_splits():
    mesh = _FakeMesh(np.arange(4), ("model",))
    with pytest.raises(ValueError, match="no 'pipeline' axis"):
        split_mesh(mesh, 2, axis="pipeline")
    with pytest.raises(ValueError, match="cannot split"):
        split_mesh(mesh, 4, axis="model")        # nothing left for decode
    with pytest.raises(ValueError, match="cannot split"):
        split_mesh(mesh, 3, 2, axis="model")     # 3+2 > 4


# ---------------------------------------------------------------------------
# fleet layer: KV-aware placement, disagg simulator, phase-split planning
# ---------------------------------------------------------------------------


def test_router_adopt_placement_survives_drain():
    r = PrefixAffinityRouter()
    reps = [object(), object()]
    keys = [b"a", b"b", b"c"]
    assert r.adopt_placement(keys, reps[1]) == 3
    # a full adopted chain scores reps[1] strictly above an empty twin
    class _Rep:
        draining = False
        def queue_depth(self): return 0
        def load(self): return 0.0
        def saturated(self): return False
        def match_tokens(self, chain): return 0
    a, b = _Rep(), _Rep()
    r.placement.clear()
    r.adopt_placement(keys, b)
    order = r.order(0.0, 64, keys, [a, b])
    assert order[0][2] == 1                      # adopted home wins
    assert order[0][0] > order[1][0]             # strictly, via the credit
    assert r._adopted_frac(keys, b) == 1.0
    assert r._adopted_frac([b"a", b"x", b"c"], b) == pytest.approx(1 / 3)
    # the map is bounded: old entries fall off the LRU end
    r.placement_cap = 4
    r.adopt_placement([b"1", b"2", b"3", b"4"], a)
    assert len(r.placement) == 4 and b"a" not in r.placement
    # round-robin ignores placement entirely (pure cycling order)
    rr = RoundRobinRouter()
    rr.adopt_placement(keys, b)
    assert [i for _, _, i in rr.order(0.0, 64, keys, [a, b])] == [0, 1]
    assert [i for _, _, i in rr.order(0.0, 64, keys, [a, b])] == [1, 0]


def test_disagg_fleet_simulator_conservation_and_handoff():
    trace = gate_workload(400, 7, "mmpp", 120.0)
    pspec = ReplicaSpec(latency=gate_table(), num_slots=4, max_queue=16,
                        page_size=16, prefix_blocks=24)
    dspec = ReplicaSpec(latency=gate_table(), num_slots=8, max_queue=16,
                        page_size=16, prefix_blocks=24)
    fs = DisaggFleetSimulator(pspec, 2, dspec, 2, PrefixAffinityRouter(),
                              kv_token_bytes=128.0).run(trace)
    assert len(fs.served) + len(fs.shed) == 400
    assert fs.handoffs == len(fs.served)         # every served chain moved
    assert fs.handoff_bytes > 0
    assert fs.handoff_shared_tokens > 0          # KV-aware placement hit
    assert fs.prefill_replicas == 2
    assert all(sr.emitted == sr.req.output_len for sr in fs.served)
    assert all(sr.first_tok_t is not None and sr.finish_t >= sr.first_tok_t
               >= sr.req.arrival for sr in fs.served)
    # determinism
    fs2 = DisaggFleetSimulator(pspec, 2, dspec, 2, PrefixAffinityRouter(),
                               kv_token_bytes=128.0).run(trace)
    assert [(s.req.rid, s.finish_t) for s in fs.served] \
        == [(s.req.rid, s.finish_t) for s in fs2.served]


def test_disagg_simulator_decode_never_reruns_prefill():
    """Decode-class replicas admit transferred chains with zero prefill
    left; TPOT therefore never pays the chunk-interleave tax that the
    colocated fleet pays on the same table."""
    trace = gate_workload(300, 3, "mmpp", 40.0)
    # make the interleave tax visible: chunks cost 5x a decode step, as
    # on compute-dense silicon with an honest (compute-roofline) chunk
    # price — the colocated fleet pays it inside decode iterations, the
    # decode class never does
    table = dataclasses.replace(gate_table(), prefill_chunk_s=0.01)
    spec = ReplicaSpec(latency=table, num_slots=8, max_queue=16,
                       page_size=16, prefix_blocks=24)
    co = FleetSimulator(spec, 4, PrefixAffinityRouter()).run(trace)
    dis = DisaggFleetSimulator(spec, 2, spec, 2, PrefixAffinityRouter(),
                               kv_token_bytes=128.0).run(trace)
    assert dis.tpot_quantiles()["p95"] <= co.tpot_quantiles()["p95"]
    assert len(dis.served) >= len(co.served) * 0.9


def test_plan_disagg_fleet_structure(qwen_full):
    lengths = tr.LengthMix(prompt_mean=512.0, prompt_min=64,
                           prompt_max=1024, output_mean=256.0,
                           output_min=32, output_max=512)
    trace = tr.make_trace(400, 0, kind="diurnal", rate=200.0,
                          lengths=lengths)
    env = TrafficEnvelope.from_trace(trace)
    slo = SLO(ttft_s=0.4, tpot_s=0.05)
    base = DeploymentSpec(max_len=2048, weight_format="mxfp4",
                          cache_dtype="fp8", max_slots=32)
    cands = default_candidates(qwen_full, base)
    best, plans = plan_disagg_fleet(qwen_full, env, slo, cands, cands)
    assert best.feasible
    assert best.prefill.replicas >= 1 and best.decode.replicas >= 1
    assert best.ttft_est_s <= slo.ttft_s and best.tpot_est_s <= slo.tpot_s
    assert best.ttft_est_s > best.handoff_s > 0  # transfer priced in
    assert 0 < best.energy_j_per_token < float("inf")
    assert best.die_mm2 == best.prefill.die_mm2 + best.decode.die_mm2
    d = best.as_dict()
    assert d["prefill_sku"] and d["decode_sku"]
    assert d["prefill_replicas"] >= 1 and d["decode_replicas"] >= 1
    # the decode-heavy envelope makes phase-specialized silicon win both
    # objectives over the best colocated plan at the same SLO
    co_best, _ = plan_fleet(qwen_full, env, slo, cands)
    assert best.die_mm2 < co_best.die_mm2
    assert best.energy_j_per_token < co_best.energy_j_per_token


def test_latency_table_save_load_roundtrip(tmp_path, qwen_full):
    spec = DeploymentSpec(sku="h200", max_len=2048, weight_format="mxfp4",
                          cache_dtype="fp8", max_slots=32)
    t = LatencyTable.from_roofline(spec.resolve(qwen_full))
    p = tmp_path / "calibration" / "qwen3-14b--rpu.json"
    t.save(str(p))
    back = LatencyTable.load(str(p))
    assert back.batches == t.batches and back.contexts == t.contexts
    np.testing.assert_allclose(np.asarray(back.decode_s),
                               np.asarray(t.decode_s))
    assert back.prefill_chunk_s == pytest.approx(t.prefill_chunk_s)
    assert back.prefill_chunk == t.prefill_chunk
    # the loaded table predicts identically (bilinear interior point)
    assert back.decode_step_s(5, 300) == pytest.approx(
        t.decode_step_s(5, 300))
