"""Block-quantized weight formats for the Stream Decoder (paper §V).

The RPU stores weights compressed in memory and dequantizes on-the-fly in
the Stream Decoder before feeding the TMACs.  Supported formats (paper:
"BFP [53], MxFP [15], and NxFP [39], with configurable bitwidths 4-8"):

  * **MXFP4** — OCP Microscaling: 32-element blocks, E8M0 shared scale,
    E2M1 (fp4) elements.  The serving-path default (paper's deployment:
    "MXFP4 Weights ... BF16 Activations").
  * **MXFP8** — 32-element blocks, E8M0 scale, E4M3 elements.
  * **BFP16** — Microsoft Block Floating Point: 16-element blocks, shared
    8-bit exponent, 8-bit two's-complement mantissas.
  * **NXFP4** — Nanoscaling: MXFP4 plus adaptive 1-bit micro-exponent per
    8-element sub-block (a faithful simplification of [39]).

All functions are pure ``jnp`` (jit-safe) and quantize along the **last**
axis, which for a ``(K, N)`` weight stored K-major means blocks run along
the contraction dim — the order the stripe dataflow streams them.

Packing layout for MXFP4/NXFP4 (consumed by ``kernels/mxfp4_vmm``):
  codes  : uint8[..., K/2, N]   two fp4 codes per byte, low nibble = even k
  scales : uint8[..., K/32, N]  E8M0 biased exponents (bias 127)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

MX_BLOCK = 32
BFP_BLOCK = 16
NX_SUB = 8

# E2M1 representable magnitudes; code = sign<<3 | idx
FP4_VALUES = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], np.float32)
FP4_LUT = np.concatenate([FP4_VALUES, -FP4_VALUES]).astype(np.float32)
_FP4_MAX = 6.0
_E8M0_BIAS = 127


def _e8m0_scale_exp(amax: jnp.ndarray, elem_emax: float) -> jnp.ndarray:
    """Shared-scale exponent: floor(log2(amax)) - elem_emax (OCP MX spec)."""
    safe = jnp.where(amax > 0, amax, 1.0)
    e = jnp.floor(jnp.log2(safe)) - elem_emax
    return jnp.where(amax > 0, e, 0.0)


def _quantize_fp4_codes(x_scaled: jnp.ndarray) -> jnp.ndarray:
    """Round scaled values to nearest E2M1 (OCP-MX round-to-nearest-even);
    non-finite inputs saturate to +/-6.0.  Returns uint8 codes 0..15."""
    sign = jnp.signbit(x_scaled).astype(jnp.uint8)
    mag = jnp.abs(x_scaled)
    grid = jnp.asarray(FP4_VALUES)
    mids = (grid[1:] + grid[:-1]) / 2.0  # 7 midpoints
    # idx counts crossed midpoints; a tie at mids[j] sits between codes j
    # and j+1 and must pick the even mantissa, i.e. cross (>=) exactly when
    # j+1 is even: 0.25->0.0, 0.75->1.0, 1.25->1.0, 2.5->2.0, 3.5->4.0
    ties_up = jnp.asarray(np.arange(1, len(FP4_VALUES)) % 2 == 0)
    above = jnp.where(ties_up, mag[..., None] >= mids, mag[..., None] > mids)
    idx = jnp.sum(above, axis=-1).astype(jnp.uint8)
    idx = jnp.where(jnp.isfinite(mag), idx, jnp.uint8(len(FP4_VALUES) - 1))
    return (sign << 3) | idx


# ---------------------------------------------------------------------------
# MXFP4
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedMXFP4:
    """MXFP4-packed tensor, blocks along the (second-to-last-after-packing)
    original last axis.  ``shape`` is the logical unpacked shape."""

    codes: jnp.ndarray    # uint8 [..., K/2, N] (packed pairs along K)
    scales: jnp.ndarray   # uint8 [..., K/32, N] biased exponents
    shape: tuple          # logical (…, K, N)

    def tree_flatten(self):
        return (self.codes, self.scales), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    @property
    def nbytes(self) -> int:
        return self.codes.size + self.scales.size

    @property
    def bits_per_element(self) -> float:
        n = int(np.prod(self.shape))
        return 8.0 * self.nbytes / n


def quantize_mxfp4(w: jnp.ndarray) -> PackedMXFP4:
    """Quantize ``w`` (..., K, N) to MXFP4 with blocks along K (axis -2)."""
    *lead, K, N = w.shape
    assert K % MX_BLOCK == 0, f"K={K} must be a multiple of {MX_BLOCK}"
    x = w.astype(jnp.float32).reshape(*lead, K // MX_BLOCK, MX_BLOCK, N)
    amax = jnp.max(jnp.abs(x), axis=-2, keepdims=True)
    e = _e8m0_scale_exp(amax, 2.0)
    codes4 = _quantize_fp4_codes(x * jnp.exp2(-e))
    codes4 = codes4.reshape(*lead, K, N)
    lo, hi = codes4[..., 0::2, :], codes4[..., 1::2, :]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    scales = (e[..., 0, :] + _E8M0_BIAS).astype(jnp.uint8)
    return PackedMXFP4(packed, scales, tuple(w.shape))


def dequantize_mxfp4(p: PackedMXFP4, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Reference stream-decoder: unpack to ``dtype`` (pure jnp oracle)."""
    *lead, K, N = p.shape
    lut = jnp.asarray(FP4_LUT)
    lo = (p.codes & 0xF).astype(jnp.int32)
    hi = (p.codes >> 4).astype(jnp.int32)
    vals = jnp.stack([lut[lo], lut[hi]], axis=-2)            # [..., K/2, 2, N]
    vals = vals.reshape(*lead, K, N)
    e = p.scales.astype(jnp.float32) - _E8M0_BIAS            # [..., K/32, N]
    scale = jnp.repeat(jnp.exp2(e), MX_BLOCK, axis=-2)
    return (vals * scale).astype(dtype)


# ---------------------------------------------------------------------------
# MXFP8 (E4M3 elements)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedMXFP8:
    codes: jnp.ndarray    # float8_e4m3fn [..., K, N]
    scales: jnp.ndarray   # uint8 [..., K/32, N]
    shape: tuple

    def tree_flatten(self):
        return (self.codes, self.scales), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    @property
    def nbytes(self) -> int:
        return self.codes.size + self.scales.size


def quantize_mxfp8(w: jnp.ndarray) -> PackedMXFP8:
    *lead, K, N = w.shape
    assert K % MX_BLOCK == 0
    x = w.astype(jnp.float32).reshape(*lead, K // MX_BLOCK, MX_BLOCK, N)
    amax = jnp.max(jnp.abs(x), axis=-2, keepdims=True)
    e = _e8m0_scale_exp(amax, 8.0)    # E4M3 emax = 8 (448 = 1.75*2^8)
    # saturate to the E4M3 range before casting (the cast NaNs on overflow)
    scaled = jnp.clip(x * jnp.exp2(-e), -448.0, 448.0)
    codes = scaled.astype(jnp.float8_e4m3fn).reshape(*lead, K, N)
    scales = (e[..., 0, :] + _E8M0_BIAS).astype(jnp.uint8)
    return PackedMXFP8(codes, scales, tuple(w.shape))


def dequantize_mxfp8(p: PackedMXFP8, dtype=jnp.bfloat16) -> jnp.ndarray:
    *lead, K, N = p.shape
    e = p.scales.astype(jnp.float32) - _E8M0_BIAS
    scale = jnp.repeat(jnp.exp2(e), MX_BLOCK, axis=-2)
    return (p.codes.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# BFP16 (shared-exponent int8 mantissas)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedBFP:
    mantissas: jnp.ndarray  # int8 [..., K, N]
    exponents: jnp.ndarray  # int8 [..., K/16, N] unbiased shared exponents
    shape: tuple

    def tree_flatten(self):
        return (self.mantissas, self.exponents), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    @property
    def nbytes(self) -> int:
        return self.mantissas.size + self.exponents.size


def quantize_bfp(w: jnp.ndarray) -> PackedBFP:
    *lead, K, N = w.shape
    assert K % BFP_BLOCK == 0
    x = w.astype(jnp.float32).reshape(*lead, K // BFP_BLOCK, BFP_BLOCK, N)
    amax = jnp.max(jnp.abs(x), axis=-2, keepdims=True)
    # mantissa in [-127, 127]: value = m * 2^(e - 7)  with amax -> ~127
    e = jnp.where(amax > 0, jnp.ceil(jnp.log2(amax / 127.0 + 1e-45)) + 7.0, 0.0)
    m = jnp.clip(jnp.round(x * jnp.exp2(-(e - 7.0))), -127, 127)
    mant = m.reshape(*lead, K, N).astype(jnp.int8)
    exps = e[..., 0, :].astype(jnp.int8)
    return PackedBFP(mant, exps, tuple(w.shape))


def dequantize_bfp(p: PackedBFP, dtype=jnp.bfloat16) -> jnp.ndarray:
    *lead, K, N = p.shape
    e = p.exponents.astype(jnp.float32)
    scale = jnp.repeat(jnp.exp2(e - 7.0), BFP_BLOCK, axis=-2)
    return (p.mantissas.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# NXFP4: MXFP4 + per-8-element 1-bit micro-exponent
# ---------------------------------------------------------------------------


def _pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack {0,1} uint8 [..., M, N] into uint8 [..., ceil(M/8), N]
    (bit b of byte i holds entry 8*i + b; zero-padded tail)."""
    *lead, m, n = bits.shape
    pad = (-m) % 8
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*lead, pad, n), bits.dtype)], axis=-2)
    b = bits.reshape(*lead, -1, 8, n).astype(jnp.uint32)
    weights = (1 << jnp.arange(8, dtype=jnp.uint32))[:, None]
    return jnp.sum(b * weights, axis=-2).astype(jnp.uint8)


def _unpack_bits(packed: jnp.ndarray, m: int) -> jnp.ndarray:
    """Inverse of ``_pack_bits``: uint8 [..., ceil(M/8), N] -> [..., M, N]."""
    *lead, _, n = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)[:, None]
    bits = (packed[..., :, None, :] >> shifts) & 1
    return bits.reshape(*lead, -1, n)[..., :m, :]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedNXFP4:
    codes: jnp.ndarray     # uint8 [..., K/2, N]
    scales: jnp.ndarray    # uint8 [..., K/32, N]
    micro: jnp.ndarray     # uint8 [..., ceil(K/8/8), N] bit-packed micro-exps
    shape: tuple

    def tree_flatten(self):
        return (self.codes, self.scales, self.micro), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux)

    @property
    def nbytes(self) -> int:
        return self.codes.size + self.scales.size + self.micro.size


def quantize_nxfp4(w: jnp.ndarray) -> PackedNXFP4:
    *lead, K, N = w.shape
    assert K % MX_BLOCK == 0
    x = w.astype(jnp.float32).reshape(*lead, K // MX_BLOCK, MX_BLOCK, N)
    amax = jnp.max(jnp.abs(x), axis=-2, keepdims=True)
    e = _e8m0_scale_exp(amax, 2.0)
    # sub-blocks of 8: if the sub-block max is < half the block max, shift
    # the local grid down one exponent step (micro-exponent = 1).
    xs = x.reshape(*lead, K // MX_BLOCK, MX_BLOCK // NX_SUB, NX_SUB, N)
    sub_amax = jnp.max(jnp.abs(xs), axis=-2, keepdims=True)
    micro = (sub_amax * 2.0 <= jnp.exp2(e[..., None, :, :]) * _FP4_MAX).astype(jnp.float32)
    eff_e = e[..., None, :, :] - micro
    codes4 = _quantize_fp4_codes(xs * jnp.exp2(-eff_e))
    codes4 = codes4.reshape(*lead, K, N)
    lo, hi = codes4[..., 0::2, :], codes4[..., 1::2, :]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    scales = (e[..., 0, :] + _E8M0_BIAS).astype(jnp.uint8)
    micro_u8 = micro[..., 0, :].reshape(*lead, K // NX_SUB, N).astype(jnp.uint8)
    return PackedNXFP4(packed, scales, _pack_bits(micro_u8), tuple(w.shape))


def dequantize_nxfp4(p: PackedNXFP4, dtype=jnp.bfloat16) -> jnp.ndarray:
    *lead, K, N = p.shape
    lut = jnp.asarray(FP4_LUT)
    lo = (p.codes & 0xF).astype(jnp.int32)
    hi = (p.codes >> 4).astype(jnp.int32)
    vals = jnp.stack([lut[lo], lut[hi]], axis=-2).reshape(*lead, K, N)
    e = p.scales.astype(jnp.float32) - _E8M0_BIAS
    scale = jnp.repeat(jnp.exp2(e), MX_BLOCK, axis=-2)
    micro_bits = _unpack_bits(p.micro, K // NX_SUB).astype(jnp.float32)
    micro = jnp.repeat(jnp.exp2(-micro_bits), NX_SUB, axis=-2)
    return (vals * scale * micro).astype(dtype)


# ---------------------------------------------------------------------------
# Registry — the software stream decoder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FormatSpec:
    """One quantized format: the single source of truth every derived
    table (``FORMATS``, ``bits_per_element``, byte accounting) reads."""

    quantize: callable
    dequantize: callable
    packed_cls: type
    block: int            # elements sharing one scale along K
    bits: float           # average storage bits/element incl. scales


_CANONICAL = {
    "mxfp4": FormatSpec(quantize_mxfp4, dequantize_mxfp4, PackedMXFP4,
                        MX_BLOCK, 4 + 8.0 / MX_BLOCK),
    "mxfp8": FormatSpec(quantize_mxfp8, dequantize_mxfp8, PackedMXFP8,
                        MX_BLOCK, 8 + 8.0 / MX_BLOCK),
    "bfp": FormatSpec(quantize_bfp, dequantize_bfp, PackedBFP,
                      BFP_BLOCK, 8 + 8.0 / BFP_BLOCK),
    "nxfp4": FormatSpec(quantize_nxfp4, dequantize_nxfp4, PackedNXFP4,
                        MX_BLOCK, 4 + 8.0 / MX_BLOCK + 8.0 / NX_SUB / 8),
}
_ALIASES = {"bfp16": "bfp"}      # alias: 16-elem BFP blocks

# name -> (quantize, dequantize), aliases included (the legacy surface
# DeploymentSpec validates against)
FORMATS = {name: (_CANONICAL[canon].quantize, _CANONICAL[canon].dequantize)
           for name, canon in [(n, n) for n in _CANONICAL]
           + list(_ALIASES.items())}

PACKED_TYPES = tuple(s.packed_cls for s in _CANONICAL.values())
_FORMAT_BY_TYPE = {s.packed_cls: name for name, s in _CANONICAL.items()}


def canonical_format(fmt: str) -> str:
    """Resolve aliases (``bfp16`` -> ``bfp``); KeyError on unknown names."""
    fmt = _ALIASES.get(fmt, fmt)
    if fmt not in _CANONICAL:
        raise KeyError(f"unknown quantized format {fmt!r}; "
                       f"know {sorted(FORMATS)}")
    return fmt


def format_spec(fmt: str) -> FormatSpec:
    return _CANONICAL[canonical_format(fmt)]


def quantize(w: jnp.ndarray, fmt: str):
    return format_spec(fmt).quantize(w)


def dequantize(p, fmt: str, dtype=jnp.bfloat16) -> jnp.ndarray:
    return format_spec(fmt).dequantize(p, dtype)


def dequantize_any(p, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Dequantize any packed tensor, dispatching on its type."""
    return _CANONICAL[_FORMAT_BY_TYPE[type(p)]].dequantize(p, dtype)


def bits_per_element(fmt: str) -> float:
    """Average storage bits/element including scale overheads."""
    return format_spec(fmt).bits


def packed_nbytes(shape, fmt: str) -> int:
    """Exact bytes ``quantize(w, fmt)`` allocates for a ``shape`` weight
    (scale/micro metadata included) — the budget==execution invariant."""
    *lead, k, n = shape
    spec = format_spec(fmt)
    lead_n = int(np.prod(lead)) if lead else 1
    cols = lead_n * n
    per_col = {
        "mxfp4": k // 2 + k // MX_BLOCK,
        "mxfp8": k + k // MX_BLOCK,
        "bfp": k + k // BFP_BLOCK,
        "nxfp4": k // 2 + k // MX_BLOCK + -(-(k // NX_SUB) // 8),
    }[canonical_format(fmt)]
    assert k % spec.block == 0, f"K={k} not a multiple of {spec.block}"
    return per_col * cols
