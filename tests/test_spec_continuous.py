"""Scheduler-integrated speculative decoding in the continuous engine:
greedy byte-identity vs the non-speculative engine (incl. forced
preemption restarts and prefix-cache hits), the one-compiled-window
guarantee, acceptance-rate statistics vs the analytic min(1, p/q) rule,
per-request speculation counters, prompt logprobs across backends, and
the DeploymentSpec draft/window accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models.model import build_model
from repro.runtime import sampling
from repro.runtime.deployment import DeploymentSpec
from repro.runtime.engine import ContinuousServeEngine
from repro.runtime.llm import LLMEngine
from repro.runtime.sampling import SamplingParams
from repro.runtime.scheduler import Request
from repro.runtime.speculative import SpeculativeConfig

GAMMA = 3


@pytest.fixture(scope="module")
def small():
    cfg = reduced_config(get_config("qwen3-14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def draft(small):
    """A shallower copy of the target — different weights, same vocab."""
    cfg, _, _ = small
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft",
                               n_layers=max(1, cfg.n_layers // 2))
    dm = build_model(dcfg)
    return dm, dm.init(jax.random.PRNGKey(3))


def _reqs(toks, order, sps=None, G=8):
    return [Request(rid=i, prompt=np.asarray(toks[i]), max_new_tokens=G,
                    sampling=(sps[i] if sps else None)) for i in order]


@pytest.fixture(scope="module")
def spec_runs(small, draft):
    """Shared greedy runs: non-spec reference, self-draft spec, and
    separate-draft spec over the same four prompts."""
    cfg, model, params = small
    dm, dp = draft
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                                         cfg.vocab_size))

    def engine(spec_cfg, num_pages=64):
        return ContinuousServeEngine(
            model, params, num_slots=3, page_size=4, num_pages=num_pages,
            max_len=24, prefill_chunk=5, speculative=spec_cfg)

    ref_eng = engine(None)
    ref = ref_eng.run(_reqs(toks, [0, 1, 2, 3]))
    self_eng = engine(SpeculativeConfig(gamma=GAMMA))
    self_out = self_eng.run(_reqs(toks, [0, 1, 2, 3]))
    sep_eng = engine(SpeculativeConfig(draft_model=dm, draft_params=dp,
                                       gamma=GAMMA))
    sep_out = sep_eng.run(_reqs(toks, [0, 1, 2, 3]))
    return toks, ref_eng, ref, self_eng, self_out, sep_eng, sep_out


# ---------------------------------------------------------------------------
# Greedy byte-identity (the lossless guarantee)
# ---------------------------------------------------------------------------


def test_greedy_self_draft_byte_identical(spec_runs):
    """With the target drafting for itself, every greedy proposal is the
    target argmax: full acceptance, zero waste, identical streams."""
    toks, _, ref, _, self_out, _, _ = spec_runs
    for i in range(4):
        np.testing.assert_array_equal(ref.results[i], self_out.results[i])
    assert self_out.spec_windows > 0
    assert self_out.accepted_per_window == pytest.approx(GAMMA)
    assert self_out.spec_wasted == 0


def test_greedy_separate_draft_byte_identical(spec_runs):
    """Speculative decoding never changes the target's output — a draft
    with different weights only changes how fast tokens arrive."""
    toks, _, ref, _, _, _, sep_out = spec_runs
    for i in range(4):
        np.testing.assert_array_equal(ref.results[i], sep_out.results[i])
    # drafted = gamma per window, accepted <= drafted
    assert sep_out.spec_drafted == GAMMA * sep_out.spec_windows
    assert 0 <= sep_out.spec_accepted <= sep_out.spec_drafted


def test_one_compiled_draft_and_verify_step(spec_runs):
    """The whole run — ragged admissions, retirements, a greedy batch —
    compiles exactly ONE draft scan and ONE multi-token verify step."""
    _, _, _, self_eng, _, sep_eng, _ = spec_runs
    for eng in (self_eng, sep_eng):
        assert eng._spec_draft._cache_size() == 1
        assert eng._spec_verify._cache_size() == 1


def test_greedy_identity_through_forced_preemption(small, draft, spec_runs):
    """A pool tight enough to evict mid-stream must restart gamma windows
    from the rewound position and re-emit identical greedy tokens — and
    the restart must not add compiles."""
    cfg, model, params = small
    dm, dp = draft
    toks, _, ref, _, _, _, _ = spec_runs
    tight = ContinuousServeEngine(
        model, params, num_slots=3, page_size=4, num_pages=9, max_len=24,
        prefill_chunk=5,
        speculative=SpeculativeConfig(draft_model=dm, draft_params=dp,
                                      gamma=GAMMA))
    out = tight.run(_reqs(toks, [0, 1, 2, 3]))
    assert out.preemptions > 0
    for i in range(4):
        np.testing.assert_array_equal(ref.results[i], out.results[i])
    assert tight._spec_draft._cache_size() == 1
    assert tight._spec_verify._cache_size() == 1


def test_greedy_identity_with_prefix_cache_hits(spec_runs):
    """Admission through shared prefix pages (skipped prefill) lands in
    the same speculative stream."""
    toks, _, ref, _, _, sep_eng, _ = spec_runs
    out = sep_eng.run([Request(rid=0, prompt=np.asarray(toks[0]),
                               max_new_tokens=8),
                       Request(rid=1, prompt=np.asarray(toks[0]),
                               max_new_tokens=8, arrival_time=0.01)])
    assert out.prefix_hit_tokens > 0
    np.testing.assert_array_equal(ref.results[0], out.results[0])
    np.testing.assert_array_equal(ref.results[0], out.results[1])


# ---------------------------------------------------------------------------
# Sampled speculation: determinism + per-slot params through p AND q
# ---------------------------------------------------------------------------


def test_sampled_spec_deterministic_across_slot_assignments(spec_runs):
    """Sampled speculative streams are keyed by absolute token index, so
    submission order (slot assignment) and rerun don't change them."""
    toks, _, _, _, _, sep_eng, _ = spec_runs
    sps = [SamplingParams(temperature=0.9, top_k=8, top_p=0.95,
                          seed=100 + i) for i in range(4)]
    a = sep_eng.run(_reqs(toks, [0, 1, 2, 3], sps))
    b = sep_eng.run(_reqs(toks, [3, 2, 1, 0], sps))
    for i in range(4):
        np.testing.assert_array_equal(a.results[i], b.results[i])
    # still one draft + one verify compile after the sampled mix
    assert sep_eng._spec_draft._cache_size() == 1
    assert sep_eng._spec_verify._cache_size() == 1


def test_sampled_spec_with_processors_runs_and_is_deterministic(spec_runs):
    """repetition_penalty + logit_bias thread through apply_processors on
    both the draft (q) and verify (p) sides; the stream must reproduce."""
    toks, _, _, _, _, sep_eng, _ = spec_runs
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=11,
                        repetition_penalty=1.3, logit_bias={3: -2.0, 7: 1.5})
    mk = lambda: [Request(rid=0, prompt=np.asarray(toks[0]),
                          max_new_tokens=8, sampling=sp)]
    a = sep_eng.run(mk())
    b = sep_eng.run(mk())
    np.testing.assert_array_equal(a.results[0], b.results[0])
    assert len(a.results[0]) == 8


# ---------------------------------------------------------------------------
# Acceptance-rule statistics (Leviathan et al.): empirical vs analytic
# ---------------------------------------------------------------------------


def test_acceptance_rate_matches_analytic_min_p_over_q():
    """Monte-Carlo over the engine's own primitives (slot_dist, slot_draw,
    spec_uniform tags): the proposal-acceptance rate converges to
    sum_t q(t) * min(1, p(t)/q(t)), and the EMITTED marginal (accepted
    proposals + residual corrections) converges to p itself."""
    v, n = 12, 4096
    kq = jax.random.PRNGKey(20)
    lq = jax.random.normal(kq, (1, v)) * 1.5
    lp = jax.random.normal(jax.random.fold_in(kq, 1), (1, v)) * 1.5
    one = jnp.ones((n,), jnp.float32)
    zero_i = jnp.zeros((n,), jnp.int32)
    q = sampling.slot_dist(jnp.tile(lq, (n, 1)), one, zero_i, one, one * 0.0)
    p = sampling.slot_dist(jnp.tile(lp, (n, 1)), one, zero_i, one, one * 0.0)
    pos = jnp.arange(n, dtype=jnp.int32)      # one window position each
    prop = sampling.slot_draw(q, sampling.spec_uniform(0, pos,
                                                       sampling.TAG_PROPOSE))
    rows = jnp.arange(n)
    ratio = p[rows, prop] / jnp.maximum(q[rows, prop], 1e-20)
    accept = np.asarray(
        sampling.spec_uniform(0, pos, sampling.TAG_ACCEPT)
        < jnp.minimum(1.0, ratio))
    analytic = float(jnp.sum(q[0] * jnp.minimum(1.0, p[0] / jnp.maximum(
        q[0], 1e-20))))
    se = np.sqrt(analytic * (1 - analytic) / n)
    assert abs(accept.mean() - analytic) < 4 * se + 1e-6
    # rejected positions resample from the normalized residual max(p-q, 0)
    resid = jnp.maximum(p - q, 0.0)
    resid = resid / jnp.maximum(jnp.sum(resid, -1, keepdims=True), 1e-20)
    corr = sampling.slot_draw(resid, sampling.spec_uniform(
        0, pos, sampling.TAG_CORRECT))
    out = np.where(accept, np.asarray(prop), np.asarray(corr))
    emp = np.bincount(out, minlength=v) / n
    tv = 0.5 * np.abs(emp - np.asarray(p[0])).sum()
    assert tv < 0.05, f"total variation {tv:.3f} vs target p"


# ---------------------------------------------------------------------------
# Counters + RequestOutput metrics
# ---------------------------------------------------------------------------


def test_per_request_spec_counters_and_metrics(spec_runs):
    toks, _, _, _, self_out, _, sep_out = spec_runs
    for out in (self_out, sep_out):
        assert set(out.per_request) == {0, 1, 2, 3}
        for rid, st in out.per_request.items():
            assert st["spec_windows"] > 0
            assert 0 <= st["spec_accepted"] <= GAMMA * st["spec_windows"]
        assert sum(st["spec_windows"] for st in out.per_request.values()) \
            == out.spec_windows
        assert sum(st["spec_accepted"] for st in out.per_request.values()) \
            == out.spec_accepted
        for o in out.outputs.values():
            assert o.metrics["spec_windows"] == \
                out.per_request[o.rid]["spec_windows"]
            assert o.metrics["spec_accepted"] == \
                out.per_request[o.rid]["spec_accepted"]
        assert out.spec_wasted == out.spec_drafted - out.spec_accepted


# ---------------------------------------------------------------------------
# Prompt logprobs (SamplingParams.prompt_logprobs)
# ---------------------------------------------------------------------------


def _forward_plp(model, params, prompt):
    """Reference: position k's log-softmax row scores prompt token k+1."""
    lg = jax.jit(model.forward)(params, {"tokens": jnp.asarray(prompt)[None]})
    ls = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
    return np.asarray(jnp.take_along_axis(
        ls[:, :-1], jnp.asarray(prompt)[None, 1:, None], axis=-1)[0, :, 0])


def test_prompt_logprobs_continuous_chunked_exact(small, spec_runs):
    """Chunked prefill (3 chunks of 5 over a 12-token prompt) must score
    the prompt exactly as one jitted forward."""
    cfg, model, params = small
    toks, ref_eng, _, _, _, sep_eng, _ = spec_runs
    sp = SamplingParams(prompt_logprobs=True)
    for eng in (ref_eng, sep_eng):        # plain AND speculative engines
        out = eng.run([Request(rid=0, prompt=np.asarray(toks[0]),
                               max_new_tokens=4, sampling=sp)])
        got = out.outputs[0].prompt_logprobs
        assert got is not None and len(got) == 11
        np.testing.assert_allclose(np.asarray(got),
                                   _forward_plp(model, params, toks[0]),
                                   rtol=2e-4, atol=2e-4)


def test_prompt_logprobs_static_backend(small):
    cfg, model, params = small
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (2, 10), 0,
                                         cfg.vocab_size))
    llm = LLMEngine(model, params, backend="static", max_len=24)
    outs = llm.generate(toks, SamplingParams(prompt_logprobs=True),
                        max_new_tokens=4)
    for i in range(2):
        got = outs[i].prompt_logprobs
        assert got is not None and len(got) == 9
        np.testing.assert_allclose(np.asarray(got),
                                   _forward_plp(model, params, toks[i]),
                                   rtol=2e-4, atol=2e-4)


def test_prompt_logprobs_legacy_speculative_raises(small):
    cfg, model, params = small
    llm = LLMEngine(model, params, backend="speculative", max_len=24)
    with pytest.raises(ValueError, match="prompt"):
        llm.generate([np.arange(8) % cfg.vocab_size],
                     SamplingParams(prompt_logprobs=True), max_new_tokens=4)


# ---------------------------------------------------------------------------
# LLMEngine routing + DeploymentSpec accounting
# ---------------------------------------------------------------------------


def test_llm_speculative_kwarg_routes_to_continuous_only(small, draft):
    cfg, model, params = small
    dm, dp = draft
    sc = SpeculativeConfig(draft_model=dm, draft_params=dp, gamma=2)
    with pytest.raises(ValueError, match="continuous"):
        LLMEngine(model, params, backend="static", max_len=24,
                  speculative=sc)
    llm = LLMEngine(model, params, backend="continuous", max_len=24,
                    num_slots=2, page_size=4, speculative=sc)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                         cfg.vocab_size))
    ref = LLMEngine(model, params, backend="continuous", max_len=24,
                    num_slots=2, page_size=4)
    a = llm.generate(toks, max_new_tokens=6)
    b = ref.generate(toks, max_new_tokens=6)
    for i in range(2):
        assert a[i].token_ids == b[i].token_ids
        assert a[i].metrics["spec_windows"] > 0
    assert llm.last_stats.spec_windows > 0


def test_spec_config_validation(small):
    cfg, model, params = small
    with pytest.raises(ValueError):
        SpeculativeConfig(gamma=0)


def test_legacy_speculative_backend_accepts_deployment_spec(small, draft):
    """LLMEngine(backend='speculative', spec=...) used to raise; now the
    spec prices the draft too and the resolved point is exposed."""
    cfg, model, params = small
    dm, dp = draft
    llm = LLMEngine(model, params, backend="speculative",
                    spec=DeploymentSpec(sku="rpu-cu", max_len=64),
                    draft_model=dm, draft_params=dp, gamma=4)
    dep = llm.deployment
    assert dep is not None
    assert dep.spec_gamma == 4
    assert dep.draft_weight_bytes_per_device > 0
    assert dep.spec_window_seconds > 0


def test_spec_decode_benchmark_smoke():
    """Fast-tier smoke of the measured Fig-14 benchmark: a tiny
    target/draft pair through the real engines, outputs byte-identical
    (asserted inside), rows + speedup returned.  The >=1.3x gate runs in
    the slow CI tier at full size."""
    from benchmarks.spec_decode import run_measured
    rows, speedup = run_measured(gamma=2, slots=2, n_req=3, max_new=8,
                                 n_layers=2, draft_layers=1, damp=0.0,
                                 seed=0, reps=1)
    assert speedup > 0
    metrics = {r.metric for r in rows}
    assert "measured speedup" in metrics
    assert "accepted/window (measured)" in metrics
    assert "accepted/window (modeled)" in metrics


def test_deployment_resolve_draft_window_model(small, draft):
    cfg, model, params = small
    dm, dp = draft
    spec = DeploymentSpec(sku="rpu-cu", max_len=64)
    plain = spec.resolve(model)
    a, g = 0.6, 4
    res = spec.resolve(model, draft=dm, draft_params=dp, gamma=g,
                       spec_accept_rate=a)
    # draft weights join the capacity budget; draft KV pages ride in the
    # SAME page-id space, so the per-token pool cost is the combined one
    assert res.draft_weight_bytes_per_device > 0
    assert res.kv_token_bytes == \
        plain.kv_token_bytes + res.draft_kv_token_bytes
    assert res.num_pages <= plain.num_pages
    expected = a * (1.0 - a ** g) / (1.0 - a)
    assert res.spec_expected_accepted == pytest.approx(expected)
    assert res.spec_window_seconds > res.step_seconds
    assert "spec" in res.describe()
    d = res.as_dict()
    for k in ("spec_gamma", "spec_expected_accepted", "spec_window_seconds",
              "spec_tokens_per_s_ceiling", "spec_accept_rate"):
        assert k in d, k
