"""Model assembly: config -> executable model (init / forward / prefill /
decode_step) for all assigned architecture families.

A model is a sequence of **segments**; each segment is a homogeneous run of
layers executed with ``jax.lax.scan`` over stacked parameters (O(1) HLO in
depth).  A segment step may contain several block kinds (e.g. Llama4's
alternating dense/MoE pair), so heterogeneous-period stacks still scan.
Layers that differ in attention window (Hymba's global/SWA mix) are split
into separate segments so the window — and hence the KV-cache geometry —
stays static per segment.

Block kinds:
  attn_dense   GQA attention + SwiGLU MLP            (qwen*, phi3, danube, hubert, internvl2 backbone)
  attn_moe     GQA attention + MoE                    (llama4-maverick)
  mla_dense    MLA attention + SwiGLU MLP             (deepseek first layer)
  mla_moe      MLA attention + MoE(+shared)           (deepseek)
  ssm          Mamba2 SSD mixer (no MLP)              (mamba2)
  hybrid       attention ∥ SSM heads, then MLP        (hymba)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib, ssm as ssm_lib
from repro.models.common import (
    ModelConfig, count_params, dense_init, embed_init, rmsnorm, split_keys,
)
from repro.parallel.hints import shard_hint


@dataclasses.dataclass(frozen=True)
class Segment:
    kinds: tuple[str, ...]
    reps: int
    window: int | None = None     # attention window; None = full attention


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------


def build_plan(cfg: ModelConfig) -> list[Segment]:
    if cfg.family == "ssm":
        return [Segment(("ssm",), cfg.n_layers)]
    if cfg.family == "hybrid":
        # per-layer window: global attention at layers 0, every
        # ``global_attn_every``, and the last layer; SWA elsewhere.
        wins = []
        for i in range(cfg.n_layers):
            is_global = (cfg.global_attn_every and
                         (i % cfg.global_attn_every == 0 or i == cfg.n_layers - 1))
            wins.append(None if is_global else cfg.sliding_window)
        segs: list[Segment] = []
        for w in wins:
            if segs and segs[-1].window == w:
                segs[-1] = dataclasses.replace(segs[-1], reps=segs[-1].reps + 1)
            else:
                segs.append(Segment(("hybrid",), 1, w))
        return segs
    w = cfg.sliding_window
    if cfg.mla:
        segs = []
        nd = cfg.first_dense_layers
        if nd:
            segs.append(Segment(("mla_dense",), nd, w))
        segs.append(Segment(("mla_moe",), cfg.n_layers - nd, w))
        return segs
    if cfg.moe:
        if cfg.moe_layer_period == 1:
            segs = []
            nd = cfg.first_dense_layers
            if nd:
                segs.append(Segment(("attn_dense",), nd, w))
            segs.append(Segment(("attn_moe",), cfg.n_layers - nd, w))
            return segs
        assert cfg.n_layers % cfg.moe_layer_period == 0
        kinds = tuple(["attn_dense"] * (cfg.moe_layer_period - 1) + ["attn_moe"])
        return [Segment(kinds, cfg.n_layers // cfg.moe_layer_period, w)]
    return [Segment(("attn_dense",), cfg.n_layers, w)]


# ---------------------------------------------------------------------------
# Block dispatch
# ---------------------------------------------------------------------------


def _init_block(kind: str, key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, 4)
    d = cfg.d_model
    ln = lambda: jnp.ones((d,), jnp.float32)
    if kind == "attn_dense":
        return {"ln1": ln(), "attn": layers.init_attn(ks[0], cfg),
                "ln2": ln(), "mlp": layers.init_mlp(ks[1], cfg)}
    if kind == "attn_moe":
        return {"ln1": ln(), "attn": layers.init_attn(ks[0], cfg),
                "ln2": ln(), "moe": moe_lib.init_moe(ks[1], cfg)}
    if kind == "mla_dense":
        return {"ln1": ln(), "attn": layers.init_mla(ks[0], cfg),
                "ln2": ln(), "mlp": layers.init_mlp(ks[1], cfg, cfg.d_ff)}
    if kind == "mla_moe":
        return {"ln1": ln(), "attn": layers.init_mla(ks[0], cfg),
                "ln2": ln(), "moe": moe_lib.init_moe(ks[1], cfg)}
    if kind == "ssm":
        return {"ln1": ln(), "ssm": ssm_lib.init_ssm(ks[0], cfg)}
    if kind == "hybrid":
        return {"ln1": ln(), "attn": layers.init_attn(ks[0], cfg),
                "ssm": ssm_lib.init_ssm(ks[1], cfg),
                "attn_out_norm": ln(), "ssm_out_norm": ln(),
                "ln2": ln(), "mlp": layers.init_mlp(ks[2], cfg)}
    raise ValueError(kind)


def _init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                      window: int | None, dtype=None):
    dtype = dtype or jnp.bfloat16
    if kind in ("attn_dense", "attn_moe"):
        return layers.init_attn_cache(cfg, batch, max_len, window, dtype=dtype)
    if kind in ("mla_dense", "mla_moe"):
        return layers.init_mla_cache(cfg, batch, max_len, dtype=dtype)
    if kind == "ssm":
        return ssm_lib.init_ssm_state(cfg, batch)
    if kind == "hybrid":
        return {"attn": layers.init_attn_cache(cfg, batch, max_len, window,
                                               dtype=dtype),
                "ssm": ssm_lib.init_ssm_state(cfg, batch)}
    raise ValueError(kind)


def _ffn(kind: str, p: dict, x, cfg: ModelConfig, moe_impl: str):
    if kind.endswith("_moe") or kind == "attn_moe":
        return moe_lib.moe_forward(x, p["moe"], cfg, impl=moe_impl)
    return layers.mlp_forward(p["mlp"], x)


def _block_forward(kind: str, p: dict, x, cfg: ModelConfig, window,
                   moe_impl: str):
    if kind == "ssm":
        out, _ = ssm_lib.ssm_forward(rmsnorm(x, p["ln1"], cfg.norm_eps), p["ssm"], cfg)
        return x + out
    if kind == "hybrid":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a = layers.attn_forward(p["attn"], h, cfg, window=window)
        s, _ = ssm_lib.ssm_forward(h, p["ssm"], cfg)
        mix = 0.5 * (rmsnorm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rmsnorm(s, p["ssm_out_norm"], cfg.norm_eps))
        x = x + mix
        x = x + layers.mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind.startswith("mla"):
        a = layers.mla_forward(p["attn"], h, cfg)
    else:
        a = layers.attn_forward(p["attn"], h, cfg, window=window)
    x = x + a
    x = x + _ffn(kind, p, rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, moe_impl)
    return shard_hint(x, "act_bsd")


def _block_prefill(kind: str, p: dict, x, cfg: ModelConfig, window, cache,
                   moe_impl: str):
    if kind == "ssm":
        out, st = ssm_lib.ssm_forward(rmsnorm(x, p["ln1"], cfg.norm_eps),
                                      p["ssm"], cfg, None)
        return x + out, st
    if kind == "hybrid":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, ac = layers.attn_prefill(p["attn"], h, cfg, cache["attn"], window=window)
        s, sc = ssm_lib.ssm_forward(h, p["ssm"], cfg, None)
        mix = 0.5 * (rmsnorm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rmsnorm(s, p["ssm_out_norm"], cfg.norm_eps))
        x = x + mix
        x = x + layers.mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x, {"attn": ac, "ssm": sc}
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind.startswith("mla"):
        a, c = layers.mla_prefill(p["attn"], h, cfg, cache)
    else:
        a, c = layers.attn_prefill(p["attn"], h, cfg, cache, window=window)
    x = x + a
    x = x + _ffn(kind, p, rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, moe_impl)
    return x, c


def _init_block_page_pool(kind: str, cfg: ModelConfig, num_pages: int,
                          page_size: int, dtype=None):
    dtype = dtype or jnp.bfloat16
    if kind in ("attn_dense", "attn_moe"):
        return layers.init_attn_page_pool(cfg, num_pages, page_size,
                                          dtype=dtype)
    if kind in ("mla_dense", "mla_moe"):
        return layers.init_mla_page_pool(cfg, num_pages, page_size,
                                         dtype=dtype)
    raise NotImplementedError(
        f"continuous batching: no paged cache for block kind {kind!r} "
        "(ssm/hybrid state is per-slot, not positional — future PR)")


# Paged-cache leaf names with a token axis (scatter/gather targets); other
# leaves (e.g. slot_pos) are dense-path bookkeeping with no paged analogue.
_PAGED_LEAF_KEYS = ("k", "v", "c_kv", "k_rope")


def _block_decode_paged(kind: str, p: dict, x, cfg: ModelConfig, window,
                        pool, page_table, pos, moe_impl: str):
    """Paged analogue of ``_block_decode``: per-slot ragged positions and
    K/V gathered through the page table.  x: (B, D)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind.startswith("mla"):
        a, c = layers.mla_decode_paged(p["attn"], h, cfg, pool, page_table, pos)
    elif kind in ("attn_dense", "attn_moe"):
        a, c = layers.attn_decode_paged(p["attn"], h, cfg, pool, page_table,
                                        pos, window=window)
    else:
        raise NotImplementedError(kind)
    x = x + a
    x = x + _ffn(kind, p, rmsnorm(x[:, None, :], p["ln2"], cfg.norm_eps), cfg,
                 moe_impl)[:, 0]
    return x, c


def _block_decode(kind: str, p: dict, x, cfg: ModelConfig, window, cache,
                  cur_pos, moe_impl: str):
    """x: (B, D) single-token representations."""
    if kind == "ssm":
        out, st = ssm_lib.ssm_decode_step(rmsnorm(x, p["ln1"], cfg.norm_eps),
                                          p["ssm"], cfg, cache)
        return x + out, st
    if kind == "hybrid":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, ac = layers.attn_decode(p["attn"], h, cfg, cache["attn"], cur_pos,
                                   window=window)
        s, sc = ssm_lib.ssm_decode_step(h, p["ssm"], cfg, cache["ssm"])
        mix = 0.5 * (rmsnorm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rmsnorm(s, p["ssm_out_norm"], cfg.norm_eps))
        x = x + mix
        x = x + layers.mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x, {"attn": ac, "ssm": sc}
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind.startswith("mla"):
        a, c = layers.mla_decode(p["attn"], h, cfg, cache, cur_pos)
    else:
        a, c = layers.attn_decode(p["attn"], h, cfg, cache, cur_pos, window=window)
    x = x + a
    x = x + _ffn(kind, p, rmsnorm(x[:, None, :], p["ln2"], cfg.norm_eps), cfg,
                 moe_impl)[:, 0]
    return x, c


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Executable model for one ``ModelConfig``.

    Stateless: all state lives in explicit ``params`` / ``cache`` pytrees.
    """

    def __init__(self, cfg: ModelConfig, moe_impl: str = "auto"):
        self.cfg = cfg
        self.plan = build_plan(cfg)
        self.moe_impl = moe_impl
        assert sum(len(s.kinds) * s.reps for s in self.plan) == cfg.n_layers

    # ----- init -----
    def init(self, key) -> dict:
        cfg = self.cfg
        keys = split_keys(key, len(self.plan) + 3)
        stacks = []
        for seg, k in zip(self.plan, keys[:-3]):
            kinds_params = []
            for ki, kind in enumerate(seg.kinds):
                kk = jax.random.fold_in(k, ki)
                if seg.reps == 1:
                    kinds_params.append(_init_block(kind, kk, cfg))
                else:
                    kinds_params.append(jax.vmap(
                        lambda kkk: _init_block(kind, kkk, cfg))(
                            jax.random.split(kk, seg.reps)))
            stacks.append(tuple(kinds_params))
        params: dict[str, Any] = {"stacks": stacks,
                                  "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
        if cfg.frontend == "audio":
            params["in_proj"] = dense_init(keys[-3], cfg.d_model, cfg.d_model)
            params["head"] = dense_init(keys[-2], cfg.d_model, cfg.padded_vocab)
        else:
            params["embed"] = embed_init(keys[-3], cfg.padded_vocab, cfg.d_model)
            if not cfg.tie_embeddings:
                params["head"] = dense_init(keys[-2], cfg.d_model, cfg.padded_vocab)
        return params

    # ----- shared pieces -----
    def _embed_inputs(self, params: dict, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = batch["features"].astype(jnp.bfloat16) @ params["in_proj"]
        elif cfg.frontend == "vision":
            tok = params["embed"][batch["tokens"]]
            x = jnp.concatenate([batch["image_embeds"].astype(tok.dtype), tok],
                                axis=1)
        else:
            x = params["embed"][batch["tokens"]]
        return shard_hint(x, "act_bsd")

    def _head(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["head"]
        if cfg.padded_vocab != cfg.vocab_size:   # mask pad columns to -inf
            pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
        return shard_hint(logits, "logits")

    # ----- forward (training / no-cache prefill) -----
    def forward(self, params: dict, batch: dict, *, remat: bool = False) -> jnp.ndarray:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)

        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]

            def seg_step(xc, ps, seg=seg):
                for kind, p in zip(seg.kinds, ps):
                    xc = _block_forward(kind, p, xc, cfg, seg.window,
                                        self.moe_impl)
                return xc

            if remat:
                # Save ONLY the scan carry (layer boundary); recompute all
                # within-layer activations on the backward pass.  At 4k x 256
                # x 40L saving dot outputs too would need >100 GiB/device.
                seg_step = jax.checkpoint(seg_step)

            if seg.reps == 1:
                x = seg_step(x, stack)
            else:
                x, _ = jax.lax.scan(lambda c, ps: (seg_step(c, ps), None),
                                    x, stack)
        return self._head(params, x)

    # ----- loss -----
    @staticmethod
    def _xent(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
        """Mean cross-entropy without materializing (B,S,V) log-probs.

        ``logsumexp`` and ``take_along_axis`` reduce the vocab axis in f32
        on the fly, so the only (B,S,V) buffer is the bf16 logits (which
        shard over TP via the "logits" rule) — essential for 200k-vocab
        training cells.
        """
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        tgt = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - tgt)

    def loss(self, params: dict, batch: dict, *, remat: bool = False) -> jnp.ndarray:
        cfg = self.cfg
        logits = self.forward(params, batch, remat=remat)
        if cfg.frontend == "audio":
            return self._xent(logits, batch["labels"])
        tokens = batch["tokens"]
        if cfg.frontend == "vision":
            ni = batch["image_embeds"].shape[1]
            logits = logits[:, ni:, :]
        return self._xent(logits[:, :-1], tokens[:, 1:])

    # ----- cache -----
    def init_cache(self, batch: int, max_len: int, dtype=None) -> list:
        cfg = self.cfg
        caches = []
        for seg in self.plan:
            kinds_caches = []
            for kind in seg.kinds:
                single = _init_block_cache(kind, cfg, batch, max_len,
                                           seg.window, dtype)
                if seg.reps == 1:
                    kinds_caches.append(single)
                else:
                    kinds_caches.append(jax.tree.map(
                        lambda a: jnp.tile(a[None], (seg.reps,) + (1,) * a.ndim),
                        single))
            caches.append(tuple(kinds_caches))
        return caches

    # ----- paged cache (continuous-batching serve) -----
    def init_paged_cache(self, num_pages: int, page_size: int,
                         dtype=None) -> list:
        """Physical page pools, one per layer, in the same nested structure
        as ``init_cache`` (list over segments, tuple over kinds, stacked
        along a leading reps axis for scanned segments).  All layers share
        one logical page-id space — the allocator in ``runtime.kv_cache``
        is model-agnostic."""
        cfg = self.cfg
        pools = []
        for seg in self.plan:
            if seg.window is not None:
                raise NotImplementedError(
                    "continuous batching over sliding-window segments needs "
                    "ring-aware pages — future PR")
            kinds_pools = []
            for kind in seg.kinds:
                single = _init_block_page_pool(kind, cfg, num_pages,
                                               page_size, dtype)
                if seg.reps == 1:
                    kinds_pools.append(single)
                else:
                    kinds_pools.append(jax.tree.map(
                        lambda a: jnp.tile(a[None], (seg.reps,) + (1,) * a.ndim),
                        single))
            pools.append(tuple(kinds_pools))
        return pools

    def scatter_prefill_cache(self, pools: list, dense_cache: list,
                              pt_rows: jnp.ndarray) -> list:
        """Scatter a dense prefill cache into the page pools.

        ``dense_cache`` comes from ``prefill`` with ``init_cache(b, L)``
        where L is a page multiple; ``pt_rows``: (b, L // page_size) int32
        physical page ids, one row per prefilled request.  Rows of padded
        requests (and unallocated tail entries) must point at the scratch
        page — they receive the padded garbage, live pages stay exclusive."""
        flat = pt_rows.reshape(-1)
        new_pools = []
        for si, seg in enumerate(self.plan):
            kinds_new = []
            for ki, _ in enumerate(seg.kinds):
                pool, dense = pools[si][ki], dense_cache[si][ki]
                out = dict(pool)
                for key in _PAGED_LEAF_KEYS:
                    if key not in pool:
                        continue
                    pl, dl = pool[key], dense[key]
                    page = pl.shape[1] if seg.reps == 1 else pl.shape[2]
                    if seg.reps == 1:
                        # dense (b, L, ...) -> (b * n_blocks, page, ...)
                        blocks = dl.reshape(
                            (-1, page) + dl.shape[2:]).astype(pl.dtype)
                        out[key] = pl.at[flat].set(blocks)
                    else:
                        # dense (reps, b, L, ...) -> (reps, b*n_blocks, page, ...)
                        blocks = dl.reshape(
                            (dl.shape[0], -1, page) + dl.shape[3:]).astype(pl.dtype)
                        out[key] = pl.at[:, flat].set(blocks)
                kinds_new.append(out)
            new_pools.append(tuple(kinds_new))
        return new_pools

    def decode_step_paged(self, params: dict, tokens: jnp.ndarray,
                          pools: list, page_table: jnp.ndarray,
                          pos: jnp.ndarray) -> tuple[jnp.ndarray, list]:
        """One continuous-batching decode step over the slot batch.

        tokens: (B,) int32 (one per slot); pos: (B,) int32 per-slot ragged
        positions; page_table: (B, n_blocks) int32.  Inactive slots point
        at the scratch page and are masked out by the caller."""
        cfg = self.cfg
        assert cfg.frontend != "audio", "encoder-only models have no decode step"
        x = params["embed"][tokens]
        x = shard_hint(x, "act_bd")
        new_pools = []
        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]

            def seg_step(xc, layer, seg=seg):
                ps, cs = layer
                new_cs = []
                for kind, p, c in zip(seg.kinds, ps, cs):
                    xc, nc = _block_decode_paged(kind, p, xc, cfg, seg.window,
                                                 c, page_table, pos,
                                                 self.moe_impl)
                    new_cs.append(nc)
                return xc, tuple(new_cs)

            if seg.reps == 1:
                x, nc = seg_step(x, (stack, pools[si]))
            else:
                x, nc = jax.lax.scan(seg_step, x, (stack, pools[si]))
            new_pools.append(nc)
        logits = self._head(params, x[:, None, :])[:, 0]
        return logits, new_pools

    # ----- prefill -----
    def prefill(self, params: dict, batch: dict, cache: list):
        """Run the full prompt, fill the cache; returns (last_logits, cache)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        new_caches = []
        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]

            def seg_step(xc, layer, seg=seg):
                ps, cs = layer
                new_cs = []
                for kind, p, c in zip(seg.kinds, ps, cs):
                    xc, nc = _block_prefill(kind, p, xc, cfg, seg.window, c,
                                            self.moe_impl)
                    new_cs.append(nc)
                return xc, tuple(new_cs)

            if seg.reps == 1:
                x, nc = seg_step(x, (stack, cache[si]))
            else:
                x, nc = jax.lax.scan(seg_step, x, (stack, cache[si]))
            new_caches.append(nc)
        logits = self._head(params, x[:, -1:, :])[:, 0]
        return logits, new_caches

    # ----- decode -----
    def decode_step(self, params: dict, tokens: jnp.ndarray, cache: list,
                    cur_pos) -> tuple[jnp.ndarray, list]:
        """One decode step.  tokens: (B,) int32; cur_pos: scalar position."""
        cfg = self.cfg
        assert cfg.frontend != "audio", "encoder-only models have no decode step"
        x = params["embed"][tokens]
        x = shard_hint(x, "act_bd")
        new_caches = []
        for si, seg in enumerate(self.plan):
            stack = params["stacks"][si]

            def seg_step(xc, layer, seg=seg):
                ps, cs = layer
                new_cs = []
                for kind, p, c in zip(seg.kinds, ps, cs):
                    xc, nc = _block_decode(kind, p, xc, cfg, seg.window, c,
                                           cur_pos, self.moe_impl)
                    new_cs.append(nc)
                return xc, tuple(new_cs)

            if seg.reps == 1:
                x, nc = seg_step(x, (stack, cache[si]))
            else:
                x, nc = jax.lax.scan(seg_step, x, (stack, cache[si]))
            new_caches.append(nc)
        logits = self._head(params, x[:, None, :])[:, 0]
        return logits, new_caches

    def param_count(self, params) -> int:
        return count_params(params)


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)
