"""Block-paged KV cache for continuous-batching serve: a **ref-counted**
page pool with a prompt-prefix index and copy-on-write.

Layout (vLLM-style): every attention layer owns a **page pool** — an array
``(num_pages, page_size, ...)`` — and all layers share ONE logical page id
space, so a single host-side allocator manages the whole model.  A request's
token at absolute position ``t`` lives at
``pool[page_table[slot, t // page_size], t % page_size]`` in every layer.

The host side is split in two:

  * ``PageAllocator`` — a pure-python free-list allocator with per-owner
    page lists and **per-page reference counts**: a physical page may be
    named by several owners at once (prompt-prefix sharing), and is freed
    only when its last reference drops.  Physical page 0 is **reserved as a
    scratch page**: every unallocated page-table entry (and every inactive
    decode slot) points at it, so the jitted decode step can scatter/gather
    unconditionally — dead slots write garbage into scratch instead of
    corrupting live pages.
  * ``PagedKVCache`` — the per-slot page tables over that allocator, plus
    admission / growth / release / defrag bookkeeping, the
    **prompt-prefix index** (chained hash of full token blocks -> resident
    read-only page, LRU-evicted under pool pressure), and **copy-on-write**
    for the pathological case of a write landing in a shared page.

Prefix sharing only ever covers *full* prompt blocks, capped so at least
the final prompt token is always recomputed (its logits seed generation),
which means divergence naturally lands in request-private pages; CoW is
the defensive backstop, and the invariant tests pin its semantics (the
donor page stays byte-identical).

Device pools themselves live in the engine (they are model-shaped pytrees
built by ``Model.init_paged_cache``); this module is deliberately
JAX-light so the allocator invariants are testable without compiles.

Tensor-parallel serving shards the pool arrays over the mesh's model axis
(per the owning backend's ``paged_partition_spec`` — e.g. GQA pools split
their KV-head axis), but the page-id space stays LOGICAL and shared: every
shard holds its slice of the same physical page, so one host-side
allocator + one page table drive all shards, and admission / growth /
CoW / defrag bookkeeping is unchanged.  The allocator itself is
sharding-agnostic; per-device capacity accounting (pool bytes divide by
the shard degree for sharded leaves) lives in
``parallel.plan.paged_kv_token_bytes``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import numpy as np

SCRATCH_PAGE = 0

PREFIX_OWNER = ("prefix",)      # the index's own reference on cached pages


class PageAllocator:
    """Free-list page allocator with ref-counted, shareable ownership.

    Invariants (asserted by ``check()`` and tests/test_kv_cache.py):
      * page 0 is never handed out (scratch);
      * ``rc[p] >= 1`` for every live page and equals the number of
        owner-list entries naming ``p`` (ref-counts can never go negative:
        the last ``drop`` frees the page and deletes the count);
      * ``len(free) + len(unique live) + 1 == num_pages`` (conservation).
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is scratch)")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list: low page ids handed out first (helps locality)
        self._free: list[int] = list(range(num_pages - 1, 0, -1))
        self._rc: dict[int, int] = {}
        self._owned: dict[object, list[int]] = {}

    # -- queries ------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        """Unique live pages (shared pages count once)."""
        return len(self._rc)

    def pages_of(self, owner) -> list[int]:
        return list(self._owned.get(owner, ()))

    def refcount(self, page: int) -> int:
        return self._rc.get(page, 0)

    # -- alloc / share / free ----------------------------------------------
    def alloc(self, owner, n: int = 1) -> list[int] | None:
        """Allocate ``n`` exclusive pages for ``owner`` (all-or-nothing)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._rc[p] = 1
        self._owned.setdefault(owner, []).extend(pages)
        return pages

    def share(self, owner, pages: list[int]) -> None:
        """Add a reference from ``owner`` to already-live ``pages``."""
        for p in pages:
            if p not in self._rc:
                raise ValueError(f"cannot share dead page {p}")
            self._rc[p] += 1
        self._owned.setdefault(owner, []).extend(pages)

    def _drop_ref(self, page: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        rc = self._rc.get(page, 0)
        assert rc > 0, f"ref-count underflow on page {page}"
        if rc == 1:
            del self._rc[page]
            self._free.append(page)
            return True
        self._rc[page] = rc - 1
        return False

    def drop_page(self, owner, page: int) -> bool:
        """Remove ONE of ``owner``'s references to ``page``."""
        pages = self._owned.get(owner, [])
        pages.remove(page)                       # ValueError if not an owner
        if not pages:
            self._owned.pop(owner, None)
        return self._drop_ref(page)

    def free_owner(self, owner) -> int:
        """Release every reference of ``owner``; returns pages actually
        freed (shared pages survive under their remaining references)."""
        pages = self._owned.pop(owner, [])
        return sum(self._drop_ref(p) for p in pages)

    # -- defrag -------------------------------------------------------------
    def defrag(self) -> dict[int, int]:
        """Compact live pages into the lowest physical ids.

        Returns the ``{old_page: new_page}`` mapping for moved pages (empty
        when already compact).  A shared page moves once and every owner's
        reference follows it, so aliasing is preserved; the caller only has
        to (a) permute the device pools with the mapping and (b) rewrite
        its page tables (and prefix index) through it.
        """
        live = sorted(self._rc)
        mapping: dict[int, int] = {}
        for target, p in enumerate(live, start=1):   # page 0 stays scratch
            if p != target:
                mapping[p] = target
        if mapping:
            self._rc = {mapping.get(p, p): rc for p, rc in self._rc.items()}
            for owner, pages in self._owned.items():
                self._owned[owner] = [mapping.get(p, p) for p in pages]
            self._free = list(range(self.num_pages - 1, len(live), -1))
        return mapping

    # -- invariants ---------------------------------------------------------
    def check(self) -> None:
        counts: dict[int, int] = {}
        for owner, pages in self._owned.items():
            for p in pages:
                assert p != SCRATCH_PAGE, f"{owner} owns the scratch page"
                counts[p] = counts.get(p, 0) + 1
        assert counts == self._rc, "ref-counts out of sync with owner lists"
        assert all(rc >= 1 for rc in self._rc.values()), "dead page counted"
        assert not (set(self._rc) & set(self._free)), "page both free and live"
        assert len(self._free) + len(self._rc) + 1 == self.num_pages, \
            "free-list conservation violated"


@dataclasses.dataclass
class SlotView:
    """Host view of one decode slot's cache occupancy."""
    owner: object
    num_tokens: int = 0        # absolute positions written so far


def _chain_key(prev: bytes, block_tokens: np.ndarray) -> bytes:
    """Position-dependent content hash of one full token block."""
    h = hashlib.blake2b(prev, digest_size=16)
    h.update(np.ascontiguousarray(block_tokens, np.int32).tobytes())
    return h.digest()


class PagedKVCache:
    """Per-slot page tables over a ``PageAllocator``, with prefix caching.

    ``table()`` materializes the ``(num_slots, max_blocks)`` int32 page
    table the jitted decode step consumes; rows of inactive slots (and the
    unallocated tail of active rows) point at the scratch page.

    Prefix caching (``enable_prefix_cache=True``): after a request's
    prompt is fully prefilled, its full blocks are inserted into an LRU
    index keyed by the chained block hash; a later ``admit`` with matching
    leading blocks **shares** those pages read-only instead of allocating
    and recomputing them.  The index holds its own reference on each cached
    page, so pages outlive their request until pool pressure reclaims them
    (LRU, index-only pages first).
    """

    def __init__(self, *, num_slots: int, num_pages: int, page_size: int,
                 max_blocks: int, enable_prefix_cache: bool = False,
                 has_full: bool = True, ring=None,
                 recompute_shared: bool = False):
        self.num_slots = num_slots
        self.max_blocks = max_blocks
        self.page_size = page_size
        self.enable_prefix_cache = enable_prefix_cache
        # -- stateful cache layouts (runtime.state_cache) --
        # has_full=False: no segment streams full-context KV (pure
        # SSM / pure sliding-window models) — admission is slot-based
        # only, the full table stays parked on scratch.
        # ring: a RingPageSpace for the model's sliding-window segments,
        # grown with ``ensure`` and pruned with ``reclaim`` alongside
        # the full space so eviction moves both together.
        # recompute_shared: prefix hits share pages for CAPACITY but
        # report 0 shared tokens, so prefill recomputes from position 0
        # (hybrid models must replay the whole prompt to rebuild SSM
        # state and ring pages; the rewrites into shared attention
        # pages are byte-identical, so donors are unaffected).
        self.has_full = has_full
        self.ring = ring
        self.recompute_shared = recompute_shared
        if enable_prefix_cache and not has_full:
            raise ValueError("prefix cache requires full-KV pages")
        self.allocator = PageAllocator(num_pages, page_size)
        self._table = np.zeros((num_slots, max_blocks), np.int32)
        self._slots: dict[int, SlotView] = {}
        self._prefix: OrderedDict[bytes, int] = OrderedDict()  # key -> page
        self._prefix_pages: dict[int, bytes] = {}              # page -> key
        # counters for serve stats
        self.hit_tokens = 0          # prompt tokens satisfied from the index
        self.lookup_tokens = 0       # prompt tokens admitted in total
        self.cow_events = 0

    # -- queries ------------------------------------------------------------
    def table(self) -> np.ndarray:
        return self._table

    def blocks_of(self, slot: int) -> int:
        return len(self.allocator.pages_of(("slot", slot)))

    def chain(self, slot: int, n_tokens: int) -> list[int]:
        """The page ids backing ``slot``'s first ``n_tokens`` positions, in
        block order — the unit the disaggregated ``KVHandoff`` transfers
        between engines (every id is live and owned/shared by the slot)."""
        return [int(p) for p in
                self._table[slot, :self._needed_blocks(n_tokens)]]

    @property
    def occupancy(self) -> float:
        """Fraction of non-scratch pages currently live."""
        return self.allocator.num_live / (self.allocator.num_pages - 1)

    @property
    def prefix_hit_rate(self) -> float:
        return self.hit_tokens / max(self.lookup_tokens, 1)

    def _needed_blocks(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    # -- prefix index -------------------------------------------------------
    def _shareable_blocks(self, n_tokens: int) -> int:
        """Full blocks eligible for sharing: always leave >= 1 prompt token
        to recompute, so the admitting request still produces first-token
        logits (and divergence lands in its own pages)."""
        return (n_tokens - 1) // self.page_size

    def _match_prefix(self, tokens: np.ndarray) -> list[int]:
        pages: list[int] = []
        key = b""
        ps = self.page_size
        for i in range(self._shareable_blocks(len(tokens))):
            key = _chain_key(key, tokens[i * ps:(i + 1) * ps])
            page = self._prefix.get(key)
            if page is None:
                break
            self._prefix.move_to_end(key)              # LRU touch
            pages.append(page)
        return pages

    def index_prompt(self, slot: int, tokens: np.ndarray) -> int:
        """Insert ``slot``'s fully-written prompt blocks into the index.

        Call only after prefill completed — a block must be resident before
        another request may share it.  Returns blocks newly indexed."""
        if not self.enable_prefix_cache:
            return 0
        added = 0
        key = b""
        ps = self.page_size
        for i in range(self._shareable_blocks(len(tokens))):
            key = _chain_key(key, tokens[i * ps:(i + 1) * ps])
            page = int(self._table[slot, i])
            if key in self._prefix or page == SCRATCH_PAGE \
                    or page in self._prefix_pages:
                continue
            self.allocator.share(PREFIX_OWNER, [page])
            self._prefix[key] = page
            self._prefix_pages[page] = key
            added += 1
        return added

    def _reclaim(self, n: int) -> int:
        """Drop up to ``n`` LRU index entries whose page would free."""
        freed = 0
        for key in list(self._prefix):
            if freed >= n:
                break
            page = self._prefix[key]
            if self.allocator.refcount(page) == 1:     # index-only page
                del self._prefix[key]
                del self._prefix_pages[page]
                self.allocator.drop_page(PREFIX_OWNER, page)
                freed += 1
        return freed

    def _alloc_with_reclaim(self, owner, n: int) -> list[int] | None:
        short = n - self.allocator.num_free
        if short > 0 and self._reclaim(short) < short:
            return None
        return self.allocator.alloc(owner, n)

    # -- lifecycle ----------------------------------------------------------
    def admit(self, slot: int, n_tokens: int,
              tokens: np.ndarray | None = None) -> int | None:
        """Back ``n_tokens`` positions for ``slot``; returns the number of
        leading prompt tokens satisfied by shared prefix pages (0 without a
        hit), or None when the pool cannot back the request."""
        assert slot not in self._slots, f"slot {slot} already live"
        n_blocks = self._needed_blocks(n_tokens)
        if n_blocks > self.max_blocks:
            raise ValueError(
                f"request needs {n_blocks} blocks > max_blocks={self.max_blocks}")
        owner = ("slot", slot)
        if not self.has_full:
            # slot-based admission only: ring pages (and state-pool rows)
            # are backed lazily by ``ensure`` as prefill advances
            self._slots[slot] = SlotView(owner=owner, num_tokens=n_tokens)
            self.lookup_tokens += n_tokens
            return 0
        shared: list[int] = []
        if self.enable_prefix_cache and tokens is not None:
            shared = self._match_prefix(np.asarray(tokens))
            # pin the matched pages BEFORE allocating: the fresh allocation
            # may reclaim LRU index-only pages, and an unpinned match (rc=1,
            # donor request already gone) would be freed and handed straight
            # back as a writable "fresh" page — aliasing two table entries
            self.allocator.share(owner, shared)
        fresh = self._alloc_with_reclaim(owner, n_blocks - len(shared))
        if fresh is None:
            for p in shared:
                self.allocator.drop_page(owner, p)
            return None
        self._slots[slot] = SlotView(owner=owner, num_tokens=n_tokens)
        self._table[slot, :len(shared)] = shared
        self._table[slot, len(shared):n_blocks] = fresh
        self.lookup_tokens += n_tokens
        self.hit_tokens += len(shared) * self.page_size
        return 0 if self.recompute_shared else len(shared) * self.page_size

    def ensure(self, slot: int, pos: int) -> bool:
        """Grow ``slot`` so position ``pos`` has a backing page (in every
        page space the model uses — full and ring grow together, so one
        preemption decision covers both)."""
        view = self._slots[slot]
        need = self._needed_blocks(pos + 1)
        if need > self.max_blocks:
            return False
        if self.has_full:
            have = self.blocks_of(slot)
            if need > have:
                pages = self._alloc_with_reclaim(view.owner, need - have)
                if pages is None:
                    return False
                self._table[slot, have:need] = pages
        if self.ring is not None and not self.ring.ensure(slot, pos):
            return False
        view.num_tokens = max(view.num_tokens, pos + 1)
        return True

    def reclaim(self, slot: int, pos_next: int) -> int:
        """Return ``slot``'s out-of-window ring pages to the ring
        allocator (no-op for pure full-KV layouts); returns pages freed.
        The engine calls this after every prefill chunk and decode step
        with the NEXT query position, keeping windowed residency at
        O(window) per slot."""
        if self.ring is None:
            return 0
        return self.ring.reclaim(slot, pos_next)

    def ring_table(self) -> np.ndarray | None:
        return None if self.ring is None else self.ring.table()

    def release(self, slot: int) -> int:
        """Drop every reference of ``slot`` (finish or eviction); returns
        pages actually freed (shared/indexed pages stay resident).
        Releases every space the slot owns — full pages, ring pages —
        together (the engine separately resets the slot's state-pool
        rows at its next admission)."""
        self._slots.pop(slot, None)
        freed = self.allocator.free_owner(("slot", slot))
        self._table[slot, :] = SCRATCH_PAGE
        if self.ring is not None:
            freed += self.ring.release(slot)
        return freed

    # -- copy-on-write ------------------------------------------------------
    def page_shared(self, slot: int, block: int) -> bool:
        return self.allocator.refcount(int(self._table[slot, block])) > 1

    def cow(self, slot: int, block: int) -> tuple[int, int] | None:
        """Detach ``slot``'s ``block`` from a shared page before a write.

        Allocates a private page and repoints the table entry; returns
        ``(donor_page, private_page)`` so the engine can copy the device
        contents, or None when the page was already exclusive.  The donor
        page (and every other table pointing at it) is untouched."""
        view = self._slots[slot]
        old = int(self._table[slot, block])
        if self.allocator.refcount(old) <= 1:
            return None
        fresh = self._alloc_with_reclaim(view.owner, 1)
        if fresh is None:
            raise RuntimeError("page pool exhausted during copy-on-write")
        self.allocator.drop_page(view.owner, old)
        self._table[slot, block] = fresh[0]
        self.cow_events += 1
        return old, fresh[0]

    # -- defrag -------------------------------------------------------------
    def defrag(self) -> np.ndarray | None:
        """Compact live pages; returns the pool gather index or None.

        The gather index ``g`` satisfies ``new_pool[i] = old_pool[g[i]]``
        for every page pool; page tables and the prefix index are rewritten
        in place (shared pages move once, so aliasing is preserved).
        """
        mapping = self.allocator.defrag()
        if not mapping:
            return None
        lut = np.arange(self.allocator.num_pages, dtype=np.int32)
        for old, new in mapping.items():
            lut[old] = new
        self._table = lut[self._table]
        self._prefix = OrderedDict(
            (k, int(lut[p])) for k, p in self._prefix.items())
        self._prefix_pages = {p: k for k, p in self._prefix.items()}
        gather = np.arange(self.allocator.num_pages, dtype=np.int32)
        for old, new in mapping.items():
            gather[new] = old
        return gather
