"""Logical sharding hints — decouples model code from mesh layout.

Model layers call ``shard_hint(x, "act_btd")`` at layer boundaries; the
launcher installs a rules table mapping logical names to
``PartitionSpec``s for the active mesh (see ``parallel.plan``).  Outside a
rules context the hints are no-ops, so models stay pure single-device code
for CPU tests.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping

import jax

_RULES: contextvars.ContextVar[Mapping | None] = contextvars.ContextVar(
    "shard_rules", default=None)


@contextlib.contextmanager
def sharding_rules(rules: Mapping):
    """Install logical-name -> PartitionSpec rules for the enclosed trace."""
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def _drop_uneven(sharding, shape):
    """Drop sharded axes on dims the array size doesn't divide (e.g. 25
    heads over a 16-way model axis) — the hint then constrains only the
    dims that partition cleanly."""
    from jax.sharding import NamedSharding, PartitionSpec
    if not isinstance(sharding, NamedSharding):
        return sharding
    mesh = sharding.mesh
    spec = sharding.spec
    new = []
    changed = False
    for dim in range(len(shape)):
        entry = spec[dim] if dim < len(spec) else None
        if entry is None:
            new.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        if shape[dim] % prod != 0:
            new.append(None)
            changed = True
        else:
            new.append(entry)
    if not changed:
        return sharding
    return NamedSharding(mesh, PartitionSpec(*new))


_SUSPENDED: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "shard_hints_suspended", default=False)


@contextlib.contextmanager
def suspend_hints():
    """Disable shard hints for the enclosed trace — used inside shard_map
    manual regions, where constraints built from the launcher's (all-Auto)
    mesh are invalid and break the backward pass."""
    token = _SUSPENDED.set(True)
    try:
        yield
    finally:
        _SUSPENDED.reset(token)


def _in_manual_region() -> bool:
    return _SUSPENDED.get()


def _rebuild_for_context(sharding):
    """Rebuild the rule's NamedSharding against the ambient abstract mesh.

    Inside a partial-manual shard_map region the context mesh marks some
    axes Manual; a constraint built from the launcher's all-Auto Mesh is
    rejected (including by the backward pass).  Keep only spec axes that
    are Auto in the ambient mesh and bind the spec to that mesh.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return sharding
    if am is None or not getattr(am, "axis_names", ()):
        return sharding
    if tuple(am.axis_names) != tuple(sharding.mesh.axis_names):
        return sharding
    types = dict(zip(am.axis_names, am.axis_types))
    manual = {a for a, t in types.items() if "Manual" in str(t)}
    if not manual:
        return sharding
    new = []
    for entry in sharding.spec:
        if entry is None:
            new.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a not in manual)
        new.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return NamedSharding(am, PartitionSpec(*new))


def shard_hint(x, name: str):
    """Apply a sharding constraint if a rule for ``name`` is installed."""
    rules = _RULES.get()
    if rules is None:
        return x
    spec = rules.get(name)
    if spec is None:
        return x
    if _in_manual_region():
        return x
    sh = _rebuild_for_context(spec)
    return jax.lax.with_sharding_constraint(x, _drop_uneven(sh, x.shape))


def ep_context():
    """(mesh, model_axis_name) for expert-parallel shard_map regions, or
    None outside a sharded launch (single-device tests).  Also None inside
    a suspended (already-manual) region: shard_map does not nest, so MoE
    layers traced there must run their local (replicated) path."""
    if _SUSPENDED.get():
        return None
    rules = _RULES.get()
    if rules is None:
        return None
    return rules.get("__ep__")


# -- manual tensor-parallel regions (sharded paged serving) -----------------
#
# The sharded serve path (parallel.plan.PagedServePlan) wraps the paged
# decode/prefill-chunk step in a manual shard_map over the mesh's model
# axis: every projection runs on its local head/d_ff slice and the model
# code marks the point where a Megatron column pair closes with
# ``tp_row_dot`` (the K-contracted matmul) + ``tp_psum``.  Outside a
# manual region (single-device tests, GSPMD launches) the marks are
# no-ops, so the model stays pure single-device code.
#
# Two reduction modes, mirroring the paged kernel's exact/online split:
#
#   * ``"gather"`` — all-gather the column-sharded intermediate (a pure
#     concatenation, in shard order == the unsharded column order) and run
#     the closing matmul replicated against the FULL row weight.  Every
#     activation is then BIT-IDENTICAL to the single-device trace — the
#     mode the byte-identical serve invariant is tested under (and the
#     CPU default).
#   * ``"psum"``   — classic Megatron: row-sharded weight, f32 partial
#     sums, ONE psum per block, round to the activation dtype after.
#     Minimal collective bytes and no replicated matmul — the production
#     accelerator mode; equal to single-device up to f32 reassociation of
#     the K split (token streams agree in practice, not by construction).

_TP_AXIS: contextvars.ContextVar[str | None] = contextvars.ContextVar(
    "manual_tp_axis", default=None)
_TP_MODE: contextvars.ContextVar[str] = contextvars.ContextVar(
    "manual_tp_mode", default="gather")


@contextlib.contextmanager
def manual_tp_axis(axis: str, mode: str = "gather"):
    """Declare that the enclosed trace runs inside a manual shard_map over
    ``axis``, closing each column/row pair per ``mode`` (see above)."""
    if mode not in ("gather", "psum"):
        raise ValueError(f"mode={mode!r} (want 'gather' or 'psum')")
    token = _TP_AXIS.set(axis)
    mtoken = _TP_MODE.set(mode)
    try:
        yield
    finally:
        _TP_MODE.reset(mtoken)
        _TP_AXIS.reset(token)


@contextlib.contextmanager
def no_manual_tp():
    """Disable the TP marks for the enclosed trace: subtrees whose weights
    run REPLICATED inside a manual region (MoE experts, shared experts)
    must close no pair — their matmuls are already complete."""
    token = _TP_AXIS.set(None)
    try:
        yield
    finally:
        _TP_AXIS.reset(token)


def tp_psum(x):
    """Close a Megatron column->row pair: the one reduction per block in
    ``"psum"`` mode; identity in ``"gather"`` mode (the all-gather inside
    ``tp_row_dot`` already completed the value) and outside manual TP."""
    axis = _TP_AXIS.get()
    if axis is None or _TP_MODE.get() == "gather":
        return x
    return jax.lax.psum(x, axis)


def tp_row_dot(x, w):
    """The K-contracted matmul closing a Megatron pair.

    Outside a manual region this is exactly ``x @ w``.  In ``"gather"``
    mode, ``x``'s sharded last dim is all-gathered (tiled, shard order ==
    column order) and the matmul runs against the full replicated ``w`` —
    bit-identical to the single-device dot.  In ``"psum"`` mode ``w`` is
    row-sharded and the contraction runs with f32 inputs so each shard's
    PARTIAL sum stays unrounded until ``tp_psum``: XLA accumulates a bf16
    dot in f32 and rounds once at the end, so rounding partials to bf16
    before the reduction would land a bf16 quantum off — the caller casts
    back to the activation dtype AFTER the psum instead.

    Packed (quantized) row weights route through ``quant.linear.qdot`` in
    the unsharded / gather paths; the psum path dequantizes to f32 first
    so the partial-sum contract above is unchanged."""
    from repro.quant.linear import is_packed, qdot
    axis = _TP_AXIS.get()
    if axis is None:
        return qdot(x, w)
    if _TP_MODE.get() == "gather":
        full = jax.lax.all_gather(x, axis, axis=x.ndim - 1, tiled=True)
        return qdot(full, w)
    import jax.numpy as jnp
    if is_packed(w):
        from repro.quant import formats
        w = formats.dequantize_any(w, jnp.float32)
    return x.astype(jnp.float32) @ w.astype(jnp.float32)
