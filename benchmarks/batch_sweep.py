"""Paper Fig 13 (speedup/energy vs batch) + Fig 11 bottom (throughput &
BW-utilization vs batch)."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.core import hardware
from repro.sim.compiler import CompileOptions, compile_decode_step
from repro.sim.engine import simulate_program
from repro.sim.gpu_model import GPUSystemConfig, gpu_decode_latency
from repro.sim.scaling import rpu_point


def run() -> list[Row]:
    rows: list[Row] = []
    # Fig 13: 8k prefill/2k decode context; sweep batch on 70B vs 1xH100-pair
    for name, n_gpus in [("llama3-8b", 1), ("llama3-70b", 2)]:
        cfg = get_config(name)
        gpu = GPUSystemConfig(chip=hardware.H100, n_gpus=n_gpus)
        for batch in (1, 4, 16, 64):
            g = gpu_decode_latency(cfg, gpu, batch=batch, seq_len=8192)
            p = rpu_point(cfg, 128, batch=batch, seq_len=8192)
            if p is None:
                continue
            rows.append(Row(
                "Fig13", f"{name} BS={batch} RPU-128 vs {n_gpus}xH100 speedup",
                g.total_s * 1e3 / p.ms_per_token,
                "40-50" if batch <= 4 else "15-20", "x",
                f"energy ratio {g.energy_j / max(p.sim.energy_j,1e-12):.1f}x"))

    # Fig 11 bottom: per-query throughput + bw utilization vs batch (128 CU)
    for name in ("llama3-405b", "llama4-maverick-400b-a17b",
                 "llama4-scout-109b-a17b"):
        cfg = get_config(name)
        for batch in (1, 8, 32, 128):
            prog = compile_decode_step(cfg, CompileOptions(
                n_cus=128, batch=batch, seq_len=8192))
            r = simulate_program(prog)
            rows.append(Row(
                "Fig11b", f"{name} BS={batch} tok/s/query",
                1.0 / r.latency_s, None, "",
                f"mem-bw util {r.mem_bw_utilization:.2f}"))
    return rows
