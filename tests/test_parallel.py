"""ParallelPlan / collective-matmul / gradient-compression tests.

Plan tests build NamedShardings for every assigned arch's full param tree
on the production meshes via abstract mesh devices (no allocation) and
assert even divisibility — exactly the property ``jit in_shardings``
enforces in the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config, reduced_config
from repro.launch import shapes as shp
from repro.models.model import build_model
from repro.parallel import compression
from repro.parallel.compat import make_abstract_mesh, shard_map
from repro.parallel.plan import make_plan
from repro.train.optimizer import init_opt_state


def _fake_mesh(shape, axes):
    """AbstractMesh-backed mesh: lets us build NamedShardings for a 512-chip
    topology inside the single-device test process."""
    return make_abstract_mesh(shape, axes)


def _check_divisible(shardings, tree):
    def chk(path, sh, leaf):
        spec = sh.spec
        for dim in range(leaf.ndim):
            entry = spec[dim] if dim < len(spec) else None
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = 1
            for a in axes:
                prod *= sh.mesh.shape[a]
            assert leaf.shape[dim] % prod == 0, (path, leaf.shape, spec)
    jax.tree_util.tree_map_with_path(
        lambda p, s, l: chk(p, s, l), shardings, tree)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh_shape,axes", [
    ((16, 16), ("data", "model")),
    ((2, 16, 16), ("pod", "data", "model")),
])
@pytest.mark.parametrize("shape_name", list(shp.SHAPES))
def test_plan_divisibility_all_cells(arch, mesh_shape, axes, shape_name):
    cfg = get_config(arch)
    shape = shp.SHAPES[shape_name]
    ok, _ = shp.cell_supported(cfg, shape)
    if not ok:
        pytest.skip("cell not runnable")
    mesh = _fake_mesh(mesh_shape, axes)
    plan = make_plan(cfg, mesh, global_batch=shape.global_batch,
                     shape_kind=shape.kind)
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    _check_divisible(plan.param_shardings(params), params)
    if shape.kind == "train":
        opt = jax.eval_shape(lambda: init_opt_state(params))
        _check_divisible(plan.param_shardings(opt), opt)
    if shape.kind in ("decode", "long_decode"):
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        _check_divisible(plan.cache_shardings(cache), cache)


def test_plan_kinds():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    cfg = get_config("qwen3-14b")
    tr = make_plan(cfg, mesh, global_batch=256, shape_kind="train")
    assert tr.fsdp == ("data",) and tr.seq_parallel and not tr.ep
    ld = make_plan(cfg, mesh, global_batch=1, shape_kind="long_decode")
    assert ld.dp == () and ld.cache_seq == ("data", "model")
    # dense decode with divisible widths: full-TP (the paper's regime —
    # one weight stream for the whole batch)
    de = make_plan(cfg, mesh, global_batch=128, shape_kind="decode")
    assert de.dp == () and de.tp == ("data", "model")
    # MoE decode keeps the DP plan (128 experts don't span 256 shards)
    big = make_plan(get_config("llama4-maverick-400b-a17b"), mesh,
                    global_batch=128, shape_kind="decode")
    assert big.dp == ("data",) and big.fsdp == ("data",)
    # SWA dims (kv 640) don't divide 256: DP plan
    sw = make_plan(get_config("h2o-danube-1.8b"), mesh, global_batch=128,
                   shape_kind="decode")
    assert sw.dp == ("data",) and sw.cache_seq == "model"


# ---------------------------------------------------------------------------
# Ring collective matmul (the paper's broadcast-overlap VMM, §IV)
# ---------------------------------------------------------------------------


def _ring_devices():
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >= 2 devices for a ring; covered by dry-run")
    return n


def test_ring_allgather_matmul_matches_dense():
    from repro.parallel.collective_matmul import ring_allgather_matmul
    n = _ring_devices()
    mesh = jax.make_mesh((n,), ("model",))
    k, m, nn = 8 * n, 16, 32
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, nn), jnp.float32)

    def f(x_frag, w_cols):
        return ring_allgather_matmul(x_frag, w_cols, axis_name="model")

    out = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(None, "model"), P(None, "model")),
        out_specs=P(None, "model")))(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


def test_ring_matmul_reducescatter_matches_dense():
    from repro.parallel.collective_matmul import ring_matmul_reducescatter
    n = _ring_devices()
    mesh = jax.make_mesh((n,), ("model",))
    k, m, nn = 8 * n, 16, 8 * n
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, nn), jnp.float32)

    def f(x_frag, w_rows):
        return ring_matmul_reducescatter(x_frag, w_rows, axis_name="model")

    out = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
        out_specs=P(None, "model")))(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Gradient compression (cross-pod DP)
# ---------------------------------------------------------------------------


def test_int8_roundtrip_error_feedback():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (256,), jnp.float32)
    q, scale = compression.int8_quantize(g)
    gd = compression.int8_dequantize(q, scale)
    assert float(jnp.max(jnp.abs(gd - g))) <= float(scale) + 1e-7


def test_error_feedback_accumulates_to_true_mean():
    """With error feedback, repeated compressed means converge: the running
    residual keeps what quantization dropped."""
    g = jnp.asarray([1e-4] * 64, jnp.float32)  # tiny values vanish in int8
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(200):
        q, scale = compression.int8_quantize(g + err)
        sent = compression.int8_dequantize(q, scale)
        err = g + err - sent
        total = total + sent
    mean_sent = total / 200.0
    np.testing.assert_allclose(np.asarray(mean_sent), np.asarray(g),
                               rtol=0.05, atol=1e-6)


# ---------------------------------------------------------------------------
# PagedServePlan (tensor-parallel paged serving)
# ---------------------------------------------------------------------------


def test_paged_serve_plan_specs_and_local_config():
    from repro.parallel.plan import make_paged_serve_plan, paged_kv_token_bytes
    import dataclasses
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-14b")),
                              n_heads=8, n_kv_heads=4)
    model = build_model(cfg)
    mesh = _fake_mesh((2, 4), ("data", "model"))
    plan = make_paged_serve_plan(cfg, mesh, reduce="gather")
    lc = plan.local_config(cfg)
    assert (lc.n_heads, lc.n_kv_heads, lc.d_ff) == (2, 1, cfg.d_ff // 4)
    # pool specs shard the KV-head axis of the (reps-stacked) gqa pools
    specs = plan.pool_specs(model)
    leaf = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))[0]
    assert leaf == P(None, None, None, "model", None)
    # gather mode: column weights shard, row weights stay replicated
    params = model.init(jax.random.PRNGKey(0))
    pspecs = plan.param_specs(params)
    stack = pspecs["stacks"][0][0]
    assert stack["attn"]["wq"] == P(None, None, "model")
    assert stack["attn"]["wo"] == P()
    assert stack["mlp"]["w_gate"] == P(None, None, "model")
    assert pspecs["embed"] == P()
    # psum mode row-shards the closing weight instead
    psplan = make_paged_serve_plan(cfg, mesh, reduce="psum")
    pstack = psplan.param_specs(params)["stacks"][0][0]
    assert pstack["attn"]["wo"] == P(None, "model", None)
    # per-device KV bytes/token shrink 1/TP
    assert (paged_kv_token_bytes(model, tp=4)
            == paged_kv_token_bytes(model, tp=1) // 4)
    assert plan.psum_bytes_per_step(model, num_slots=8) > 0


def test_paged_serve_plan_quantized_pool_and_packed_param_specs():
    """fp8 pools add k_scale/v_scale leaves — every leaf (codes AND
    scales) shards the KV-head axis — and mxfp4-packed params get the
    parent weight's partition spec on both pytree children, so the TP
    serve path shards the packed codes/scales like the dense weight."""
    from repro.parallel.plan import make_paged_serve_plan, \
        paged_kv_token_bytes
    from repro.quant.formats import PackedMXFP4
    from repro.quant.linear import quantize_params
    import dataclasses
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-14b")),
                              n_heads=8, n_kv_heads=4)
    model = build_model(cfg)
    mesh = _fake_mesh((2, 4), ("data", "model"))
    plan = make_paged_serve_plan(cfg, mesh, reduce="gather")
    specs = plan.pool_specs(model, cache_dtype="fp8")
    leaves = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    dense = jax.tree.leaves(plan.pool_specs(model),
                            is_leaf=lambda s: isinstance(s, P))
    assert len(leaves) == 2 * len(dense)      # + k_scale/v_scale per pool
    assert set(leaves) == {P(None, None, None, "model", None),   # codes
                           P(None, None, None, "model")}         # scales
    # packed param children inherit the parent leaf's spec
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_params(params, "mxfp4")
    pspecs = plan.param_specs(qp)
    wq = pspecs["stacks"][0][0]["attn"]["wq"]
    assert isinstance(qp["stacks"][0][0]["attn"]["wq"], PackedMXFP4)
    assert wq.codes == wq.scales == P(None, None, "model")
    assert pspecs["stacks"][0][0]["attn"]["wo"].codes == P()  # gather mode
    # sharded packed bytes divide evenly: N is the sharded axis for both
    # children and the mesh TP degree divides it
    for leaf in (qp["stacks"][0][0]["attn"]["wq"].codes,
                 qp["stacks"][0][0]["attn"]["wq"].scales):
        assert leaf.shape[-1] % 4 == 0
    # quantized per-token pool bytes still scale 1/TP on the code leaves
    assert paged_kv_token_bytes(model, tp=4, cache_dtype="fp8") \
        == paged_kv_token_bytes(model, tp=1, cache_dtype="fp8") // 4


def test_paged_serve_plan_kv_head_replication():
    """llama3-style kvh < TP: the plan replicates each KV head on tp/kvh
    shards instead of raising — local model runs 1 KV head/shard, the
    pools widen to tp heads, and capacity accounting counts replicas."""
    from repro.parallel.plan import make_paged_serve_plan, \
        paged_kv_token_bytes
    import dataclasses
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-14b")),
                              n_heads=8, n_kv_heads=2)
    model = build_model(cfg)
    mesh = _fake_mesh((1, 8), ("data", "model"))
    plan = make_paged_serve_plan(cfg, mesh, reduce="gather")
    assert plan.kv_repl == 4
    lc = plan.local_config(cfg)
    assert (lc.n_heads, lc.n_kv_heads) == (1, 1)
    pc = plan.pool_config(cfg)
    assert pc.n_kv_heads == 8                    # widened to tp heads
    # wk/wv columns repeat per head group; wq untouched
    params = model.init(jax.random.PRNGKey(0))
    prep = plan.prepare_params(params, cfg)
    wk = params["stacks"][0][0]["attn"]["wk"]
    wkp = prep["stacks"][0][0]["attn"]["wk"]
    assert wkp.shape[-1] == wk.shape[-1] * 4
    hd = cfg.hd
    w = np.asarray(wk).reshape(*wk.shape[:-1], 2, hd)
    wp = np.asarray(wkp).reshape(*wk.shape[:-1], 8, hd)
    for g in range(8):
        np.testing.assert_array_equal(wp[..., g, :], w[..., g // 4, :])
    np.testing.assert_array_equal(np.asarray(prep["stacks"][0][0]["attn"]
                                             ["wq"]),
                                  np.asarray(params["stacks"][0][0]["attn"]
                                             ["wq"]))
    # per-device KV bytes bottom out at ONE head (kvh/tp * kv_repl)
    full = paged_kv_token_bytes(model, tp=1)
    assert paged_kv_token_bytes(model, tp=8, kv_repl=4) == full // 2
    # still an error when kvh neither divides nor is divided by tp
    bad = dataclasses.replace(cfg, n_kv_heads=3)
    with pytest.raises(ValueError, match="n_kv_heads"):
        make_paged_serve_plan(bad, mesh)


def test_paged_serve_plan_mla_pools_replicated():
    from repro.parallel.plan import make_paged_serve_plan
    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    model = build_model(cfg)
    mesh = _fake_mesh((2, 4), ("data", "model"))
    plan = make_paged_serve_plan(cfg, mesh)
    for spec in jax.tree.leaves(plan.pool_specs(model),
                                is_leaf=lambda s: isinstance(s, P)):
        assert spec == P()                 # latent pools shard nothing
    params = model.init(jax.random.PRNGKey(0))
    pspecs = plan.param_specs(params)
    moe_stack = pspecs["stacks"][-1][0]
    assert moe_stack["attn"]["w_uk"][-1] == "model"    # heads column-shard
    # MoE experts replicate inside the manual region (no nested EP)
    assert all(s == P() for s in jax.tree.leaves(
        moe_stack["moe"], is_leaf=lambda s: isinstance(s, P)))


def test_paged_serve_plan_validation():
    from repro.parallel.plan import make_paged_serve_plan
    import dataclasses
    mesh = _fake_mesh((2, 4), ("data", "model"))
    cfg = reduced_config(get_config("qwen3-14b"))
    # kvh=2 on 4-way TP replicates KV heads (no longer an error)
    assert make_paged_serve_plan(cfg, mesh).kv_repl == 2
    # kvh that neither divides nor divides into TP still fails
    bad = dataclasses.replace(cfg, n_heads=12, n_kv_heads=3)
    with pytest.raises(ValueError, match="n_kv_heads"):
        make_paged_serve_plan(bad, mesh)
    with pytest.raises(NotImplementedError, match="SSM"):
        make_paged_serve_plan(reduced_config(get_config("mamba2-370m")), mesh)
    with pytest.raises(ValueError, match="axis"):
        make_paged_serve_plan(cfg, _fake_mesh((8,), ("data",)))
