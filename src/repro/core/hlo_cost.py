"""Trip-count-aware cost analysis of compiled HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE, but all our
layer stacks (and the blocked-attention / SSD inner loops) are
``lax.scan``s, so FLOPs/bytes/collective-bytes would be undercounted by the
trip count (up to ~50x for a 48-layer stack).  This module walks the HLO
module text recursively:

  * ``while`` ops multiply their body+condition cost by the trip count,
    recovered from the canonical scan pattern in the condition computation
    (``compare(iv, constant N), direction=LT``).
  * ``fusion`` / ``call`` / ``conditional`` descend into the called
    computations (fusion FLOPs = dots inside the fused computation; fusion
    bytes = top-level operand + result bytes).
  * ``dot`` FLOPs = 2 x prod(result dims) x prod(contracting dims).
  * collective ops are tallied per kind with ring wire-byte estimates, so
    collectives inside scanned layers are correctly multiplied.

Bytes are the same op-level "operands + result" accounting that XLA's own
cost model uses (no cache modeling) — the right proxy for the HBM-stream
roofline term.

The walker is validated in tests/test_hlo_cost.py against fully-unrolled
lowerings of the same program (exact match for dot flops).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e8m0fnu": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# computation header: params may contain nested tuple parens
_COMP_HDR_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*"
    r"(?:\((?:[^()]|\((?:[^()]|\([^()]*\))*\))*\))?\s*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?P<ty>\((?:[^()]|\([^()]*\))*\)|"
    r"[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+(?P<op>[\w\-]+)"
    r"(?P<rest>\(.*)$")
_CALLS_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\s*"
    r"(\{[^}]*\}|%?[\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(ty: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(ty):
        size = _DTYPE_BYTES.get(m.group(1))
        if size is None:
            continue
        n = 1
        dims = m.group(2)
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * size
    return total


def _shape_elems(ty: str) -> int:
    m = _SHAPE_RE.search(ty)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_wire_bytes: dict = dataclasses.field(default_factory=dict)
    coll_count: dict = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_wire_bytes.items():
            self.coll_wire_bytes[k] = self.coll_wire_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + v * mult
        self.unknown_trip_loops += other.unknown_trip_loops * int(mult > 0)

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def total_coll_wire_bytes(self) -> float:
        return sum(self.coll_wire_bytes.values())


class HloModule:
    """Minimal HLO-text parser: computations as lists of op lines.

    ``discount_pure_converts``: XLA:CPU upcasts bf16 weights to f32 via
    wrapped_convert fusions (CPU has no bf16 GEMM); these copies don't
    exist on the TPU target, so they are skipped by default — the
    downstream f32 reads still count (conservative by 2x on weight
    streams; see EXPERIMENTS.md §Roofline methodology).
    """

    discount_pure_converts = True

    def __init__(self, text: str):
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        cur: list[str] | None = None
        cur_name = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_HDR_RE.match(line)
                # `= ` guard rejects op lines; strip /*index=N*/ comments
                # first (they contain '=')
                head = re.sub(r"/\*[^*]*\*/", "", line.split("->")[0])
                if m and " = " not in head:
                    cur_name = m.group(1)
                    cur = []
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur_name
                continue
            if line.strip() == "}":
                self.comps[cur_name] = cur
                cur = None
                continue
            cur.append(line)
        if self.entry is None and self.comps:
            # fall back: the computation containing the most ops
            self.entry = max(self.comps, key=lambda k: len(self.comps[k]))

    # ---- helpers ----
    def _called(self, rest: str) -> list[str]:
        names: list[str] = []
        for m in _CALLS_RE.finditer(rest):
            blob = m.group(1)
            for n in re.findall(r"%?([\w.\-]+)", blob):
                if n in self.comps:
                    names.append(n)
        return names

    def _trip_count(self, cond_comp: str) -> int | None:
        """Scan-style loop: condition compares induction var < constant."""
        lines = self.comps.get(cond_comp, [])
        consts = []
        for ln in lines:
            if "constant(" in ln:
                m = _TRIP_RE.search(ln)
                if m:
                    consts.append(int(m.group(1)))
        if not consts:
            return None
        # the loop bound is the largest integer constant in the condition
        return max(consts)

    @staticmethod
    def _operand_names(rest: str) -> list[str]:
        m = re.match(r"\((?:[^()]|\([^()]*\))*\)", rest)
        if not m:
            return []
        return re.findall(r"%([\w.\-]+)", m.group(0))

    def _operand_bytes(self, rest: str, symtab: dict) -> float:
        """Sum of operand sizes, resolved through the symbol table."""
        total = 0.0
        for name in self._operand_names(rest):
            ty = symtab.get(name)
            if ty:
                total += _shape_bytes(ty)
        return total

    # ops that touch only a slice of their big operand (XLA's cost model
    # likewise counts sliced bytes, not the full operand)
    _SLICING_OPS = ("dynamic-slice", "gather", "slice")

    def _root_dus_update_bytes(self, fused_comp: str) -> float | None:
        """If the fused computation's root is a dynamic-update-slice (the
        scan write-back pattern), return the update region's size; the root
        may be wrapped in bitcast/copy/convert."""
        lines = self.comps.get(fused_comp, [])
        symtab: dict[str, str] = {}
        defs: dict[str, "re.Match"] = {}
        root = None
        for ln in lines:
            m = _OP_RE.match(ln)
            if m:
                symtab[m.group(1)] = m.group("ty")
                defs[m.group(1)] = m
                if ln.lstrip().startswith("ROOT"):
                    root = m
        hops = 0
        while root is not None and hops < 4 and root.group("op") in (
                "bitcast", "copy", "convert", "reshape", "transpose"):
            names = self._operand_names(root.group("rest"))
            root = defs.get(names[0]) if names else None
            hops += 1
        if root is not None and root.group("op") == "dynamic-update-slice":
            names = self._operand_names(root.group("rest"))
            if len(names) > 1:
                upd = symtab.get(names[1], "")
                if upd:
                    return _shape_bytes(upd)
        return None

    def _fusion_result_bytes(self, fused_comp: str, default_ty: str) -> float:
        """Write bytes of a fusion: if the root is a dynamic-update-slice
        (scan writing one layer's slice into the stacked output), only the
        update region is written, not the whole stack."""
        dus = self._root_dus_update_bytes(fused_comp)
        return dus if dus is not None else _shape_bytes(default_ty)

    def _is_pure_convert(self, fused_comp: str) -> bool:
        """kLoop wrapped_convert fusions (dtype-only copies).  XLA:CPU
        inserts them to upcast bf16 weights for f32 GEMMs; they don't exist
        on the TPU target, so callers may discount them."""
        ops = []
        for ln in self.comps.get(fused_comp, []):
            m = _OP_RE.match(ln)
            if m and m.group("op") not in ("parameter",):
                ops.append(m.group("op"))
        return all(o in ("convert", "bitcast", "copy") for o in ops) and ops

    def _fusion_param_bytes(self, fused_comp: str, operand_tys: list[str]) -> float:
        """HBM reads of a fusion: for each parameter, count the full size
        unless every consumer inside the fused computation is a slicing op,
        in which case count the slice results (the scan-over-stacked-weights
        pattern: dynamic-slice of the (L, ...) stack reads one layer)."""
        lines = self.comps.get(fused_comp, [])
        # param index -> defined name
        param_names: dict[int, str] = {}
        for ln in lines:
            m = _OP_RE.match(ln)
            if m and m.group("op") == "parameter":
                idx_m = re.search(r"parameter\((\d+)\)", ln)
                if idx_m:
                    param_names[int(idx_m.group(1))] = m.group(1)
        # symbol table for update-operand lookups inside the fusion
        symtab: dict[str, str] = {}
        op_lines: list = []
        for ln in lines:
            m = _OP_RE.match(ln)
            if m:
                symtab[m.group(1)] = m.group("ty")
                op_lines.append(m)

        # see-through ops: XLA:CPU's bf16 legalization wraps tensors in
        # converts; on the TPU target those don't exist, so usage
        # classification must look through pure dtype/layout hops.
        _THROUGH = ("convert", "bitcast", "copy", "reshape")

        def usage(pname, depth=0):
            """Returns (sliced_bytes, whole: bool) for one value name."""
            sliced = 0.0
            whole = False
            used = False
            for m in op_lines:
                names = self._operand_names(m.group("rest") or "")
                if pname not in names:
                    continue
                used = True
                op = m.group("op")
                if op in self._SLICING_OPS:
                    sliced += _shape_bytes(m.group("ty"))
                elif op == "dynamic-update-slice" and names[0] == pname:
                    # DUS destination: only the update region is touched
                    upd = symtab.get(names[1], "") if len(names) > 1 else ""
                    sliced += _shape_bytes(upd)
                elif op in _THROUGH and depth < 4:
                    s2, w2, u2 = usage(m.group(1), depth + 1)
                    sliced += s2
                    whole = whole or w2
                    if w2:
                        break
                else:
                    whole = True
                    break
            return sliced, whole, used

        total = 0.0
        for i, ty in enumerate(operand_tys):
            pname = param_names.get(i)
            if pname is None:
                total += _shape_bytes(ty)
                continue
            sliced, whole, used = usage(pname)
            if not used:
                continue
            total += _shape_bytes(ty) if whole else sliced
        return total

    @staticmethod
    def _group_size(line: str) -> int:
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_RE.search(line)
        if m:
            first = m.group(1).split("},{")[0].strip("{}")
            if first:
                return len(first.split(","))
        return 1

    def _dot_flops(self, ty: str, rest: str, symtab: dict) -> float:
        """dot FLOPs = 2 x prod(result dims) x prod(lhs contracting dims).

        Operand shapes aren't inline in scheduled HLO — resolve the lhs
        operand's result type through the computation's symbol table.
        """
        out_elems = _shape_elems(ty)
        contract = 1
        m = _CONTRACT_RE.search(rest)
        if m:
            # Operand types may be inline (`dot(f32[4,32,48]{2,1,0} %a, ...)`,
            # the modern HLO syntax) or name-only (`dot(%a, %b)`); prefer the
            # inline type, else resolve the name through the symbol table.
            inline_m = re.match(r"\(\s*([a-z][a-z0-9]*\[[0-9,]*\])", rest)
            if inline_m:
                lhs_ty = inline_m.group(1)
            else:
                names = self._operand_names(rest)
                lhs_ty = symtab.get(names[0], "") if names else ""
            sm = _SHAPE_RE.search(lhs_ty)
            if sm and sm.group(2):
                lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(lhs_dims):
                        contract *= lhs_dims[idx]
        return 2.0 * out_elems * contract

    # ---- recursive walk ----
    def cost(self, comp: str | None = None,
             _memo: dict | None = None) -> Cost:
        if comp is None:
            comp = self.entry
        if _memo is None:
            _memo = {}
        if comp in _memo:
            return _memo[comp]
        total = Cost()
        _memo[comp] = total          # cycles impossible in HLO, safe
        lines = self.comps.get(comp, [])
        # first pass: symbol table (op name -> result type) for operand lookups
        symtab: dict[str, str] = {}
        for line in lines:
            m = _OP_RE.match(line)
            if m:
                symtab[m.group(1)] = m.group("ty")
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            op = m.group("op")
            ty = m.group("ty")
            rest = m.group("rest")
            if op == "while":
                called = self._called(rest)
                body_m = re.search(r"body=%?([\w.\-]+)", rest)
                cond_m = re.search(r"condition=%?([\w.\-]+)", rest)
                body = body_m.group(1) if body_m else (called[0] if called else None)
                cond = cond_m.group(1) if cond_m else None
                trip = self._trip_count(cond) if cond else None
                if trip is None:
                    trip = 1
                    total.unknown_trip_loops += 1
                if body and body in self.comps:
                    total.add(self.cost(body, _memo), trip)
                if cond and cond in self.comps:
                    total.add(self.cost(cond, _memo), trip)
                continue
            if op in ("fusion", "call", "conditional", "map", "reduce",
                      "reduce-window", "sort", "async-start"):
                callees = self._called(rest)
                for callee in callees:
                    sub = self.cost(callee, _memo)
                    # fused computations: count their dot flops +
                    # collectives, NOT their internal bytes
                    contrib = Cost(flops=sub.flops,
                                   coll_bytes=dict(sub.coll_bytes),
                                   coll_wire_bytes=dict(sub.coll_wire_bytes),
                                   coll_count=dict(sub.coll_count))
                    contrib.unknown_trip_loops = sub.unknown_trip_loops
                    total.add(contrib)
                if op == "fusion" and callees:
                    if (self.discount_pure_converts
                            and self._is_pure_convert(callees[0])):
                        continue
                    operand_tys = [symtab.get(n, "")
                                   for n in self._operand_names(rest)
                                   if n in symtab]
                    total.bytes += (self._fusion_result_bytes(callees[0], ty)
                                    + self._fusion_param_bytes(callees[0],
                                                               operand_tys))
                else:
                    total.bytes += _shape_bytes(ty) + self._operand_bytes(rest, symtab)
                continue
            base_kind = op[:-6] if op.endswith("-start") else op
            if base_kind in _COLL_KINDS:
                if op.endswith("-done"):
                    continue
                size = _shape_bytes(ty)
                if op.endswith("-start") and ty.startswith("("):
                    size /= 2.0     # tuple aliases (operand, result)
                g = self._group_size(line)
                k = base_kind
                total.coll_count[k] = total.coll_count.get(k, 0) + 1
                total.coll_bytes[k] = total.coll_bytes.get(k, 0.0) + size
                if k == "all-reduce":
                    wire = 2.0 * (g - 1) / max(g, 1) * size
                elif k == "collective-permute":
                    wire = size
                else:
                    wire = (g - 1) / max(g, 1) * size
                total.coll_wire_bytes[k] = total.coll_wire_bytes.get(k, 0.0) + wire
                total.bytes += _shape_bytes(ty) + self._operand_bytes(rest, symtab)
                continue
            if op == "dot":
                total.flops += self._dot_flops(ty, rest, symtab)
            elif op == "convolution":
                # rare here; approximate as dot over the window
                total.flops += 2.0 * _shape_elems(ty)
            elif op in ("parameter", "constant", "get-tuple-element", "tuple",
                        "bitcast", "after-all", "copy"):
                continue
            if op in self._SLICING_OPS:
                # read the slice, write the slice (+ tiny index operands)
                total.bytes += 2.0 * _shape_bytes(ty)
                continue
            if op in ("dynamic-update-slice", "scatter"):
                # read+write only the updated region (operand 1 = update)
                names = self._operand_names(rest)
                upd = symtab.get(names[1], "") if len(names) > 1 else ""
                total.bytes += 2.0 * _shape_bytes(upd)
                if op == "scatter":
                    for callee in self._called(rest):
                        total.add(self.cost(callee, _memo))
                continue
            # op-level bytes: result + operands (same proxy as XLA cost model)
            total.bytes += _shape_bytes(ty) + self._operand_bytes(rest, symtab)
        return total


def analyze_hlo_text(text: str) -> Cost:
    return HloModule(text).cost()
