"""Seeded fleet workload generators: arrivals, lengths, tenants.

A fleet trace is a list of :class:`FleetRequest` — arrival time, tenant,
prompt/shared-prefix/output lengths — plus the metadata needed to
re-materialize the exact token streams.  Everything is driven by a
``numpy`` Generator seeded from one integer, so a (kind, seed, knobs)
tuple names a reproducible workload for benchmarks and tests.

Arrival processes
-----------------
- ``poisson``: homogeneous Poisson at ``rate`` req/s (exponential gaps).
- ``diurnal``: nonhomogeneous Poisson with a sinusoidal rate profile
  (``peak_to_trough`` ratio over ``period_s``), sampled by thinning
  against the peak rate.
- ``mmpp``: 2-state Markov-modulated Poisson process — dwell times are
  exponential, the high state fires ``burst_ratio`` times faster than
  the low state.  This is the "bursty" workload: long quiet stretches
  punctuated by arrival storms, the adversarial case for admission
  control and preemption.

Tenants and shared prefixes
---------------------------
Requests are tagged with a tenant drawn from a Zipf-like categorical
mix.  Every tenant owns a deterministic shared prefix (its "system
prompt") of ``prefix_len`` tokens; a request's prompt is that prefix
followed by unique tokens.  Routers that concentrate a tenant's traffic
on one replica turn the prefix into KV-cache hits — the workload the
prefix-affinity router is measured on.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.runtime.kv_cache import _chain_key

ARRIVAL_KINDS = ("poisson", "diurnal", "mmpp")


@dataclasses.dataclass(frozen=True)
class FleetRequest:
    """One request in a fleet trace (lengths in tokens, times in s)."""
    rid: int
    arrival: float
    tenant: int
    prompt_len: int        # total prompt tokens, including the prefix
    prefix_len: int        # leading tokens shared with the whole tenant
    output_len: int        # tokens to generate


@dataclasses.dataclass(frozen=True)
class LengthMix:
    """Clipped-lognormal prompt/output length distributions."""
    prompt_mean: float = 96.0      # mean of the clipped distribution, approx
    prompt_sigma: float = 0.5      # lognormal shape (log-space std)
    prompt_min: int = 8
    prompt_max: int = 192
    output_mean: float = 24.0
    output_sigma: float = 0.5
    output_min: int = 2
    output_max: int = 64

    def sample(self, rng: np.random.Generator, mean: float, sigma: float,
               lo: int, hi: int, n: int) -> np.ndarray:
        mu = math.log(mean) - 0.5 * sigma ** 2   # lognormal with that mean
        v = rng.lognormal(mu, sigma, size=n)
        return np.clip(np.round(v), lo, hi).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class TenantMix:
    """Zipf-weighted tenants, each owning a shared ``prefix_len`` prompt."""
    n_tenants: int = 8
    zipf_s: float = 1.0            # 0 = uniform, larger = more skewed
    prefix_len: int = 48           # shared leading tokens per tenant

    def weights(self) -> np.ndarray:
        w = 1.0 / np.arange(1, self.n_tenants + 1) ** self.zipf_s
        return w / w.sum()


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------


def poisson_arrivals(rng: np.random.Generator, n: int,
                     rate: float) -> np.ndarray:
    """n homogeneous-Poisson arrival times at ``rate`` req/s."""
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def diurnal_arrivals(rng: np.random.Generator, n: int, mean_rate: float, *,
                     peak_to_trough: float = 4.0,
                     period_s: float = 60.0) -> np.ndarray:
    """Nonhomogeneous Poisson with a sinusoidal day/night profile.

    rate(t) = mean_rate * (1 + beta * sin(2 pi t / period)) where beta is
    set so peak/trough == ``peak_to_trough``.  Sampled by thinning against
    the peak rate, so the output is an exact draw from the process.
    """
    p = float(peak_to_trough)
    beta = (p - 1.0) / (p + 1.0)
    lam_max = mean_rate * (1.0 + beta)
    out = np.empty(n)
    t, i = 0.0, 0
    while i < n:
        t += rng.exponential(1.0 / lam_max)
        lam = mean_rate * (1.0 + beta * math.sin(2 * math.pi * t / period_s))
        if rng.random() * lam_max <= lam:
            out[i] = t
            i += 1
    return out


def mmpp_arrivals(rng: np.random.Generator, n: int, mean_rate: float, *,
                  burst_ratio: float = 8.0, burst_fraction: float = 0.2,
                  mean_dwell_s: float = 2.0) -> np.ndarray:
    """2-state MMPP: quiet vs burst, exponential dwell in each state.

    ``burst_fraction`` of wall time is spent in the burst state, whose
    rate is ``burst_ratio`` x the quiet rate; rates are normalized so the
    long-run mean is ``mean_rate``.
    """
    f, r = float(burst_fraction), float(burst_ratio)
    quiet = mean_rate / ((1.0 - f) + f * r)
    rates = (quiet, quiet * r)
    dwells = (mean_dwell_s * (1.0 - f) * 2.0, mean_dwell_s * f * 2.0)
    out = np.empty(n)
    t, i, state = 0.0, 0, 0
    next_switch = rng.exponential(dwells[0])
    while i < n:
        gap = rng.exponential(1.0 / rates[state])
        if t + gap >= next_switch:
            t = next_switch
            state ^= 1
            next_switch = t + rng.exponential(dwells[state])
            continue
        t += gap
        out[i] = t
        i += 1
    return out


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Trace:
    """A materializable fleet workload."""
    requests: list[FleetRequest]
    kind: str
    seed: int
    vocab: int
    lengths: LengthMix
    tenants: TenantMix
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.requests[-1].arrival if self.requests else 0.0

    @property
    def total_output_tokens(self) -> int:
        return sum(r.output_len for r in self.requests)

    def mean_rate(self) -> float:
        return len(self.requests) / max(self.duration, 1e-9)


def make_trace(n: int, seed: int, *, kind: str = "poisson",
               rate: float = 32.0, vocab: int = 2048,
               lengths: LengthMix | None = None,
               tenants: TenantMix | None = None, **arrival_kw) -> Trace:
    """Generate ``n`` requests with ``kind`` arrivals (seeded, exact)."""
    if kind not in ARRIVAL_KINDS:
        raise ValueError(f"unknown arrival kind {kind!r}; "
                         f"know {ARRIVAL_KINDS}")
    lengths = lengths or LengthMix()
    tenants = tenants or TenantMix()
    rng = np.random.default_rng(seed)
    if kind == "poisson":
        arr = poisson_arrivals(rng, n, rate)
    elif kind == "diurnal":
        arr = diurnal_arrivals(rng, n, rate, **arrival_kw)
    else:
        arr = mmpp_arrivals(rng, n, rate, **arrival_kw)
    tid = rng.choice(tenants.n_tenants, size=n, p=tenants.weights())
    plen = lengths.sample(rng, lengths.prompt_mean, lengths.prompt_sigma,
                          lengths.prompt_min, lengths.prompt_max, n)
    olen = lengths.sample(rng, lengths.output_mean, lengths.output_sigma,
                          lengths.output_min, lengths.output_max, n)
    # the shared prefix must leave at least one unique trailing token
    plen = np.maximum(plen, tenants.prefix_len + 1)
    reqs = [FleetRequest(rid=i, arrival=float(arr[i]), tenant=int(tid[i]),
                         prompt_len=int(plen[i]),
                         prefix_len=int(tenants.prefix_len),
                         output_len=int(olen[i]))
            for i in range(n)]
    return Trace(requests=reqs, kind=kind, seed=seed, vocab=vocab,
                 lengths=lengths, tenants=tenants, meta=dict(arrival_kw))


def tenant_prefix_tokens(trace: Trace, tenant: int) -> np.ndarray:
    """The tenant's deterministic shared prefix (its "system prompt")."""
    rng = np.random.default_rng((trace.seed, 0x7e4a, tenant))
    return rng.integers(0, trace.vocab, size=trace.tenants.prefix_len,
                        dtype=np.int64).astype(np.int32)


def materialize_prompt(trace: Trace, req: FleetRequest) -> np.ndarray:
    """Token ids for one request: tenant prefix + unique tail (seeded)."""
    prefix = tenant_prefix_tokens(trace, req.tenant)[:req.prefix_len]
    rng = np.random.default_rng((trace.seed, 0x51ab, req.rid))
    tail = rng.integers(0, trace.vocab, size=req.prompt_len - req.prefix_len,
                        dtype=np.int64).astype(np.int32)
    return np.concatenate([prefix, tail])


def prefix_chain(tokens: np.ndarray, page_size: int) -> tuple[bytes, ...]:
    """Chained block hashes of a prompt, one per *full* block.

    The same position-dependent chain the paged KV cache indexes shared
    prefixes by (``runtime.kv_cache._chain_key``), over at most
    ``len(tokens) - 1`` tokens — the cache never shares the final prompt
    token (its K/V depends on the first sampled position).
    """
    shareable = (max(len(tokens) - 1, 0)) // page_size
    chain, prev = [], b""
    for b in range(shareable):
        prev = _chain_key(prev, tokens[b * page_size:(b + 1) * page_size])
        chain.append(prev)
    return tuple(chain)


def tenant_chains(trace: Trace, page_size: int) -> dict[int, tuple[bytes, ...]]:
    """Per-tenant block-hash chains of the shared prefixes (cheap: one
    chain per tenant, not per request)."""
    out = {}
    for t in range(trace.tenants.n_tenants):
        toks = tenant_prefix_tokens(trace, t)
        # full blocks of the prefix only — the tail diverges per request
        n_blocks = trace.tenants.prefix_len // page_size
        chain, prev = [], b""
        for b in range(n_blocks):
            prev = _chain_key(prev, toks[b * page_size:(b + 1) * page_size])
            chain.append(prev)
        out[t] = tuple(chain)
    return out
