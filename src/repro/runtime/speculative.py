"""Speculative decoding (paper §X "Comparison Under Speculative Decoding").

Draft/target scheme with the stochastic acceptance rule of Leviathan et al.
[37]: the draft proposes a lookahead window of ``gamma`` tokens; the target
scores them; token i is accepted with prob min(1, p_t(x_i)/p_d(x_i)); on
the first rejection we resample from max(p_t - p_d, 0) normalized.  The
paper's evaluation uses gamma=8 with a Llama3-8B draft for a Llama3-70B
target, accepting 4.6 tokens per window on average for a 1.8x speedup —
``benchmarks/spec_decode.py`` reproduces that comparison on the RPU
simulator, while this module is the executable runtime mechanism.

Batch size 1 (the paper's "fastest thinking speed" regime).  Cache rewind
relies on the slot_pos-masked KV caches: entries written for rejected
positions carry slot_pos > cur_pos so they are masked out and later
overwritten — no explicit rollback pass is needed.  SSM-state models
cannot rewind state and are rejected (the paper's draft/target pairs are
attention-based).

Draft proposals and target verification both go through the SHARED
``sampling.dist`` / ``sampling.draw`` helpers with the request's
``SamplingParams``: the draft draws from exactly the distribution recorded
as q, and the target scores with the same temperature / top-k / top-p /
min-p filtering — so the acceptance ratio p/q (and the acceptance-rate
stats built on it) stays correct under per-request sampling parameters.
At temperature 0 both distributions are exact one-hots, keeping greedy
speculation lossless.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.runtime import sampling
from repro.runtime.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    """Scheduler-integrated speculation settings for the continuous engine
    (``LLMEngine(..., speculative=SpeculativeConfig(...))``).

    draft_model / draft_params: the proposer.  The draft's KV pages come
    out of the SAME ``PageAllocator`` page-id space as the target's —
    its pool pytree is a second set of leaves over identical page
    tables, so sharing, copy-on-write, preemption, and defrag act on
    both in lockstep.  ``None`` self-drafts with the target (useful for
    tests: acceptance is then ~1 and outputs are trivially identical).

    gamma: draft lookahead per window; each window costs gamma draft
    steps + 1 multi-token verify step and emits 1..gamma+1 tokens.
    """
    draft_model: Model | None = None
    draft_params: object = None
    gamma: int = 4

    def __post_init__(self):
        if self.gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {self.gamma}")
        if self.draft_model is not None:
            _check_rewindable(self.draft_model)


@dataclasses.dataclass
class SpecStats:
    tokens: jnp.ndarray            # (n,) generated tokens
    accepted_per_window: jnp.ndarray
    windows: int

    @property
    def mean_accepted(self) -> float:
        return float(jnp.mean(self.accepted_per_window))


def _check_rewindable(model: Model):
    if model.cfg.family in ("ssm", "hybrid"):
        raise ValueError("speculative decoding requires rewindable caches; "
                         f"{model.cfg.name} carries SSM state")


def make_speculative_window(draft: Model, target: Model, *, gamma: int = 8,
                            temperature: float = 1.0,
                            sampling_params: SamplingParams | None = None):
    """Build the jitted draft-propose / target-verify window (batch=1).

    window(dparams, tparams, last_token (1,), dcache, tcache, pos, key)
      -> (tokens (gamma+1,), n_emitted, dcache, tcache, new_pos)
    Entries past n_emitted are padding and must be ignored.
    """
    sp = (sampling_params if sampling_params is not None
          else SamplingParams(temperature=temperature))

    def window(dparams, tparams, last_token, dcache, tcache, pos, key):
        kd, kr = jax.random.split(key, 2)

        # --- draft proposes gamma tokens; each draw comes from the SAME
        # filtered distribution recorded as q (sampling.dist/draw), so the
        # acceptance ratio sees the true proposal distribution
        def d_step(carry, k):
            tok, cache, p = carry
            logits, cache = draft.decode_step(dparams, tok, cache, p)
            dist = sampling.dist(logits, sp)[0]                   # (V,)
            nxt = sampling.draw(k, dist[None])
            return (nxt, cache, p + 1), (nxt[0], dist)

        (_, dcache, _), (prop, q_dist) = jax.lax.scan(
            d_step, (last_token, dcache, pos), jax.random.split(kd, gamma))

        # fill the draft cache for prop[gamma-1] (position pos+gamma): on a
        # full accept the next window's draft must see the whole history —
        # without this the draft attends over a hole and diverges from the
        # target even when the models are identical.
        _, dcache = draft.decode_step(dparams, prop[-1][None], dcache,
                                      pos + gamma)

        # --- target scores all gamma proposals PLUS the bonus position:
        # t_inputs[i] consumes token i-1, so p_dist[i] is the target's
        # distribution for window position i; row gamma is the bonus
        # distribution after a full accept (keeps the scheme lossless).
        # Rejected positions' cache writes are masked/overwritten via
        # slot_pos (see module docstring).
        t_inputs = jnp.concatenate([last_token, prop])

        def t_step(carry, tok):
            cache, p = carry
            logits, cache = target.decode_step(tparams, tok[None], cache, p)
            return (cache, p + 1), sampling.dist(logits, sp)[0]

        (tcache, _), p_dist = jax.lax.scan(t_step, (tcache, pos), t_inputs)

        idx = jnp.arange(gamma)
        p_prop = p_dist[idx, prop]
        q_prop = q_dist[idx, prop]

        # --- stochastic acceptance: accept while u < p/q
        u = jax.random.uniform(kr, (gamma,))
        accept = u < jnp.minimum(1.0, p_prop / jnp.maximum(q_prop, 1e-20))
        rej = jnp.argmax(~accept)
        n_acc = jnp.where(jnp.any(~accept), rej, gamma)

        # --- correction token: residual max(p-q, 0) at the first rejection;
        # the true bonus-position target sample on a full accept.
        q_pad = jnp.concatenate([q_dist, jnp.zeros_like(q_dist[:1])])
        resid = jnp.maximum(p_dist[n_acc] - q_pad[n_acc], 0.0)
        resid_ok = jnp.sum(resid) > 1e-20
        full_accept = n_acc == gamma
        corr_dist = jnp.where(full_accept | ~resid_ok, p_dist[n_acc], resid)
        corrected = sampling.draw(jax.random.fold_in(kr, 1),
                                  corr_dist / jnp.sum(corr_dist))

        tokens = jnp.where(idx < n_acc, prop, 0)
        tokens = jnp.concatenate([tokens, jnp.zeros((1,), jnp.int32)])
        tokens = tokens.at[n_acc].set(corrected)
        n_emitted = n_acc + 1
        return tokens, n_emitted, dcache, tcache, pos + n_emitted

    return jax.jit(window)


class SpeculativeEngine:
    """Draft/target speculative decoding with cached compilations.

    ``speculative_generate`` builds fresh jit objects (two prefills + the
    window) on every call, so serving N prompts re-traces everything N
    times.  This engine owns the jitted prefills and a window cache keyed
    by the ``SamplingParams`` fields the window actually bakes in
    (temperature / top-k / top-p / min-p — seed and stop conditions are
    data), so repeated prompts reuse the compiled program; only a NEW
    filtering configuration (or a new prompt-length shape, handled by jit's
    own shape cache) traces again.  ``LLMEngine(backend="speculative")``
    holds one instance for its lifetime.
    """

    def __init__(self, draft: Model, dparams, target: Model, tparams, *,
                 gamma: int = 8):
        _check_rewindable(draft)
        _check_rewindable(target)
        self.draft, self.dparams = draft, dparams
        self.target, self.tparams = target, tparams
        self.gamma = gamma
        self._prefill_d = jax.jit(draft.prefill)
        self._prefill_t = jax.jit(target.prefill)
        self._windows: dict[tuple, Callable] = {}

    def _window_for(self, sp: SamplingParams):
        key = (sp.temperature, sp.top_k, sp.top_p, sp.min_p)
        win = self._windows.get(key)
        if win is None:
            win = make_speculative_window(self.draft, self.target,
                                          gamma=self.gamma,
                                          sampling_params=sp)
            self._windows[key] = win
        return win

    def generate(self, prompt: jnp.ndarray, *, max_new_tokens: int,
                 sampling_params: SamplingParams | None = None,
                 max_len: int | None = None, key=None) -> SpecStats:
        """Generate ``max_new_tokens`` tokens for a (1, S) prompt."""
        sp = sampling_params if sampling_params is not None \
            else SamplingParams(temperature=1.0)
        key = key if key is not None else jax.random.PRNGKey(sp.seed)
        s = prompt.shape[1]
        max_len = max_len or (s + max_new_tokens + self.gamma + 2)

        dcache = self.draft.init_cache(1, max_len)
        tcache = self.target.init_cache(1, max_len)
        _, dcache = self._prefill_d(self.dparams, {"tokens": prompt}, dcache)
        tlogits, tcache = self._prefill_t(self.tparams, {"tokens": prompt},
                                          tcache)

        key, k0 = jax.random.split(key)
        last = sampling.draw(k0, sampling.dist(tlogits, sp))   # (1,)
        pos = jnp.int32(s)
        window = self._window_for(sp)

        out = [int(last[0])]
        accepted = []
        windows = 0
        while len(out) < max_new_tokens + 1:
            key, kw = jax.random.split(key)
            tokens, n_emit, dcache, tcache, pos = window(
                self.dparams, self.tparams, last, dcache, tcache, pos, kw)
            n = int(n_emit)
            out.extend(int(t) for t in tokens[:n])
            accepted.append(n - 1)
            last = tokens[n - 1][None]
            windows += 1
        return SpecStats(tokens=jnp.asarray(out[:max_new_tokens + 1]),
                         accepted_per_window=jnp.asarray(accepted,
                                                         jnp.float32),
                         windows=windows)


def speculative_generate(draft: Model, dparams, target: Model, tparams,
                         prompt: jnp.ndarray, *, max_new_tokens: int,
                         gamma: int = 8, temperature: float = 1.0,
                         sampling_params: SamplingParams | None = None,
                         max_len: int | None = None,
                         key=None) -> SpecStats:
    """One-shot wrapper: a throwaway ``SpeculativeEngine``.  Callers doing
    repeated generation should hold an engine (or ``LLMEngine``) instead."""
    sp = (sampling_params if sampling_params is not None
          else SamplingParams(temperature=temperature))
    eng = SpeculativeEngine(draft, dparams, target, tparams, gamma=gamma)
    return eng.generate(prompt, max_new_tokens=max_new_tokens,
                        sampling_params=sp, max_len=max_len, key=key)
