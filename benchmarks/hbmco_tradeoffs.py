"""Paper Fig 4 (Goldilocks BW/Cap landscape) + Fig 5 (HBM-CO tradeoffs)."""
from __future__ import annotations

from benchmarks.common import Row
from repro.core.hbmco import (CANDIDATE_CO, HBM3E_LIKE,
                              enumerate_design_space, pareto_frontier)


def run() -> list[Row]:
    rows = [
        Row("Fig5", "HBM3e-like energy", HBM3E_LIKE.energy_pj_per_bit, 3.44,
            " pJ/b", "calibration target"),
        Row("Fig5", "candidate (768MB/256GBps) energy",
            CANDIDATE_CO.energy_pj_per_bit, 1.45, " pJ/b"),
        Row("Fig5", "candidate BW/Cap", CANDIDATE_CO.bw_per_cap, 341, ""),
        Row("Fig5", "energy ratio HBM3e/candidate",
            HBM3E_LIKE.energy_pj_per_bit / CANDIDATE_CO.energy_pj_per_bit,
            2.4, "x"),
        Row("Fig5", "cost/GB ratio candidate/HBM3e",
            CANDIDATE_CO.cost_per_gb / HBM3E_LIKE.cost_per_gb, 1.81, "x"),
        Row("Fig5", "module cost ratio HBM3e/candidate",
            HBM3E_LIKE.module_cost / CANDIDATE_CO.module_cost, 35, "x"),
        Row("Fig5", "bandwidth-per-dollar ratio",
            CANDIDATE_CO.bandwidth_per_cost / HBM3E_LIKE.bandwidth_per_cost,
            5.0, "x", ">= paper"),
        Row("Fig4", "candidate ideal token latency",
            CANDIDATE_CO.ideal_token_latency_s * 1e3, 2.9, " ms",
            "Goldilocks range"),
        Row("Fig4", "HBM3e capacity utilization at candidate perf",
            CANDIDATE_CO.bw_per_cap and
            100.0 * HBM3E_LIKE.bw_per_cap / CANDIDATE_CO.bw_per_cap, 7.9,
            " %", "overprovisioning paradox"),
    ]
    space = enumerate_design_space()
    frontier = pareto_frontier(space)
    rows.append(Row("Fig5", "design points enumerated", len(space)))
    rows.append(Row("Fig9", "Pareto-frontier SKUs (256GB/s class)",
                    len(frontier), None, "",
                    " | ".join(f"{c.capacity_mb:.0f}MB@{c.energy_pj_per_bit:.2f}pJ"
                               for c in frontier)))
    return rows
