"""Fleet serving: traffic generation, routing, simulation, autoscaling.

The system-level layer over ``DeploymentSpec`` replicas — seeded
workload generators (`traffic`), a prefix-affinity SLO router
(`router`), a calibrated discrete-event fleet simulator (`simulator`),
and traffic-envelope SKU/replica planning (`autoscaler`).
"""
from repro.fleet.router import SLO, PrefixAffinityRouter, RoundRobinRouter
from repro.fleet.simulator import (DisaggFleetSimulator, FleetSimulator,
                                   FleetStats, LatencyTable, ReplicaSpec,
                                   calibrate, cross_check,
                                   disagg_replica_specs)
from repro.fleet.autoscaler import (DisaggFleetPlan, FleetPlan,
                                    ReactiveAutoscaler, TrafficEnvelope,
                                    default_candidates, plan_disagg_fleet,
                                    plan_fleet)
from repro.fleet.traffic import (FleetRequest, LengthMix, TenantMix, Trace,
                                 make_trace)

__all__ = [
    "SLO", "PrefixAffinityRouter", "RoundRobinRouter",
    "DisaggFleetSimulator", "FleetSimulator", "FleetStats", "LatencyTable",
    "ReplicaSpec", "calibrate", "cross_check", "disagg_replica_specs",
    "DisaggFleetPlan", "FleetPlan", "ReactiveAutoscaler", "TrafficEnvelope",
    "default_candidates", "plan_disagg_fleet", "plan_fleet",
    "FleetRequest", "LengthMix", "TenantMix", "Trace", "make_trace",
]
