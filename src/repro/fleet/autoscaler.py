"""Fleet planning: SKU + replica count from a traffic envelope.

Closes the loop from the paper's provisioning analysis to a running
system: a :class:`TrafficEnvelope` (peak/mean arrival rate, length mix)
is turned into candidate ``DeploymentSpec``s — RPU CUs with the HBM-CO
stack chosen from the Fig-10 Pareto frontier (``core.hbmco``), plus
named GPU SKUs — each resolved into per-replica throughput via
``DeploymentSpec.resolve`` and priced with the §IV provisioning models
(``core.provisioning``): TDP per replica, die-mm² per provisioned GB/s,
joules per token.  :func:`plan_fleet` picks the cheapest feasible
(SKU, replica count) under the SLO; :class:`ReactiveAutoscaler` is the
closed-loop variant the simulator polls mid-run.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import hardware, provisioning
from repro.core.hbmco import CANDIDATE_CO, enumerate_design_space, \
    hbmco_by_name, pareto_frontier, select_sku
from repro.core.sku import WorkloadFootprint
from repro.fleet.router import SLO
from repro.fleet import traffic as tr
from repro.runtime.deployment import CHIP_SKUS, DeploymentError, \
    DeploymentSpec


# ---------------------------------------------------------------------------
# traffic envelope
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficEnvelope:
    """What the fleet must absorb: rates in req/s, lengths in tokens."""
    peak_rate: float
    mean_rate: float
    mean_prompt: float
    mean_output: float

    @classmethod
    def from_trace(cls, trace: tr.Trace,
                   window_s: float = 10.0) -> "TrafficEnvelope":
        """Peak = max windowed arrival rate over the trace."""
        arr = np.asarray([r.arrival for r in trace.requests])
        if arr.size == 0:
            raise ValueError("empty trace")
        duration = max(float(arr[-1]), 1e-9)
        # a window longer than the trace would report peak < mean
        w = min(window_s, max(duration / 4.0, 1e-6))
        nbins = max(int(math.ceil(duration / w)), 1)
        counts, _ = np.histogram(arr, bins=nbins, range=(0.0, nbins * w))
        return cls(
            peak_rate=float(counts.max()) / w,
            mean_rate=trace.mean_rate(),
            mean_prompt=float(np.mean([r.prompt_len
                                       for r in trace.requests])),
            mean_output=float(np.mean([r.output_len
                                       for r in trace.requests])))

    @property
    def peak_decode_tokens_per_s(self) -> float:
        return self.peak_rate * self.mean_output


# ---------------------------------------------------------------------------
# per-replica cost models (paper §IV provisioning)
# ---------------------------------------------------------------------------


def _resolve_hbm(spec: DeploymentSpec):
    hbm = spec.hbmco
    if isinstance(hbm, str):
        hbm = hbmco_by_name(hbm)
    return hbm or CANDIDATE_CO


def replica_power_w(spec: DeploymentSpec, tp: int = 1) -> float:
    """Modeled TDP of one replica (``tp`` devices).

    RPU CUs get the §IV per-CU TDP (full memory stream over the stack's
    pJ/bit, divided by the memory power fraction); named chips use their
    data-sheet TDP.
    """
    if isinstance(spec.sku, str) and spec.sku == "rpu-cu":
        return provisioning.cu_tdp_w(_resolve_hbm(spec)) * tp
    chip = spec.sku if isinstance(spec.sku, hardware.ChipSpec) \
        else CHIP_SKUS[spec.sku]
    return chip.tdp_w * tp


def replica_die_mm2(spec: DeploymentSpec, tp: int = 1) -> float:
    """Die-area cost proxy per replica: mm² per provisioned GB/s at the
    SKU's compute-to-bandwidth provisioning point (the §IX 3.3x lever)."""
    if isinstance(spec.sku, str) and spec.sku == "rpu-cu":
        gbs = hardware.RPU_DEFAULT.cu_mem_bw / 1e9
        return provisioning.RPU_POINT.die_mm2_per_gbs() * gbs * tp
    chip = spec.sku if isinstance(spec.sku, hardware.ChipSpec) \
        else CHIP_SKUS[spec.sku]
    return provisioning.GPU_LIKE.die_mm2_per_gbs() * (chip.hbm_bw / 1e9) * tp


# ---------------------------------------------------------------------------
# candidates
# ---------------------------------------------------------------------------


def rpu_candidates(model, base: DeploymentSpec, *,
                   stacks=(2, 4, 8)) -> list[DeploymentSpec]:
    """RPU-CU candidates with the HBM-CO stack picked from the Pareto
    frontier for the model's footprint (the Fig-10 selection rule: the
    highest-BW/Cap stack whose capacity still fits weights + KV)."""
    wl = WorkloadFootprint.from_model(model, weight_format=base.weight_format,
                                      cache_dtype=base.cache_dtype)
    frontier = pareto_frontier(enumerate_design_space())
    out = []
    for n in stacks:
        # per-stack capacity the workload needs at a full slot set,
        # with workspace headroom mirroring resolve()'s budget split
        need = wl.capacity_bytes(base.max_slots, base.max_len) \
            / (n * (1.0 - base.workspace_fraction))
        sku = select_sku(need, frontier)
        if sku is None:
            continue
        out.append(dataclasses.replace(base, sku="rpu-cu", hbmco=sku,
                                       stacks_per_device=n))
    return out


def default_candidates(model, base: DeploymentSpec | None = None,
                       **kw) -> list[DeploymentSpec]:
    """RPU stacks off the frontier + the named GPU SKUs."""
    base = base or DeploymentSpec(**kw)
    cands = rpu_candidates(model, base)
    for name in ("h100", "h200"):
        cands.append(dataclasses.replace(base, sku=name, hbmco=None,
                                         stacks_per_device=2))
    return cands


# ---------------------------------------------------------------------------
# static planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetPlan:
    """One (SKU, replica-count) point, priced and SLO-checked."""
    spec: DeploymentSpec
    resolved: object | None
    replicas: int
    feasible: bool
    reason: str = ""
    per_replica_tokens_per_s: float = 0.0
    fleet_tokens_per_s: float = 0.0
    ttft_est_s: float = 0.0
    tpot_est_s: float = 0.0
    power_w: float = 0.0              # whole fleet
    die_mm2: float = 0.0              # whole fleet
    energy_j_per_token: float = 0.0   # TDP / per-replica throughput

    @property
    def name(self) -> str:
        if self.resolved is not None:
            return self.resolved.device.name
        return str(self.spec.sku)

    def as_dict(self) -> dict:
        return {"sku": self.name, "replicas": self.replicas,
                "feasible": self.feasible, "reason": self.reason,
                "per_replica_tokens_per_s":
                    round(self.per_replica_tokens_per_s, 2),
                "fleet_tokens_per_s": round(self.fleet_tokens_per_s, 2),
                "ttft_est_s": round(self.ttft_est_s, 4),
                "tpot_est_s": round(self.tpot_est_s, 5),
                "power_w": round(self.power_w, 1),
                "die_mm2": round(self.die_mm2, 1),
                "energy_j_per_token": round(self.energy_j_per_token, 6)}


def plan_candidate(model, spec: DeploymentSpec, envelope: TrafficEnvelope,
                   slo: SLO, *, headroom: float = 1.25) -> FleetPlan:
    try:
        r = spec.resolve(model)
    except (DeploymentError, NotImplementedError) as e:
        return FleetPlan(spec=spec, resolved=None, replicas=0,
                         feasible=False, reason=str(e))
    # prefill chunks interleave with decode iterations: a fresh prompt
    # waits ~one decode step per chunk on top of its own chunk compute
    chunks = math.ceil(envelope.mean_prompt / r.prefill_chunk)
    ttft_est = 2.0 * chunks * r.step_seconds
    tpot_est = r.step_seconds
    feasible, reason = True, ""
    if tpot_est > slo.tpot_s:
        feasible, reason = False, (f"modeled TPOT {tpot_est:.4f}s exceeds "
                                   f"SLO {slo.tpot_s}s")
    elif ttft_est > slo.ttft_s:
        feasible, reason = False, (f"modeled TTFT {ttft_est:.3f}s exceeds "
                                   f"SLO {slo.ttft_s}s")
    demand = envelope.peak_decode_tokens_per_s * headroom
    per = r.tokens_per_s_ceiling
    n = max(1, math.ceil(demand / per))
    power = replica_power_w(spec, r.tp)
    return FleetPlan(
        spec=spec, resolved=r, replicas=n, feasible=feasible, reason=reason,
        per_replica_tokens_per_s=per, fleet_tokens_per_s=per * n,
        ttft_est_s=ttft_est, tpot_est_s=tpot_est,
        power_w=power * n, die_mm2=replica_die_mm2(spec, r.tp) * n,
        energy_j_per_token=power / per)


def plan_fleet(model, envelope: TrafficEnvelope, slo: SLO,
               candidates: list[DeploymentSpec], *, headroom: float = 1.25,
               objective: str = "cost") -> tuple[FleetPlan, list[FleetPlan]]:
    """Price every candidate, return (best feasible, all plans).

    objective "cost" minimizes fleet die-mm² (power breaks ties);
    "energy" minimizes joules per token.
    """
    plans = [plan_candidate(model, c, envelope, slo, headroom=headroom)
             for c in candidates]
    feasible = [p for p in plans if p.feasible]
    if not feasible:
        raise DeploymentError(
            "no candidate meets the SLO: "
            + "; ".join(f"{p.name}: {p.reason}" for p in plans))
    if objective == "energy":
        key = lambda p: (p.energy_j_per_token, p.die_mm2)
    else:
        key = lambda p: (p.die_mm2, p.power_w)
    best = min(feasible, key=key)
    return best, plans


# ---------------------------------------------------------------------------
# disaggregated planning: phase-specialized SKUs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DisaggFleetPlan:
    """One (prefill SKU, decode SKU) pairing, priced per phase.

    Prefill replicas are sized against peak **prompt** tokens/s on the
    prefill-phase resolve (compute ceiling); decode replicas against
    peak **decode** tokens/s on the decode-phase resolve (bandwidth
    ceiling).  TTFT is chunk compute plus the KV handoff; TPOT is a pure
    decode step — no chunk interleave, which is the modeled win over
    ``plan_candidate``'s colocated ``2.0 * chunks`` interference term.
    """
    prefill: FleetPlan
    decode: FleetPlan
    feasible: bool
    reason: str = ""
    ttft_est_s: float = 0.0
    tpot_est_s: float = 0.0
    handoff_s: float = 0.0
    prompt_demand_tokens_per_s: float = 0.0
    decode_demand_tokens_per_s: float = 0.0

    @property
    def name(self) -> str:
        return f"{self.prefill.name} + {self.decode.name}"

    @property
    def power_w(self) -> float:
        return self.prefill.power_w + self.decode.power_w

    @property
    def die_mm2(self) -> float:
        return self.prefill.die_mm2 + self.decode.die_mm2

    @property
    def energy_j_per_token(self) -> float:
        """Joules per output token at the demand point: each tier burns
        TDP times its utilization (its demand over its fleet ceiling),
        charged to the decode-output stream.  For a colocated plan the
        same convention collapses to ``plan_candidate``'s
        ``power / per_replica_tokens_per_s``, so the numbers compare."""
        util_p = min(self.prompt_demand_tokens_per_s
                     / max(self.prefill.fleet_tokens_per_s, 1e-9), 1.0)
        util_d = min(self.decode_demand_tokens_per_s
                     / max(self.decode.fleet_tokens_per_s, 1e-9), 1.0)
        burn = self.prefill.power_w * util_p + self.decode.power_w * util_d
        return burn / max(self.decode_demand_tokens_per_s, 1e-9)

    def as_dict(self) -> dict:
        return {"prefill_sku": self.prefill.name,
                "decode_sku": self.decode.name,
                "prefill_replicas": self.prefill.replicas,
                "decode_replicas": self.decode.replicas,
                "feasible": self.feasible, "reason": self.reason,
                "ttft_est_s": round(self.ttft_est_s, 4),
                "tpot_est_s": round(self.tpot_est_s, 5),
                "handoff_s": round(self.handoff_s, 5),
                "power_w": round(self.power_w, 1),
                "die_mm2": round(self.die_mm2, 1),
                "energy_j_per_token": round(self.energy_j_per_token, 6)}


def plan_disagg_candidate(model, prefill_spec: DeploymentSpec,
                          decode_spec: DeploymentSpec,
                          envelope: TrafficEnvelope, slo: SLO, *,
                          headroom: float = 1.25,
                          handoff_gbs: float = 64.0) -> DisaggFleetPlan:
    def infeasible(reason, rp=None, rd=None):
        empty = lambda s, r: FleetPlan(spec=s, resolved=r, replicas=0,
                                       feasible=False, reason=reason)
        return DisaggFleetPlan(prefill=empty(prefill_spec, rp),
                               decode=empty(decode_spec, rd),
                               feasible=False, reason=reason)

    try:
        rp = prefill_spec.resolve(model, phase="prefill")
    except (DeploymentError, NotImplementedError) as e:
        return infeasible(f"prefill: {e}")
    try:
        rd = decode_spec.resolve(model, phase="decode")
    except (DeploymentError, NotImplementedError) as e:
        return infeasible(f"decode: {e}", rp)
    chunks = math.ceil(envelope.mean_prompt / rp.prefill_chunk)
    handoff_s = envelope.mean_prompt * rd.kv_token_bytes / (handoff_gbs * 1e9)
    ttft_est = chunks * rp.step_seconds + handoff_s
    tpot_est = rd.step_seconds
    feasible, reason = True, ""
    if tpot_est > slo.tpot_s:
        feasible, reason = False, (f"modeled TPOT {tpot_est:.4f}s exceeds "
                                   f"SLO {slo.tpot_s}s")
    elif ttft_est > slo.ttft_s:
        feasible, reason = False, (f"modeled TTFT {ttft_est:.3f}s exceeds "
                                   f"SLO {slo.ttft_s}s")
    prompt_demand = envelope.peak_rate * envelope.mean_prompt * headroom
    per_p = rp.tokens_per_s_ceiling
    n_p = max(1, math.ceil(prompt_demand / per_p))
    decode_demand = envelope.peak_decode_tokens_per_s * headroom
    per_d = rd.tokens_per_s_ceiling
    n_d = max(1, math.ceil(decode_demand / per_d))
    pw_p = replica_power_w(prefill_spec, rp.tp)
    pw_d = replica_power_w(decode_spec, rd.tp)
    pre = FleetPlan(
        spec=prefill_spec, resolved=rp, replicas=n_p, feasible=feasible,
        reason=reason, per_replica_tokens_per_s=per_p,
        fleet_tokens_per_s=per_p * n_p, ttft_est_s=ttft_est,
        power_w=pw_p * n_p, die_mm2=replica_die_mm2(prefill_spec, rp.tp) * n_p,
        energy_j_per_token=pw_p / per_p)
    dec = FleetPlan(
        spec=decode_spec, resolved=rd, replicas=n_d, feasible=feasible,
        reason=reason, per_replica_tokens_per_s=per_d,
        fleet_tokens_per_s=per_d * n_d, tpot_est_s=tpot_est,
        power_w=pw_d * n_d, die_mm2=replica_die_mm2(decode_spec, rd.tp) * n_d,
        energy_j_per_token=pw_d / per_d)
    return DisaggFleetPlan(prefill=pre, decode=dec, feasible=feasible,
                           reason=reason, ttft_est_s=ttft_est,
                           tpot_est_s=tpot_est, handoff_s=handoff_s,
                           prompt_demand_tokens_per_s=prompt_demand,
                           decode_demand_tokens_per_s=decode_demand)


def plan_disagg_fleet(model, envelope: TrafficEnvelope, slo: SLO,
                      prefill_candidates: list[DeploymentSpec],
                      decode_candidates: list[DeploymentSpec], *,
                      headroom: float = 1.25, handoff_gbs: float = 64.0,
                      objective: str = "cost"
                      ) -> tuple[DisaggFleetPlan, list[DisaggFleetPlan]]:
    """Cross the phase candidate lists, price each pairing, return
    (best feasible, all).  Pass ``default_candidates`` for both lists
    and the planner discovers the phase-specialized split itself —
    compute-dense SKUs win the prefill tier, bandwidth-dense HBM-CO
    stacks the decode tier.  Objectives match :func:`plan_fleet`.
    """
    plans = [plan_disagg_candidate(model, p, d, envelope, slo,
                                   headroom=headroom,
                                   handoff_gbs=handoff_gbs)
             for p in prefill_candidates for d in decode_candidates]
    feasible = [p for p in plans if p.feasible]
    if not feasible:
        raise DeploymentError(
            "no disaggregated pairing meets the SLO: "
            + "; ".join(f"{p.name}: {p.reason}" for p in plans[:8]))
    if objective == "energy":
        key = lambda p: (p.energy_j_per_token, p.die_mm2)
    else:
        key = lambda p: (p.die_mm2, p.power_w)
    best = min(feasible, key=key)
    return best, plans


# ---------------------------------------------------------------------------
# closed loop
# ---------------------------------------------------------------------------


class ReactiveAutoscaler:
    """Queue-pressure scaler the simulator polls every ``interval_s``.

    Scale up when mean queue depth per slot crosses ``high`` or requests
    were shed since the last tick; scale down when it falls under
    ``low``.  Changes are bounded to ``max_step`` replicas per tick so
    the loop stays stable under bursty arrivals.
    """

    def __init__(self, *, min_replicas: int = 1, max_replicas: int = 64,
                 interval_s: float = 1.0, low: float = 0.35,
                 high: float = 0.9, max_step: int = 2):
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.interval_s = interval_s
        self.low = low
        self.high = high
        self.max_step = max_step
        self.decisions: list[tuple[float, int]] = []
        self._last_shed = 0

    def desired(self, now: float, sim) -> int:
        active = [r for r in sim.replicas if not r.draining]
        n = len(active)
        load = float(np.mean([r.load() for r in active])) if active else 1e9
        shed = getattr(sim.router, "shed", 0)
        shed_delta = shed - self._last_shed
        self._last_shed = shed
        want = n
        if shed_delta > 0 or load > self.high:
            want = n + min(self.max_step,
                           max(1, math.ceil(n * (load - self.high))))
        elif load < self.low and n > self.min_replicas:
            want = n - 1
        want = int(np.clip(want, self.min_replicas, self.max_replicas))
        if want != n:
            self.decisions.append((now, want))
        return want
