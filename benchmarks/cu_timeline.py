"""Paper Fig 8: one-CU timeline, BS=1 vs BS=32, + §IX C3 ablations."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.compiler import CompileOptions, compile_decode_step
from repro.sim.engine import simulate_program


def run() -> list[Row]:
    cfg = get_config("llama3-8b")
    p1 = compile_decode_step(cfg, CompileOptions(n_cus=64, batch=1,
                                                 seq_len=16384))
    p32 = compile_decode_step(cfg, CompileOptions(n_cus=64, batch=32,
                                                  seq_len=8192))
    r1 = simulate_program(p1)
    r32 = simulate_program(p32)
    r32_serial = simulate_program(p32, decoupled=False)
    # global-barrier ablation at the scale where collectives matter
    p405 = compile_decode_step(get_config("llama3-405b"),
                               CompileOptions(n_cus=428, batch=1,
                                              seq_len=8192))
    r405 = simulate_program(p405)
    r405_barrier = simulate_program(p405, fine_grained_net=False)

    rows = [
        Row("Fig8", "llama3-8b BS=1 16k (64 CU) latency",
            r1.latency_s * 1e3, None, " ms/tok"),
        Row("Fig8", "BS=1 memory-BW utilization",
            r1.mem_bw_utilization, 1.0, "", "paper: saturates at BS=1"),
        Row("Fig8", "llama3-8b BS=32 8k latency", r32.latency_s * 1e3, None,
            " ms/tok"),
        Row("Fig8", "BS=32 / BS=1 latency ratio",
            r32.latency_s / r1.latency_s, 13.0, "x",
            "paper: ~13x (KV$ serialization); sharding-model delta noted"),
        Row("Fig8", "BS=32 buffer peak", r32.buffer_peak_bytes / 1e6, 6.0,
            " MB/CU", "paper: ~6MB lookahead"),
        Row("IX-C3", "decoupling speedup at BS=32 (ablation)",
            r32_serial.latency_s / r32.latency_s, 1.6, "x",
            "paper: up to 1.6x"),
        Row("IX-C3", "fine-grained net vs global barrier (405B/428CU)",
            r405_barrier.latency_s / r405.latency_s, 2.0, "x",
            "paper: avoids up to 2.0x"),
        Row("Fig8", "BS=32 compute busy fraction",
            r32.comp_busy_s / r32.latency_s),
        Row("Fig8", "BS=32 energy per step", r32.energy_j, None, " J"),
    ]
    return rows
