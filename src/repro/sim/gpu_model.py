"""Analytical H100/H200 decode baseline, calibrated to the paper's §II
profiling:

  * 32% of peak HBM bandwidth sustained during distributed low-batch decode
    (Fig 2 right; "consistent with prior work [33],[52],[68]").
  * full bandwidth only for >~1GB working sets; dense-kernel compute at
    ~70% of peak for the large compute-bound phases.
  * kernel-launch overhead ~4us/kernel; TP collective latency ~9us
    (§II "kernel launch overheads become non-negligible...").
  * decode phase draws ~34% of TDP (Fig 2 left).

Deployment dtypes for the comparison follow §VIII: 4-bit weights (MARLIN
[18]) + 16-bit activations, KV$ 16-bit.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import hardware
from repro.models.common import ModelConfig
from repro.models.footprint import Footprint, compute_footprint


@dataclasses.dataclass(frozen=True)
class GPUSystemConfig:
    chip: hardware.GPUSpec = hardware.H100
    n_gpus: int = 1
    weight_bits: float = 4.25         # MARLIN 4-bit + scales
    kv_bits: float = 16.0
    kernels_per_layer: int = 10       # qkv, rope, sdpa(2), o, 2xnorm, 3xmlp
    collectives_per_layer: int = 2    # Megatron TP: attn + mlp all-reduce

    @property
    def tdp_w(self) -> float:
        return self.chip.tdp_w * self.n_gpus


@dataclasses.dataclass
class GPULatency:
    total_s: float
    mem_s: float
    comp_s: float
    overhead_s: float
    bw_utilization: float
    energy_j: float              # per generated token

    @property
    def tokens_per_s(self) -> float:
        return 1.0 / self.total_s if self.total_s else 0.0


def _bw_utilization(gpu: GPUSystemConfig, working_set_bytes: float,
                    batch: int) -> float:
    """Paper Fig 2 (right): utilization grows with per-kernel working set,
    saturating only above ~1GB; low-batch decode measured at 0.32."""
    base = gpu.chip.decode_bw_utilization
    # working set per kernel per GPU ~ largest weight shard
    if working_set_bytes >= 1e9:
        return 0.85
    # log-linear ramp between 128MB (the paper's measured 0.32 regime —
    # Fig 2 right shows full BW "only when the working set exceeds ~1GB")
    lo, hi = 128e6, 1e9
    if working_set_bytes <= lo:
        return base
    f = (math.log(working_set_bytes) - math.log(lo)) / (math.log(hi) - math.log(lo))
    return base + f * (0.85 - base)


def gpu_decode_latency(cfg: ModelConfig, gpu: GPUSystemConfig, *,
                       batch: int = 1, seq_len: int = 8192,
                       fp: Footprint | None = None) -> GPULatency:
    """Per-token decode latency of the GPU baseline (full TP over n_gpus)."""
    fp = fp or compute_footprint(cfg)
    n = gpu.n_gpus
    chip = gpu.chip

    w_bytes = fp.active_param_bytes(gpu.weight_bits)
    kv_bytes = fp.kv_bytes(batch, seq_len, int(gpu.kv_bits // 8))
    stream = (w_bytes + kv_bytes) / n

    # per-kernel working set: one layer's biggest matrix shard per GPU
    biggest = 3 * cfg.d_model * cfg.d_ff * gpu.weight_bits / 8.0 / max(n, 1) / 3
    util = _bw_utilization(gpu, biggest, batch)
    mem_s = stream / (chip.hbm_bw * util)

    flops = fp.decode_flops_per_token(batch, seq_len) / n
    comp_s = flops / (chip.peak_flops_bf16 * chip.compute_efficiency)

    n_layers = cfg.n_layers
    overhead = n_layers * gpu.kernels_per_layer * chip.kernel_launch_s
    if n > 1:
        overhead += n_layers * gpu.collectives_per_layer * chip.collective_latency_s

    total = max(mem_s, comp_s) + overhead
    # §II: decode draws ~34% of TDP
    energy = gpu.tdp_w * 0.34 * total
    return GPULatency(total_s=total, mem_s=mem_s, comp_s=comp_s,
                      overhead_s=overhead, bw_utilization=util, energy_j=energy)


def min_gpus_for_model(cfg: ModelConfig, gpu_spec: hardware.GPUSpec,
                       weight_bits: float = 4.25, *, batch: int = 1,
                       seq_len: int = 8192) -> int:
    """Smallest GPU count whose HBM fits weights + KV$ (power of two)."""
    fp = compute_footprint(cfg)
    need = fp.param_bytes(weight_bits) + fp.kv_bytes(batch, seq_len, 2)
    n = 1
    while n * gpu_spec.hbm_capacity * 0.9 < need:
        n *= 2
    return n
