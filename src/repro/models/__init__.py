"""Model zoo: unified config + functional models for all assigned archs."""
from repro.models.common import ModelConfig, count_params
from repro.models.model import Model, build_model, build_plan, Segment
