"""Iteration-level request scheduler for continuous batching.

Request lifecycle:  PENDING --admit--> PREFILL --chunks done--> RUNNING
                        ^                 |                        |
                        +----preempt------+------------------------+
                                                RUNNING --finish--> FINISHED

Disaggregated serving splits the lifecycle across two engines: on a
prefill-phase engine, chunk completion parks the request in HANDOFF
(pages held, no decode) until the ``KVHandoff`` seam transfers its page
chain into a decode-phase engine, where it enters RUNNING directly via
``admit_handoff``.  A decode-side preemption re-queues the victim as
PENDING; the disaggregated driver drains it back to the prefill engine
(``drain_preempted``), whose re-prefill reproduces the identical chain.

The scheduler owns admission policy only; the engine drives the loop
(run one prefill **chunk** for each admitted-but-unfilled request, run one
fused decode step over every decoding slot, retire finished slots).
Admission is slot-based: the jitted decode step has a fixed batch of
``num_slots`` rows, and a request occupies one slot from admission to
finish.  Freed slots are refilled from the arrival queue on the **next
iteration** without recompiling — page tables and positions are data, not
shapes.

Admission allocates pages for the whole prompt up front, consulting the
prefix index: matching leading blocks are shared read-only and skipped by
prefill, so ``req.pos`` starts at the first *unseen* token.  Long prompts
then prefill in fixed-size chunks interleaved with decode iterations, so
admission never stalls the running batch.

Preemption (when the page pool is exhausted) is restart-style: the victim
loses its pages and generated tokens and re-queues at the front.  A
restart reproduces the same tokens — greedy trivially, and sampled
requests because every token's PRNG key is ``fold_in(seed, pos)`` (a
function of the request's seed and the token's sequence index only, see
``runtime.sampling``) — so preemption is invisible in the output stream.
The ``emitted`` counter is the one field a restart must NOT reset: it
marks how much of the stream the client has already seen, so the engine
re-emits nothing twice.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Iterable

import numpy as np

from repro.runtime.kv_cache import PagedKVCache
from repro.runtime.sampling import SamplingParams

PENDING, PREFILL, RUNNING, FINISHED = "pending", "prefill", "running", "finished"
# disaggregated serving: prefill finished, page chain awaiting transfer
HANDOFF = "handoff"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (plen,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0          # seconds relative to serve start
    sampling: SamplingParams | None = None   # engine default when None
    # -- mutable lifecycle state --
    state: str = PENDING
    slot: int = -1
    pos: int = 0                       # next cache write/prefill position
    tokens: list[int] = dataclasses.field(default_factory=list)
    logprobs: list[float] = dataclasses.field(default_factory=list)
    emitted: int = 0                   # tokens already streamed to the client
    finish_reason: str | None = None   # "stop" | "length" once finished
    admit_time: float | None = None
    first_token_time: float | None = None
    finish_time: float | None = None
    preemptions: int = 0
    chunks: int = 0                    # prefill chunks executed (all attempts)
    shared_tokens: int = 0             # prefix-cache tokens at last admission
    # -- speculative decoding (cumulative across preemption restarts:
    # re-run windows are real work, and their wasted draft tokens real
    # waste, so the per-request acceptance stats keep counting) --
    spec_windows: int = 0              # draft/verify windows run
    spec_accepted: int = 0             # draft proposals accepted (<= gamma/win)
    # -- prompt scoring (SamplingParams.prompt_logprobs) --
    prompt_logprobs: list[float] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def check_finish(self) -> str | None:
        """The finish reason the current token stream implies, or None —
        the single source of the stop/length rule (the engine applies it
        between steps)."""
        if (self.sampling and self.sampling.stop_token_ids and self.tokens
                and self.tokens[-1] in self.sampling.stop_token_ids):
            return "stop"
        if len(self.tokens) >= self.max_new_tokens:
            return "length"
        return None

    @property
    def done(self) -> bool:
        return self.check_finish() is not None

    @property
    def ttft(self) -> float | None:
        """Arrival -> first generated token (None until it exists)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> float | None:
        """Mean seconds per generated token after the first.

        None until the request finishes, and None for single-token outputs
        (there is no inter-token gap to measure).
        """
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = len(self.tokens) - 1
        if n <= 0:
            return None
        return (self.finish_time - self.first_token_time) / n


class Scheduler:
    """Slot-based admission over a paged KV cache."""

    def __init__(self, cache: PagedKVCache,
                 on_release: Callable[[int], None] | None = None,
                 max_running: int | None = None):
        self.cache = cache
        self.num_slots = cache.num_slots
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}
        self._free_slots: list[int] = list(range(self.num_slots))[::-1]
        # engine hook: a slot's per-slot sampling tensors are cleared the
        # moment the slot frees (preempt/finish), alongside its page rows
        self.on_release = on_release
        # bandwidth-model admission hint (``DeploymentSpec``): cap the
        # concurrently-admitted requests below ``num_slots`` when the
        # roofline says extra slots only stretch the decode step (the KV
        # stream already dominates the weight stream)
        self.max_running = min(self.num_slots,
                               max_running or self.num_slots)

    # -- queries ------------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.running)

    def next_arrival(self) -> float | None:
        # ``submit`` keeps the whole deque arrival-sorted (re-sorting when
        # a later batch arrives out of order) and ``preempt`` only
        # re-queues already-arrived requests at the front, so the head is
        # the minimum — no O(n) scan.
        return self.waiting[0].arrival_time if self.waiting else None

    @property
    def num_running(self) -> int:
        return len(self.running)

    def prefilling(self) -> list[Request]:
        return sorted((r for r in self.running.values() if r.state == PREFILL),
                      key=lambda r: r.rid)

    def decoding(self) -> list[Request]:
        return sorted((r for r in self.running.values() if r.state == RUNNING),
                      key=lambda r: r.rid)

    # -- lifecycle ----------------------------------------------------------
    def submit(self, requests: Iterable[Request]) -> None:
        reqs = sorted(requests, key=lambda r: r.arrival_time)
        if self.waiting and reqs \
                and reqs[0].arrival_time < self.waiting[-1].arrival_time:
            # a later submit with earlier arrivals: merge to keep the
            # deque sorted (next_arrival/admit read only the head)
            self.waiting = deque(sorted(
                list(self.waiting) + reqs, key=lambda r: r.arrival_time))
        else:
            self.waiting.extend(reqs)

    def admit(self, now: float) -> list[Request]:
        """Admit arrived requests into free slots while pages last.

        Admitted requests enter PREFILL with ``pos`` at the first token the
        prefix cache could not supply; the engine drives their chunks."""
        admitted: list[Request] = []
        while (self.waiting and self._free_slots
               and len(self.running) < self.max_running
               and self.waiting[0].arrival_time <= now):
            req = self.waiting[0]
            slot = self._free_slots[-1]
            # prompt-scoring requests skip prefix sharing: a shared prefix
            # would skip exactly the chunk positions whose logprobs were
            # asked for (their pages may still be shared FROM, once filled)
            plp = bool(req.sampling and req.sampling.prompt_logprobs)
            shared = self.cache.admit(slot, req.prompt_len,
                                      tokens=None if plp else req.prompt)
            if shared is None:
                break                      # pool exhausted: wait for frees
            self.waiting.popleft()
            self._free_slots.pop()
            req.state, req.slot = PREFILL, slot
            req.pos = shared               # skip straight past shared pages
            req.shared_tokens = shared
            req.admit_time = now
            self.running[slot] = req
            admitted.append(req)
        return admitted

    # -- disaggregated handoff ---------------------------------------------
    def handoff_ready(self) -> list[Request]:
        """Requests whose prefill finished and whose page chain is parked
        awaiting transfer to a decode-phase engine."""
        return sorted((r for r in self.running.values()
                       if r.state == HANDOFF),
                      key=lambda r: r.rid)

    def admit_handoff(self, req: Request, now: float) -> int | None:
        """Admit a prefilled request straight into RUNNING (decode phase).

        Allocates the prompt's page chain in THIS scheduler's cache —
        consulting the local prefix index, so previously-transferred
        tenant chains are shared instead of re-copied — and returns the
        shared token count, or None when no slot/pages are available
        (the transfer stays queued on the prefill side)."""
        if not self._free_slots or len(self.running) >= self.max_running:
            return None
        slot = self._free_slots[-1]
        plp = bool(req.sampling and req.sampling.prompt_logprobs)
        shared = self.cache.admit(slot, req.prompt_len,
                                  tokens=None if plp else req.prompt)
        if shared is None:
            return None
        self._free_slots.pop()
        req.state, req.slot = RUNNING, slot
        req.admit_time = now
        self.running[slot] = req
        return shared

    def release_handoff(self, slot: int) -> None:
        """Free a HANDOFF request's slot after its chain was transferred.

        Slot-keyed (not request-keyed): by transfer time the request's
        ``slot`` field already points at its decode-side slot.  The
        request is NOT finished — ownership moved to the decode engine.
        Pages shared into the prefix index keep their refs, so later
        prompts with the same prefix skip recompute on this side."""
        self.cache.release(slot)
        self.running.pop(slot)
        self._free_slots.append(slot)
        if self.on_release:
            self.on_release(slot)

    def drain_preempted(self) -> list[Request]:
        """Pop every preempted (PENDING) request off the waiting queue.

        A decode-phase engine cannot re-prefill a preemption victim; the
        disaggregated driver drains them back to the prefill engine."""
        out = [r for r in self.waiting if r.state == PENDING]
        if out:
            self.waiting = deque(r for r in self.waiting
                                 if r.state != PENDING)
        return out

    def requeue(self, req: Request) -> None:
        """Front-queue a preemption victim returned by the decode engine
        (mirrors ``preempt``'s appendleft priority on this side)."""
        req.state = PENDING
        self.waiting.appendleft(req)

    def ensure_capacity(self, req: Request, upto: int | None = None) -> bool:
        """Back ``req``'s write positions through ``upto`` (default: just
        ``req.pos``) with pages, evicting the youngest running request —
        INCLUDING ``req`` itself — while the pool is exhausted.  Returns
        False if ``req`` was preempted.  The speculative engine passes
        ``upto=req.pos + gamma`` so a whole draft/verify window's KV
        writes are backed before the window starts (windows never
        preempt midway — the capacity barrier is at window boundaries).

        A request never evicts one admitted before it: letting a
        freshly-admitted request evict an older one livelocks a pool too
        small for two working sets (each admission grabs the last free
        page, then its first growth evicts the other request, forever —
        the oldest request must be allowed to run to completion so its
        pages come back)."""
        while not self.cache.ensure(req.slot,
                                    req.pos if upto is None else upto):
            victim = max(self.running.values(),
                         key=lambda r: (r.admit_time, r.rid))
            self.preempt(victim)
            if victim is req:
                return False
        return True

    def preempt(self, req: Request) -> None:
        slot = req.slot
        self.cache.release(slot)
        self.running.pop(slot)
        self._free_slots.append(slot)
        req.preemptions += 1
        req.state, req.slot, req.pos = PENDING, -1, 0
        # restart re-derives the identical tokens (fold_in(seed, pos)
        # streams); ``emitted`` survives so nothing is streamed twice
        req.tokens.clear()
        req.logprobs.clear()
        req.prompt_logprobs.clear()
        self.waiting.appendleft(req)
        if self.on_release:
            self.on_release(slot)

    def finish(self, req: Request, now: float) -> None:
        slot = req.slot
        self.cache.release(slot)
        self.running.pop(slot)
        self._free_slots.append(slot)
        req.state, req.finish_time = FINISHED, now
        req.slot = -1
        if self.on_release:
            self.on_release(slot)
