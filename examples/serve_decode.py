"""End-to-end serving driver (the paper's kind: low-latency decode).

Prefill/decode disaggregation on a small model with batched requests:
  * prefill pass fills the KV caches (compute-bound phase);
  * the decode loop is ONE jitted lax.scan — no host round-trips (the JAX
    analogue of the RPU's autonomous execution);
  * optional speculative decoding (paper Fig 14: draft/target, lossless).

  PYTHONPATH=src python examples/serve_decode.py [--arch h2o-danube-1.8b]
      [--batch 8] [--new 48] [--speculative]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models.model import build_model
from repro.runtime.engine import ServeEngine
from repro.runtime.speculative import speculative_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--speculative", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    eng = ServeEngine(model, params,
                      max_len=args.prompt_len + args.new + 1,
                      temperature=args.temperature)
    # warm-up compile, then measure steady-state decode
    eng.generate({"tokens": prompts}, max_new_tokens=2)
    t0 = time.time()
    out = eng.generate({"tokens": prompts}, max_new_tokens=args.new)
    dt = time.time() - t0
    total = args.batch * args.new
    print(f"[batched decode] {args.batch} requests x {args.new} tokens in "
          f"{dt:.2f}s = {total/dt:.0f} tok/s")
    print("  first request:", out.tokens[0, :16].tolist())

    if args.speculative:
        # With an agreeing draft (here: the target itself) every window
        # accepts all gamma tokens; real deployments use a small trained
        # draft (paper: Llama3-8B drafting for 70B, 4.6/8 accepted).
        # Untrained random drafts accept ~0 — run one of each to show the
        # acceptance machinery.
        stats = speculative_generate(
            model, params, model, params, prompts[:1],
            max_new_tokens=args.new, gamma=4, temperature=0.0)
        print(f"[speculative, ideal draft] {stats.windows} windows, "
              f"{stats.mean_accepted:.2f}/4 accepted  tokens: "
              f"{stats.tokens[:8].tolist()}")
        draft_cfg = dataclasses.replace(cfg, name="draft",
                                        n_layers=max(2, cfg.n_layers // 2))
        draft = build_model(draft_cfg)
        dparams = draft.init(jax.random.fold_in(key, 2))
        stats = speculative_generate(
            draft, dparams, model, params, prompts[:1],
            max_new_tokens=args.new, gamma=4, temperature=0.0)
        print(f"[speculative, random draft] {stats.windows} windows, "
              f"{stats.mean_accepted:.2f}/4 accepted (untrained draft: "
              f"low acceptance expected; output stays lossless)")


if __name__ == "__main__":
    main()
