"""Ring collective matmuls — the JAX/TPU realization of the RPU's
distributed VMM dataflow (paper §IV).

The paper's scheme: weights are column-sharded across cores; each core
starts computing on its *local* activation fragment immediately while
forwarding fragments around the ring, so the vector broadcast is hidden
behind compute ("This strategy mirrors Cannon's algorithm ... data movement
and computation are interleaved").  The row-sharded variant needs a
reduction "always on the compute-network critical path".

JAX analogues (used inside ``jax.shard_map`` over a tensor-parallel axis):

  * ``ring_allgather_matmul``   — x fragment (B, K/P) x W columns (K, N/P):
    P steps, each overlapping one chunk matmul with one ``ppermute`` hop of
    the activation fragment.  == the paper's broadcast-overlap VMM.
  * ``ring_matmul_reducescatter`` — x fragment (B, K/P) x W rows (K/P, N):
    partial outputs travel the ring accumulating; each device ends with its
    fully-reduced (B, N/P) chunk.  == the paper's reduction-tree path.

Both are numerically identical (up to fp reassociation) to the dense
``x @ w`` and are property-tested against it.  XLA schedules the
``ppermute`` asynchronously (collective-permute-start/done), overlapping
the hop with the chunk matmul — the same decoupled compute/network
pipelining the Reasoning Core implements in hardware.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map


def _axis_size(axis_name) -> int:
    return jax.lax.psum(1, axis_name)


def ring_allgather_matmul(x_frag: jnp.ndarray, w_cols: jnp.ndarray,
                          axis_name: str) -> jnp.ndarray:
    """Column-sharded VMM with broadcast-compute overlap.

    x_frag: (..., B, K/P) local activation fragment (K sharded)
    w_cols: (K, N/P) local full-K column shard
    returns (..., B, N/P) local output columns.
    """
    p = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    kp = x_frag.shape[-1]
    nl = w_cols.shape[-1]
    perm = [(j, (j + 1) % p) for j in range(p)]

    def step(i, carry):
        acc, frag = carry
        src = jax.lax.rem(idx - i + p, p)          # origin of current fragment
        w_slice = jax.lax.dynamic_slice_in_dim(w_cols, src * kp, kp, axis=0)
        acc = acc + jnp.matmul(frag, w_slice.astype(frag.dtype),
                               preferred_element_type=jnp.float32)
        frag = jax.lax.cond(
            i < p - 1,
            lambda f: jax.lax.ppermute(f, axis_name, perm),
            lambda f: f,
            frag)
        return acc, frag

    acc0 = jnp.zeros(x_frag.shape[:-1] + (nl,), jnp.float32)
    acc, _ = jax.lax.fori_loop(0, p, step, (acc0, x_frag), unroll=True)
    return acc.astype(x_frag.dtype)


def ring_matmul_reducescatter(x_frag: jnp.ndarray, w_rows: jnp.ndarray,
                              axis_name: str) -> jnp.ndarray:
    """Row-sharded VMM with ring reduce-scatter overlap.

    x_frag: (..., B, K/P) local activation fragment
    w_rows: (K/P, N) local row shard
    returns (..., B, N/P): device d holds output columns [d*N/P, (d+1)*N/P).
    """
    p = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    n = w_rows.shape[-1]
    nl = n // p
    perm = [(j, (j + 1) % p) for j in range(p)]

    def chunk(c):
        w_slice = jax.lax.dynamic_slice_in_dim(w_rows, c * nl, nl, axis=1)
        return jnp.matmul(x_frag, w_slice.astype(x_frag.dtype),
                          preferred_element_type=jnp.float32)

    # partial for chunk (idx - i - 1) arrives having visited i devices;
    # add our contribution and pass on.  After P-1 hops we hold our own
    # fully-reduced chunk.
    def step(i, acc):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        c = jax.lax.rem(idx - i - 1 + 2 * p, p)
        return acc + chunk(c)

    acc0 = chunk(jax.lax.rem(idx + p - 1, p))      # i = 0 chunk (no recv yet)
    acc = jax.lax.fori_loop(1, p, step, acc0, unroll=True)
    return acc.astype(x_frag.dtype)


# ---------------------------------------------------------------------------
# pjit-level wrappers: apply the ring kernels over a mesh axis via shard_map
# ---------------------------------------------------------------------------


def tp_linear_overlapped(x: jnp.ndarray, w: jnp.ndarray, mesh,
                         tp_axis: str = "model", mode: str = "ag") -> jnp.ndarray:
    """Tensor-parallel linear with RPU-style ring overlap.

    x: (..., K) with its last dim sharded over ``tp_axis``;
    w: (K, N) column-sharded (mode="ag") or row-sharded (mode="rs").
    Output: (..., N) sharded over ``tp_axis`` on the last dim.

    ``shard_map`` is manual only over ``tp_axis`` (``axis_names``); any
    data-parallel sharding of the leading dims stays on the automatic
    (GSPMD) side, so this composes with pjit-sharded batches.
    """
    nb = x.ndim - 1
    lead = (None,) * nb

    if mode == "ag":
        in_specs = (P(*lead, tp_axis), P(None, tp_axis))
        fn = ring_allgather_matmul
    elif mode == "rs":
        in_specs = (P(*lead, tp_axis), P(tp_axis, None))
        fn = ring_matmul_reducescatter
    else:
        raise ValueError(mode)

    return shard_map(
        functools.partial(fn, axis_name=tp_axis),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(*lead, tp_axis),
        axis_names={tp_axis},
        check_vma=False,
    )(x, w)
