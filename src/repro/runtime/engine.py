"""Serving engines: static batch and continuous batching.

``ServeEngine`` mirrors the paper's deployment model (§VI "Deployment"):
prefill and decode are separate entry points (Splitwise/Dynamo-style phase
splitting, the paper's prerequisite architecture), and the decode loop runs
as ONE jitted ``lax.scan`` over steps — no host round-trip per token, the
JAX analogue of the RPU's host-free autonomous execution ("eliminating the
host-driven offload model used by GPUs").

``ContinuousServeEngine`` is the throughput path the paper's ISO-TDP claim
rests on: decode is bandwidth-bound, so sustained tokens/s is proportional
to slot occupancy.  Requests arrive raggedly; iteration-level batching
admits each one into a freed decode slot the moment both a slot and KV
pages are available, so the fused decode step stays full without
recompiling — page tables and positions are data, not shapes.

Both engines are mesh-agnostic: pass shardings built by ``parallel.plan``
to run the same code distributed; CPU tests run them single-device.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.runtime import sampling
from repro.runtime.kv_cache import PagedKVCache
from repro.runtime.scheduler import Request, Scheduler


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # (B, n_new) int32
    logprobs: jnp.ndarray | None
    steps: int


class ServeEngine:
    """Batched request serving for one model."""

    def __init__(self, model: Model, params: Any, *, max_len: int,
                 temperature: float = 0.0, top_k: int = 0,
                 donate_cache: bool = True, cache_dtype=None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.top_k = top_k
        self.cache_dtype = cache_dtype
        self._decode_loop = jax.jit(
            self._decode_loop_impl,
            static_argnames=("n_steps",),
            donate_argnums=(1,) if donate_cache else (),
        )
        self._prefill = jax.jit(self.model.prefill)

    # -- phase 1: prefill ---------------------------------------------------
    def prefill(self, batch: dict):
        """Run the prompt; returns (first_token_logits, cache, prompt_len)."""
        b = (batch["features"] if "features" in batch else batch["tokens"]).shape[0]
        cache = self.model.init_cache(b, self.max_len, dtype=self.cache_dtype)
        logits, cache = self._prefill(self.params, batch, cache)
        plen = batch["tokens"].shape[1]
        if "image_embeds" in batch:
            plen += batch["image_embeds"].shape[1]
        return logits, cache, plen

    # -- phase 2: autonomous decode loop -------------------------------------
    def _decode_loop_impl(self, first_tokens, cache, start_pos, key, *,
                          n_steps: int):
        def step(carry, _):
            tokens, cache, pos, key = carry
            logits, cache = self.model.decode_step(self.params, tokens, cache, pos)
            key, sub = jax.random.split(key)
            nxt = sampling.sample(sub, logits, self.temperature, self.top_k)
            return (nxt, cache, pos + 1, key), nxt

        (_, cache, _, _), toks = jax.lax.scan(
            step, (first_tokens, cache, start_pos, key), length=n_steps)
        return jnp.moveaxis(toks, 0, 1), cache     # (B, n_steps)

    def generate(self, batch: dict, *, max_new_tokens: int,
                 key=None) -> GenerationResult:
        """prefill + decode max_new_tokens; returns all generated tokens."""
        key = key if key is not None else jax.random.PRNGKey(0)
        logits, cache, plen = self.prefill(batch)
        key, sub = jax.random.split(key)
        first = sampling.sample(sub, logits, self.temperature, self.top_k)
        toks, cache = self._decode_loop(
            first, cache, jnp.int32(plen), key, n_steps=max_new_tokens - 1)
        all_toks = jnp.concatenate([first[:, None], toks], axis=1)
        return GenerationResult(tokens=all_toks, logprobs=None,
                                steps=max_new_tokens)


@dataclasses.dataclass
class ContinuousStats:
    """Outcome of one ``ContinuousServeEngine.run``."""
    results: dict                 # rid -> np.ndarray (n_new,) int32
    steps: int                    # fused decode iterations executed
    occupancy: float              # mean fraction of busy slots per step
    wall: float                   # seconds, admission of first request -> done
    preemptions: int

    @property
    def total_tokens(self) -> int:
        return int(sum(t.shape[0] for t in self.results.values()))


class ContinuousServeEngine:
    """Iteration-level continuous batching over a block-paged KV cache.

    The jitted decode step has a fixed slot batch; per-slot page tables and
    ragged positions route each slot's K/V stream through the physical page
    pools (``Model.decode_step_paged``).  Admission, growth, eviction, and
    retirement are host-side bookkeeping between steps — no recompiles.
    """

    def __init__(self, model: Model, params: Any, *, num_slots: int,
                 page_size: int, num_pages: int, max_len: int,
                 temperature: float = 0.0, top_k: int = 0,
                 cache_dtype=None):
        if model.cfg.frontend is not None:
            raise NotImplementedError(
                "continuous batching serves token frontends only")
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_blocks = -(-max_len // page_size)
        if num_pages - 1 < self.max_blocks:   # page 0 is scratch
            raise ValueError(
                f"num_pages={num_pages} cannot back even one max-length "
                f"request ({self.max_blocks} blocks + scratch)")
        self.temperature = temperature
        self.top_k = top_k
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(model.prefill)
        self._scatter = jax.jit(model.scatter_prefill_cache,
                                donate_argnums=(0,))
        self._step = jax.jit(self._step_impl, donate_argnums=(1,))

    # -- jitted pieces ------------------------------------------------------
    def _step_impl(self, params, pools, tokens, pos, page_table, key):
        logits, pools = self.model.decode_step_paged(params, tokens, pools,
                                                     page_table, pos)
        key, sub = jax.random.split(key)
        nxt = sampling.sample(sub, logits, self.temperature, self.top_k)
        return nxt, pools, key

    def _permute_pools(self, pools, gather):
        """Apply a defrag page permutation to every pool leaf."""
        gather = jnp.asarray(gather)
        new_pools = []
        for si, seg in enumerate(self.model.plan):
            axis = 0 if seg.reps == 1 else 1
            new_pools.append(tuple(
                {k: jnp.take(v, gather, axis=axis) for k, v in pool.items()}
                for pool in pools[si]))
        return new_pools

    # -- host loop ----------------------------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        b = 1
        while b < n:
            b *= 2
        return b

    def _admit_batch(self, reqs: list, pools, key):
        """Prefill a group of same-length requests together and scatter
        their KV into their pages.  The batch is padded to a power of two
        (padded rows scatter into the scratch page), so admission compiles
        at most log2(num_slots) prefill shapes per prompt length instead of
        one jitted batch-1 prefill per request."""
        plen = reqs[0].prompt_len
        n_blocks = -(-plen // self.page_size)
        bucket = self._bucket(len(reqs))
        prompts = np.stack([r.prompt for r in reqs]
                           + [reqs[-1].prompt] * (bucket - len(reqs)))
        dense = self.model.init_cache(bucket, n_blocks * self.page_size,
                                      dtype=self.cache_dtype)
        logits, dense = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)}, dense)
        key, sub = jax.random.split(key)
        first = np.asarray(sampling.sample(sub, logits, self.temperature,
                                           self.top_k))
        table = self.cache.table()
        pt_rows = np.zeros((bucket, n_blocks), np.int32)   # pad rows -> scratch
        for i, r in enumerate(reqs):
            r.tokens.append(int(first[i]))
            pt_rows[i] = table[r.slot, :n_blocks]
        pools = self._scatter(pools, dense, jnp.asarray(pt_rows))
        return pools, key

    def run(self, requests: Iterable[Request], *, key=None,
            defrag_every: int = 0) -> ContinuousStats:
        """Serve ``requests`` to completion; honors ``arrival_time``."""
        self.cache = PagedKVCache(num_slots=self.num_slots,
                                  num_pages=self.num_pages,
                                  page_size=self.page_size,
                                  max_blocks=self.max_blocks)
        sched = Scheduler(self.cache)
        requests = list(requests)
        for r in requests:
            if r.prompt_len + r.max_new_tokens > self.max_blocks * self.page_size:
                raise ValueError(
                    f"request {r.rid}: prompt {r.prompt_len} + "
                    f"{r.max_new_tokens} new tokens exceeds max_len "
                    f"{self.max_blocks * self.page_size}")
        sched.submit(requests)
        pools = self.model.init_paged_cache(self.num_pages, self.page_size,
                                            dtype=self.cache_dtype)
        key = key if key is not None else jax.random.PRNGKey(0)
        t0 = time.monotonic()
        now = lambda: time.monotonic() - t0
        steps, occ_sum, preempted = 0, 0.0, 0

        while sched.has_work():
            admitted = sched.admit(now())
            by_plen: dict[int, list] = {}
            for req in admitted:
                by_plen.setdefault(req.prompt_len, []).append(req)
            for group in by_plen.values():
                pools, key = self._admit_batch(group, pools, key)
            for req in admitted:
                if req.done:
                    sched.finish(req, now())
            if not sched.running:
                nxt_t = sched.next_arrival()
                if nxt_t is None:
                    break
                time.sleep(max(nxt_t - now(), 0.0))
                continue
            for req in sorted(sched.running.values(), key=lambda r: r.rid):
                if req.slot in sched.running:          # not yet preempted
                    sched.ensure_capacity(req)
            if not sched.running:
                continue
            if defrag_every and (steps + 1) % defrag_every == 0:
                gather = self.cache.defrag()
                if gather is not None:
                    pools = self._permute_pools(pools, gather)

            tokens = np.zeros((self.num_slots,), np.int32)
            pos = np.zeros((self.num_slots,), np.int32)
            for slot, req in sched.running.items():
                tokens[slot] = req.tokens[-1]
                pos[slot] = req.pos
            nxt, pools, key = self._step(
                self.params, pools, jnp.asarray(tokens), jnp.asarray(pos),
                jnp.asarray(self.cache.table()), key)
            nxt = np.asarray(nxt)                      # device sync
            occ_sum += len(sched.running) / self.num_slots
            steps += 1
            for slot, req in list(sched.running.items()):
                req.tokens.append(int(nxt[slot]))
                req.pos += 1
                if req.done:
                    sched.finish(req, now())

        preempted = sum(r.preemptions for r in requests)
        results = {r.rid: np.asarray(r.tokens[:r.max_new_tokens], np.int32)
                   for r in requests}
        return ContinuousStats(results=results, steps=steps,
                               occupancy=occ_sum / max(steps, 1),
                               wall=now(), preemptions=preempted)


def serve_step_fn(model: Model):
    """The bare decode step (one token, KV cache) — the function the
    dry-run lowers for ``decode_*`` / ``long_*`` shapes."""

    def serve_step(params, tokens, cache, cur_pos):
        logits, new_cache = model.decode_step(params, tokens, cache, cur_pos)
        return sampling.greedy(logits), new_cache

    return serve_step


def prefill_step_fn(model: Model):
    """Forward over the full prompt — lowered for ``prefill_*`` shapes."""

    def prefill_step(params, batch):
        return model.forward(params, batch)

    return prefill_step
