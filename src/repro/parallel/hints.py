"""Logical sharding hints — decouples model code from mesh layout.

Model layers call ``shard_hint(x, "act_btd")`` at layer boundaries; the
launcher installs a rules table mapping logical names to
``PartitionSpec``s for the active mesh (see ``parallel.plan``).  Outside a
rules context the hints are no-ops, so models stay pure single-device code
for CPU tests.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping

import jax

_RULES: contextvars.ContextVar[Mapping | None] = contextvars.ContextVar(
    "shard_rules", default=None)


@contextlib.contextmanager
def sharding_rules(rules: Mapping):
    """Install logical-name -> PartitionSpec rules for the enclosed trace."""
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def _drop_uneven(sharding, shape):
    """Drop sharded axes on dims the array size doesn't divide (e.g. 25
    heads over a 16-way model axis) — the hint then constrains only the
    dims that partition cleanly."""
    from jax.sharding import NamedSharding, PartitionSpec
    if not isinstance(sharding, NamedSharding):
        return sharding
    mesh = sharding.mesh
    spec = sharding.spec
    new = []
    changed = False
    for dim in range(len(shape)):
        entry = spec[dim] if dim < len(spec) else None
        if entry is None:
            new.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        if shape[dim] % prod != 0:
            new.append(None)
            changed = True
        else:
            new.append(entry)
    if not changed:
        return sharding
    return NamedSharding(mesh, PartitionSpec(*new))


_SUSPENDED: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "shard_hints_suspended", default=False)


@contextlib.contextmanager
def suspend_hints():
    """Disable shard hints for the enclosed trace — used inside shard_map
    manual regions, where constraints built from the launcher's (all-Auto)
    mesh are invalid and break the backward pass."""
    token = _SUSPENDED.set(True)
    try:
        yield
    finally:
        _SUSPENDED.reset(token)


def _in_manual_region() -> bool:
    return _SUSPENDED.get()


def _rebuild_for_context(sharding):
    """Rebuild the rule's NamedSharding against the ambient abstract mesh.

    Inside a partial-manual shard_map region the context mesh marks some
    axes Manual; a constraint built from the launcher's all-Auto Mesh is
    rejected (including by the backward pass).  Keep only spec axes that
    are Auto in the ambient mesh and bind the spec to that mesh.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return sharding
    if am is None or not getattr(am, "axis_names", ()):
        return sharding
    if tuple(am.axis_names) != tuple(sharding.mesh.axis_names):
        return sharding
    types = dict(zip(am.axis_names, am.axis_types))
    manual = {a for a, t in types.items() if "Manual" in str(t)}
    if not manual:
        return sharding
    new = []
    for entry in sharding.spec:
        if entry is None:
            new.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a not in manual)
        new.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return NamedSharding(am, PartitionSpec(*new))


def shard_hint(x, name: str):
    """Apply a sharding constraint if a rule for ``name`` is installed."""
    rules = _RULES.get()
    if rules is None:
        return x
    spec = rules.get(name)
    if spec is None:
        return x
    if _in_manual_region():
        return x
    sh = _rebuild_for_context(spec)
    return jax.lax.with_sharding_constraint(x, _drop_uneven(sh, x.shape))


def ep_context():
    """(mesh, model_axis_name) for expert-parallel shard_map regions, or
    None outside a sharded launch (single-device tests)."""
    rules = _RULES.get()
    if rules is None:
        return None
    return rules.get("__ep__")
