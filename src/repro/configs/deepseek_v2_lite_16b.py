"""DeepSeek-V2-Lite 16B — MLA (kv_lora=512) + MoE 64 routed experts
top-6 with 2 shared experts; first layer dense.  [arXiv:2405.04434]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,               # first dense layer FFN
    vocab_size=102400, vocab_pad_multiple=512,
    mla=True,
    kv_lora_rank=512,
    rope_head_dim=64,
    v_head_dim=128,
    moe=True,
    n_experts=64,
    n_experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10000.0,
)
