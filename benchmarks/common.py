"""Shared benchmark plumbing: result rows + rendering."""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any

EXP_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments"


@dataclasses.dataclass
class Row:
    figure: str           # paper anchor, e.g. "Fig5", "Fig11", "ours:roofline"
    metric: str
    value: Any
    paper: Any = None     # the paper's number, when one exists
    unit: str = ""
    note: str = ""

    def render(self) -> str:
        p = f" (paper {self.paper}{self.unit})" if self.paper is not None else ""
        v = f"{self.value:.4g}" if isinstance(self.value, float) else str(self.value)
        return f"{self.figure:22s} {self.metric:46s} {v}{self.unit}{p} {self.note}"


def dump(rows: list[Row], name: str):
    EXP_DIR.mkdir(parents=True, exist_ok=True)
    out = EXP_DIR / f"bench_{name}.json"
    out.write_text(json.dumps([dataclasses.asdict(r) for r in rows], indent=1,
                              default=str))
