"""Power & area provisioning model (paper §IV, Contribution 2).

The RPU's central provisioning argument: dedicate 70-80% of TDP to memory
interfaces and align compute-to-bandwidth at 32 OPs/Byte (vs ~200 for an
H100-like design), so that a memory-bandwidth-bound workload runs near the
power envelope instead of leaving it stranded.

This module computes:
  * per-CU power at a given utilization point and per-CU TDP,
  * ISO-TDP CU counts against GPU baselines (the paper's Fig 11 anchors:
    4xH100 @ 2800 W <-> ~308 CUs),
  * the die-cost / TDP-utilization deltas of re-provisioning the
    compute-to-bandwidth ratio (paper §IX Contribution 2: 3.3x die cost,
    2.6x TDP utilization).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import hardware
from repro.core.hbmco import HBMCOConfig, CANDIDATE_CO

# Datapath adder for streaming memory into the on-chip buffer (paper Fig 8:
# ~6.7 W per CU at full 512 GB/s stream => ~1.64 pJ/b total vs the 1.45 pJ/b
# device figure; the difference is the HBM->buffer datapath).
DATAPATH_PJ_PER_BIT = 0.19


def cu_mem_stream_w(mem: HBMCOConfig, bw_util: float = 1.0,
                    rpu: hardware.RPUChipParams = hardware.RPU_DEFAULT) -> float:
    """Power of one CU's memory stream at a given bandwidth utilization."""
    pj = mem.energy_pj_per_bit + DATAPATH_PJ_PER_BIT
    return rpu.cu_mem_bw * bw_util * 8.0 * pj * 1e-12


def cu_power_w(mem: HBMCOConfig, bw_util: float, compute_util: float,
               net_util: float = 0.0,
               rpu: hardware.RPUChipParams = hardware.RPU_DEFAULT) -> float:
    """Operating power of one CU at the given pipeline utilizations."""
    mem_w = cu_mem_stream_w(mem, bw_util, rpu)
    compute_w = rpu.compute_w_per_cu_peak * compute_util
    # ring traffic at CU granularity: outer-ring bytes at off-package energy
    net_w = rpu.ring_bw * net_util * 8.0 * rpu.net_pj_per_bit_off_pkg * 1e-12
    return mem_w + compute_w + net_w


def cu_tdp_w(mem: HBMCOConfig,
             rpu: hardware.RPUChipParams = hardware.RPU_DEFAULT) -> float:
    """Per-CU TDP: full memory stream / memory power fraction (70-80%)."""
    return cu_mem_stream_w(mem, 1.0, rpu) / rpu.mem_power_fraction


def iso_tdp_cus(target_tdp_w: float, mem: HBMCOConfig = CANDIDATE_CO,
                rpu: hardware.RPUChipParams = hardware.RPU_DEFAULT) -> int:
    """How many CUs fit in a GPU-system power envelope (paper Fig 11)."""
    return max(1, math.floor(target_tdp_w / cu_tdp_w(mem, rpu)))


# ---------------------------------------------------------------------------
# Compute-to-bandwidth provisioning comparison (paper §IX, Contribution 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProvisioningPoint:
    """A (OPs/Byte, memory power fraction) design point."""

    name: str
    ops_per_byte: float
    mem_power_fraction: float
    # area model: die area per GB/s of shoreline bandwidth =
    #   compute area (scales with provisioned OPs/Byte) + fixed area
    #   (IO shoreline drivers, network, buffers — does NOT scale with compute)
    mm2_per_tops: float = 0.55
    fixed_mm2_per_gbs: float = 0.0225

    def die_mm2_per_gbs(self) -> float:
        """Die area required per GB/s of provisioned bandwidth."""
        tops_per_gbs = self.ops_per_byte / 1000.0  # TOP/s per GB/s
        return tops_per_gbs * self.mm2_per_tops + self.fixed_mm2_per_gbs


# GPU-like provisioning: ~200 OPs/Byte, 30-40% of TDP to memory (§IV);
# RPU: 32 OPs/Byte, 70-80% of TDP to memory.  The fixed area term (IO
# drivers / buffers / network, ~1.3x the 32-OPs/B compute area) reproduces
# the paper's 3.3x die-cost saving.
GPU_LIKE = ProvisioningPoint("gpu-like-200ops", 200.0, 0.30)
RPU_POINT = ProvisioningPoint("rpu-32ops", 32.0, 0.78)


def die_cost_saving(a: ProvisioningPoint = GPU_LIKE,
                    b: ProvisioningPoint = RPU_POINT) -> float:
    """Die-cost ratio per unit bandwidth of provisioning ``a`` vs ``b``.

    Paper §IX-C2 reports ~3.3x die-cost saving from re-provisioning
    ~200 OPs/Byte -> 32 OPs/Byte at equal shoreline bandwidth.
    """
    return a.die_mm2_per_gbs() / b.die_mm2_per_gbs()


def tdp_utilization(point: ProvisioningPoint, workload_ai_ops_per_byte: float) -> float:
    """Fraction of TDP a memory-bound workload can actually use.

    For a workload with arithmetic intensity AI < provisioned OPs/Byte, the
    memory stream runs at 100% while compute runs at AI/provisioned; power
    utilization = mem_fraction + (1-mem_fraction) * AI/provisioned.
    """
    compute_util = min(1.0, workload_ai_ops_per_byte / point.ops_per_byte)
    return point.mem_power_fraction + (1.0 - point.mem_power_fraction) * compute_util


def tdp_utilization_gain(workload_ai: float = 1.0,
                         a: ProvisioningPoint = RPU_POINT,
                         b: ProvisioningPoint = GPU_LIKE) -> float:
    """Paper §IX-C2: ~2.6x TDP utilization at decode-like AI (~2 OPs/Byte)."""
    return tdp_utilization(a, workload_ai) / tdp_utilization(b, workload_ai)


# ---------------------------------------------------------------------------
# Shoreline argument (paper §IV: chiplets expose ~10x more IO shoreline)
# ---------------------------------------------------------------------------


def shoreline_mm(n_chiplets: int, chiplet_mm2: float = 60.0,
                 edge_fraction: float = 0.5) -> float:
    """Usable memory-IO shoreline of a sea of chiplets.

    The paper: for the same compute die area the RPU exposes ~600mm of
    shoreline vs ~60mm for a reticle-limited H100 (both long edges of each
    small chiplet face an HBM-CO stack).
    """
    edge = math.sqrt(chiplet_mm2)
    return n_chiplets * 2 * edge * edge_fraction * 2  # two edges, both sides
