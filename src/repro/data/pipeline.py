"""Synthetic data pipeline with host sharding, prefetch, and straggler
mitigation.

At 1000+ node scale the data tier is a major fault source: a slow or dead
reader host must not stall the whole step.  The pipeline therefore fetches
with a deadline; on timeout it substitutes the *last good batch* (bounded
reuse) and records the event — the standard straggler-mitigation policy
(bounded-staleness fallback).  Failure injection hooks make this testable.

Batches are deterministic functions of (seed, step, shard), so restarts
resume bit-identically from the checkpointed step — the data-side half of
the fault-tolerance contract.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterator

import numpy as np

from repro.models.common import ModelConfig


@dataclasses.dataclass
class PipelineStats:
    fetched: int = 0
    straggler_fallbacks: int = 0
    max_reuse_run: int = 0


class SyntheticTokenPipeline:
    """Deterministic token batches for LM training.

    ``delay_fn(step) -> seconds`` injects synthetic straggler latency for
    tests/benchmarks.
    """

    def __init__(self, cfg: ModelConfig, *, global_batch: int, seq_len: int,
                 seed: int = 0, shard: int = 0, n_shards: int = 1,
                 straggler_timeout_s: float | None = None,
                 max_batch_reuse: int = 3,
                 delay_fn: Callable[[int], float] | None = None):
        assert global_batch % n_shards == 0
        self.cfg = cfg
        self.local_batch = global_batch // n_shards
        self.seq_len = seq_len
        self.seed = seed
        self.shard = shard
        self.timeout = straggler_timeout_s
        self.max_reuse = max_batch_reuse
        self.delay_fn = delay_fn
        self.stats = PipelineStats()
        self._last_good: dict | None = None
        self._reuse_run = 0

    # -- raw generation ------------------------------------------------------
    def _make_batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        cfg = self.cfg
        b, s = self.local_batch, self.seq_len
        if cfg.frontend == "audio":
            return {
                "features": rng.standard_normal((b, s, cfg.d_model),
                                                dtype=np.float32),
                "labels": rng.integers(0, cfg.vocab_size, (b, s),
                                       dtype=np.int32),
            }
        if cfg.frontend == "vision":
            ni = cfg.n_frontend_tokens
            return {
                "tokens": rng.integers(0, cfg.vocab_size, (b, s - ni),
                                       dtype=np.int32),
                "image_embeds": rng.standard_normal((b, ni, cfg.d_model),
                                                    dtype=np.float32),
            }
        return {"tokens": rng.integers(0, cfg.vocab_size, (b, s),
                                       dtype=np.int32)}

    def _fetch_with_deadline(self, step: int) -> dict | None:
        """Returns the batch, or None if the deadline was exceeded."""
        if self.delay_fn is None or self.timeout is None:
            if self.delay_fn is not None:
                time.sleep(self.delay_fn(step))
            return self._make_batch(step)
        result: list = [None]

        def work():
            time.sleep(self.delay_fn(step))
            result[0] = self._make_batch(step)

        t = threading.Thread(target=work, daemon=True)
        t.start()
        t.join(self.timeout)
        return result[0]

    # -- public --------------------------------------------------------------
    def get_batch(self, step: int) -> dict:
        batch = self._fetch_with_deadline(step)
        if batch is None:
            # straggler: bounded-staleness fallback to the last good batch
            self.stats.straggler_fallbacks += 1
            self._reuse_run += 1
            self.stats.max_reuse_run = max(self.stats.max_reuse_run,
                                           self._reuse_run)
            if self._last_good is None or self._reuse_run > self.max_reuse:
                # nothing to reuse (or reused too long): block for real
                batch = self._make_batch(step)
                self._reuse_run = 0
            else:
                return self._last_good
        else:
            self._reuse_run = 0
        self.stats.fetched += 1
        self._last_good = batch
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.get_batch(step)
            step += 1
