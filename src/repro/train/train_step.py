"""Train step factory: loss -> grads -> AdamW, with optional activation
rematerialization and cross-pod int8 gradient compression.

``make_train_step(model, opt_cfg)`` returns the function the dry-run lowers
for ``train_*`` shapes and the launcher jits for real runs.

Compression path (``compress_pods=True``): the step is wrapped in a
``shard_map`` manual ONLY over the ``pod`` axis — intra-pod DP reduction
and tensor parallelism stay on the automatic (GSPMD) side — and the
cross-pod gradient mean uses int8 error-feedback compression
(``parallel.compression``), cutting the slow inter-pod wire bytes ~8x.
The error-feedback residual is part of TrainState (leading pod axis).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.model import Model
from repro.parallel import compression
from repro.parallel.compat import shard_map
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    err: Any = None          # cross-pod compression residual (or None)

    def tree_flatten(self):
        return (self.params, self.opt_state, self.err), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def step(self):
        return self.opt_state["step"]


def init_train_state(model: Model, key, *, n_pods: int = 0,
                     state_dtype: str = "float32") -> TrainState:
    params = model.init(key)
    err = None
    if n_pods:
        err = jax.tree.map(
            lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)
    return TrainState(params=params,
                      opt_state=init_opt_state(params, state_dtype), err=err)


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    remat: bool = False,
                    compress_pods: bool = False,
                    mesh=None,
                    pod_axis: str = "pod"):
    """Build ``train_step(state, batch) -> (state, metrics)``."""

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat)

    if not compress_pods:
        def train_step(state: TrainState, batch: dict):
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            new_params, new_opt, metrics = adamw_update(
                opt_cfg, state.params, grads, state.opt_state)
            metrics["loss"] = loss
            return TrainState(new_params, new_opt, state.err), metrics
        return train_step

    assert mesh is not None and pod_axis in mesh.axis_names

    def train_step(state: TrainState, batch: dict):
        def per_pod(params, batch_local, err_local):
            # local (per-pod) grads; data/model axes remain automatic.
            loss, grads = jax.value_and_grad(loss_fn)(params, batch_local)
            err_local = jax.tree.map(lambda e: e[0], err_local)
            synced, new_err = compression.tree_compressed_mean(
                grads, err_local, pod_axis)
            loss = jax.lax.pmean(loss, pod_axis)
            new_err = jax.tree.map(lambda e: e[None], new_err)
            return loss, synced, new_err

        pspec = jax.tree.map(lambda _: P(), state.params)
        bspec = jax.tree.map(lambda _: P(pod_axis), batch)
        espec = jax.tree.map(lambda _: P(pod_axis), state.err)
        loss, grads, new_err = shard_map(
            per_pod, mesh=mesh,
            in_specs=(pspec, bspec, espec),
            out_specs=(P(), pspec, espec),
            axis_names={pod_axis}, check_vma=False,
        )(state.params, batch, state.err)

        new_params, new_opt, metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt_state)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, new_err), metrics

    return train_step
